#!/usr/bin/env python3
"""Driver for the full dry-run matrix: one subprocess per cell (isolated
XLA state), resumable (skips cells whose JSON artifact already exists).

  python scripts/dryrun_all.py --out experiments/dryrun [--mesh pod1]
"""
import argparse
import json
import os
import subprocess
import sys
import time

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# smallest models first: bank results early
ARCH_ORDER = [
    "gemma3-1b", "mamba2-370m", "zamba2-1.2b", "hubert-xlarge",
    "paligemma-3b", "minitron-8b", "gemma2-9b", "qwen3-32b",
    "llama4-scout-17b-a16e", "dbrx-132b",
]
SHAPES = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]

SKIPS = {
    ("hubert-xlarge", "decode_32k"), ("hubert-xlarge", "long_500k"),
    ("qwen3-32b", "long_500k"), ("minitron-8b", "long_500k"),
    ("gemma2-9b", "long_500k"), ("dbrx-132b", "long_500k"),
    ("llama4-scout-17b-a16e", "long_500k"), ("paligemma-3b", "long_500k"),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--mesh", default="", help="pod1|pod2|'' (both)")
    ap.add_argument("--timeout", type=int, default=3000)
    ap.add_argument("--only-arch", default="")
    args = ap.parse_args()

    meshes = [args.mesh] if args.mesh else ["pod1", "pod2"]
    os.makedirs(args.out, exist_ok=True)
    log = open(os.path.join(args.out, "driver.log"), "a")

    cells = [(a, s, m) for m in meshes for a in ARCH_ORDER for s in SHAPES
             if (a, s) not in SKIPS
             and (not args.only_arch or a == args.only_arch)]
    done = failed = 0
    for arch, shape, mesh in cells:
        path = os.path.join(args.out, f"{arch}_{shape}_{mesh}.json")
        if os.path.exists(path):
            done += 1
            continue
        t0 = time.time()
        cmd = [sys.executable, "-m", "repro.launch.dryrun",
               "--arch", arch, "--shape", shape, "--mesh", mesh,
               "--out", args.out]
        env = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"))
        print(f"[driver] {arch} {shape} {mesh} ...", flush=True)
        try:
            r = subprocess.run(cmd, env=env, capture_output=True, text=True,
                               timeout=args.timeout, cwd=ROOT)
        except subprocess.TimeoutExpired:
            print(f"[driver] TIMEOUT {arch} {shape} {mesh}", flush=True)
            log.write(f"TIMEOUT {arch} {shape} {mesh}\n")
            log.flush()
            failed += 1
            continue
        dt = time.time() - t0
        if r.returncode == 0:
            done += 1
            tail = r.stdout.strip().splitlines()[-1] if r.stdout else ""
            print(f"[driver] ok ({dt:.0f}s): {tail}", flush=True)
            log.write(f"OK {arch} {shape} {mesh} {dt:.0f}s\n")
        else:
            failed += 1
            print(f"[driver] FAIL ({dt:.0f}s) {arch} {shape} {mesh}",
                  flush=True)
            log.write(f"FAIL {arch} {shape} {mesh}\n"
                      + r.stderr[-3000:] + "\n")
        log.flush()
    print(f"[driver] complete: {done} ok, {failed} failed / {len(cells)}")


if __name__ == "__main__":
    main()
