#!/usr/bin/env python3
"""Generate EXPERIMENTS.md from dry-run artifacts + paper-table bench.

  PYTHONPATH=src python scripts/make_experiments_md.py
"""
import glob
import io
import json
import os
import sys
from contextlib import redirect_stdout

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(ROOT, "src"))
sys.path.insert(0, ROOT)

DRY = os.path.join(ROOT, "experiments", "dryrun")
V0 = os.path.join(ROOT, "experiments", "dryrun_v0")


def load(d):
    out = {}
    for p in sorted(glob.glob(os.path.join(d, "*.json"))):
        c = json.load(open(p))
        tag = os.path.basename(p)[:-5]
        out[tag] = c
    return out


def fmt(x):
    return f"{x:.3e}"


MOVE_SENTENCE = {
    "compute": ("higher arithmetic intensity per step (larger per-device "
                "batch or fewer redundant FLOPs) moves it down"),
    "memory": ("less HBM traffic: tighter remat policy, fused/banded "
               "attention, int8 weights/caches"),
    "collective": ("a sharding that keeps the hot contraction local "
                   "(see §Perf) or compressed/overlapped collectives"),
}

CELL_NOTES = {
    ("dbrx-132b", "train_4k"): "EP combine + EC dispatch traffic; §Perf cell 1",
    ("llama4-scout-17b-a16e", "train_4k"): "same MoE structure as dbrx; "
    "fixed by the same local-dispatch + RS-combine knobs",
    ("gemma3-1b", "prefill_32k"): "kv=1: QK psum storm; §Perf cell 2",
    ("paligemma-3b", "prefill_32k"): "kv=1, same pathology as gemma3",
    ("qwen3-32b", "decode_32k"): "KV-cache bound; §Perf cell 3 (int8 KV)",
    ("mamba2-370m", "long_500k"): "O(1) state decode: trivially cheap, "
    "B=1 underutilizes the pod",
    ("zamba2-1.2b", "long_500k"): "shared-attn 500k caches sharded over "
    "(data: seq) x (model: kv)",
}


def roofline_table(cells):
    hdr = ("| arch | shape | compute s | memory s | collective s | "
           "dominant | useful (6ND/HLO) | bottleneck note |")
    sep = "|" + "---|" * 8
    lines = [hdr, sep]
    for key in sorted(cells):
        c = cells[key]
        if c["mesh"] != "pod1" or c.get("overrides"):
            continue
        r = c["roofline"]
        note = CELL_NOTES.get((c["arch"], c["shape"]),
                              MOVE_SENTENCE[r["dominant"]])
        lines.append(
            f"| {c['arch']} | {c['shape']} | {fmt(r['compute_s'])} | "
            f"{fmt(r['memory_s'])} | {fmt(r['collective_s'])} | "
            f"{r['dominant']} | {min(c['useful_flops_ratio'], 99):.2f} | "
            f"{note} |")
    return "\n".join(lines)


def dryrun_summary(cells):
    pod1 = [c for c in cells.values()
            if c["mesh"] == "pod1" and not c.get("overrides")]
    pod2 = [c for c in cells.values()
            if c["mesh"] == "pod2" and not c.get("overrides")]
    lines = []
    lines.append(f"* single-pod (16x16 = 256 chips): **{len(pod1)}/32 cells "
                 f"lower+compile OK**; compile time "
                 f"{min(c['compile_s'] for c in pod1):.0f}-"
                 f"{max(c['compile_s'] for c in pod1):.0f}s per cell "
                 f"(1 CPU core).")
    lines.append(f"* multi-pod (2x16x16 = 512 chips): **{len(pod2)}/32 cells "
                 f"lower+compile OK** — the `pod` axis shards (data "
                 f"parallelism + gradient reduction only; no TP collective "
                 f"crosses pods).")
    biggest = max(pod1, key=lambda c: c["params"])
    lines.append(f"* largest program: {biggest['arch']} "
                 f"({biggest['params']/1e9:.0f}B params) train_4k — "
                 f"params+optimizer "
                 f"{biggest['memory_analysis'].get('argument_size_in_bytes', 0)/2**30:.1f} "
                 f"GiB/device (memory_analysis), fits 16 GiB HBM with bf16 "
                 f"params + f32 moments sharded (model x fsdp).")
    return "\n".join(lines)


def perf_cell(cells, arch, shape, steps):
    """steps: list of (label, tag_or_None, hypothesis, verdict)."""
    out = [f"#### {arch} / {shape}\n"]
    out.append("| iteration | compute s | memory s | collective s | "
               "bound s | dominant |")
    out.append("|---|---|---|---|---|---|")
    v0 = load(V0) if os.path.isdir(V0) else {}
    for label, tag, _, _ in steps:
        if tag == "V0":
            key = f"{arch}_{shape}_pod1"
            src = v0.get(key)
        else:
            key = f"{arch}_{shape}_pod1" + (f"_{tag}" if tag else "")
            src = cells.get(key) or v0.get(key)
        if src is None:
            out.append(f"| {label} | - | - | - | - | (artifact missing) |")
            continue
        r = src["roofline"]
        out.append(f"| {label} | {fmt(r['compute_s'])} | "
                   f"{fmt(r['memory_s'])} | {fmt(r['collective_s'])} | "
                   f"{fmt(r['step_lower_bound_s'])} | {r['dominant']} |")
    out.append("")
    for label, _, hyp, verdict in steps:
        if hyp:
            out.append(f"* **{label}** — {hyp} **{verdict}**")
    return "\n".join(out)


def paper_tables_output():
    buf = io.StringIO()
    with redirect_stdout(buf):
        from benchmarks import paper_tables
        for fn in paper_tables.ALL:
            fn()
    return buf.getvalue()


def main():
    cells = load(DRY)
    pt = paper_tables_output()
    body = TEMPLATE.format(
        dryrun=dryrun_summary(cells),
        roofline=roofline_table(cells),
        perf_dbrx=perf_cell(cells, "dbrx-132b", "train_4k", DBRX_STEPS),
        perf_gemma=perf_cell(cells, "gemma3-1b", "prefill_32k", GEMMA_STEPS),
        perf_qwen=perf_cell(cells, "qwen3-32b", "decode_32k", QWEN_STEPS),
        paper_tables=pt.strip(),
    )
    with open(os.path.join(ROOT, "EXPERIMENTS.md"), "w") as f:
        f.write(body)
    print("wrote EXPERIMENTS.md")


DBRX_STEPS = [
    ("baseline (global EC)", None,
     "Global expert-choice gathers/scatters address the full 1M-token "
     "range; GSPMD can only partition them by all-gathering the (T, D) "
     "activations — predicted O(40 layers x 12.9 GB) of all-gather plus "
     "mirrored backward traffic.",
     "Confirmed: 9.7e12 link B/dev, 195 s collective term."),
    ("it1: shard-local EC dispatch", "moelocal",
     "Routing within each data shard makes gather/scatter batched "
     "(parallel over the shard axis, no movement); predicted the 3.0e12 "
     "all-gather component largely disappears.",
     "Confirmed: all-gather 3.0e12→1.07e12, collective 195→118 s. "
     "Remaining: EP-combine all-reduce of the (T, D) output, which JAX's "
     "bf16 scatter-add promotes to f32 (24.5 GB/layer)."),
    ("it2: reduce-scatter combine", "moelocal2",
     "Constraining the combine output D-sharded turns the f32 all-reduce "
     "into reduce-scatter + bf16 all-gather; napkin: ~25% less link "
     "traffic.",
     "Exceeded: backward mirrors restructure too; collective 118→44 s "
     "(4.4x total). Now memory-dominant; next lever is remat policy "
     "(recorded, not taken: projected <2x)."),
]

GEMMA_STEPS = [
    ("baseline (hd-sharded q/k)", None,
     "kv=1 leaves no head to shard; the hd fallback makes every QK block "
     "a psum: predicted ~26 layers x 64x64 chunk pairs x 8 MB ~ 1.7 TB "
     "of all-reduce.",
     "Confirmed: 106,575 all-reduce executions, 1.71e12 link B/dev."),
    ("it1: replicate q (constraint on q only)", "replq",
     "Replicating q should kill the contraction psum.",
     "REFUTED: identical 106k ARs — k/v inherit wk's column sharding, "
     "and the dot re-shards. Debugging forward, not reverting."),
    ("it2: replicate q,k,v + pin attention output", "replq3",
     "GSPMD *back-propagates* wo's row sharding and the hd-sharded "
     "prefill-cache layout into the flash loop (found by call-graph "
     "attribution of the ARs); pinning o and re-pinning k after rope "
     "should finally localize the loop.",
     "Half-confirmed: collective 34.2→0.5 s, but replication costs 16x "
     "attention compute/HBM — memory term 2.1→15.4 s. Net 2.2x."),
    ("it3: context-parallel attention (shard_map)", "seqcp",
     "Shard q over *sequence* on the model axis; k/v replicated; local "
     "layers slice only (S/n + window) keys. Predicted ~16x less "
     "attention compute/HBM than it2 with ~0 loop collectives.",
     "Confirmed: 34.2 → 0.80 s step bound (43x vs baseline); "
     "useful-FLOPs ratio 0.41→0.65."),
]

QWEN_STEPS = [
    ("baseline v0 (kv-head cache sharding)", "V0",
     "GQA kv=8 < model=16 leaves the 1.1 TB cache only data-sharded: "
     "68 GB/device cannot fit.",
     "Confirmed by memory_analysis; fixed as a sharding-rule completion "
     "(head_dim fallback), kept as the reported baseline."),
    ("it1: hd-sharded cache (fit fix)", None,
     "Cache (B->data, hd->model): 4.3 GB/device.",
     "Confirmed: args 64.2→4.2 GB/device; bound 2.82→1.52 s "
     "(collective-dominant: cache-update resharding all-gathers)."),
    ("it2: naive int8 KV cache (dequant then attend)", "int8kv",
     "Halving cache bytes should halve the memory term.",
     "REFUTED: memory 0.37→0.47 s — the explicit dequant materializes a "
     "full bf16 cache copy; HLO bytes go UP. Kept the int8 storage, "
     "fixed the compute instead."),
    ("it3: integer-domain attention (MCIM-style)", "int8kv2",
     "int8 QK^T and P·V with deferred scales (PPM -> int32 compressor -> "
     "final-adder scaling): all large reads stay int8, no bf16 copy.",
     "Confirmed: bound 1.52→0.55 s (2.7x); now memory-dominant at "
     "0.55 s with int8 cache + bf16 weights.  Next candidate (int8 "
     "weights) napkin-maths to ~4% of the memory term (weights are "
     "0.25 GB/dev vs 2.7 GB of cache+scales) — below the 5% stopping "
     "rule, so recorded and not taken."),
]

TEMPLATE = """# EXPERIMENTS

All numbers are generated from committed artifacts
(`experiments/dryrun*/*.json`) by `scripts/make_experiments_md.py`;
re-run it after adding cells.  Hardware model: TPU v5e — 197 TFLOP/s
bf16, 819 GB/s HBM, ~50 GB/s/link ICI per chip.

## §Paper-tables — reproduction of the paper's own claims

The area/timing models are calibrated on Star data points ONLY (one
area + stress/path anchors); every MCIM row below is a prediction.
`delta` = our savings minus the paper's.  Functional correctness
(the paper's VCS simulations) is covered bit-exactly by
`tests/test_core_mcim.py` / `test_kernels.py` across widths 8-512,
CT 2-8, all architectures, signed and unsigned.

```
{paper_tables}
```

Reading: relaxed-timing savings (Tables II, III, VII) reproduce within
1-7 pp across the CT sweep (40-72% at CT 2-8); strict-timing structure
reproduces (FB misses 0.31 ns, FF/Karatsuba savings within 1-4 pp at
128 b); the planner agrees with the paper's Table VIII design choices
on all six rows; Table IX's 65%-vs-array claim lands at 69%.  Honest
misses: FF at small widths is underpredicted by up to 16 pp (our
register/adder model overweights its fixed full-width final adder at
16 b — a refuted modeling hypothesis, documented rather than tuned
away), and FPGA LUT mapping (Table X) is only order-of-magnitude
(0.5-0.8x) since LUT packing is not modeled.

## §Dry-run

{dryrun}

Skipped cells (documented in DESIGN.md §Arch-applicability): encoder
has no decode step (hubert x decode/long); pure full-attention archs
skip `long_500k` (qwen3, minitron, gemma2, dbrx, llama4, paligemma).
gemma3 (5:1 local, kv=1), mamba2, and zamba2 RUN `long_500k`.

Memory accounting: `memory_analysis()` on this backend is per-device;
the table's `argument_size` covers non-donated inputs (params for
decode; batch for train since params/optimizer are donated).

## §Roofline (single-pod baseline, per assignment)

Terms are seconds per step per chip, from the compiled artifact:
scan-aware dot/conv FLOPs and ring-cost collective bytes come from the
HLO call graph with `known_trip_count` multipliers
(`launch/hlo_cost.py`; XLA's own `cost_analysis()` counts loop bodies
once and is reported in the artifacts as `raw_cost_analysis`).  The
memory term scales XLA's bytes-accessed by the same loop factor — an
estimator, biased high (fusion savings inside loop bodies are not
observable from the artifact), so treat memory terms as upper bounds.
`useful` = MODEL_FLOPS (6·N_active·D train / 2·N_active·D inference)
per device divided by HLO dot FLOPs; >1 means the analytic model counts
more than the compiled program (attention-light cells), <1 means the
program does work 2ND doesn't count (S² attention at 32k dominates the
prefill cells — e.g. llama4's 0.06 is real attention, not waste — plus
masked-out blocks, remat recompute, EC dispatch).  Note: prefill cells
carry the *decode-compatible* hd-sharded cache layout, whose sharding
back-propagates into the QK contraction for GQA archs; the
`attn_fallback=replicate` rows in §Perf remove exactly that cost
fleet-wide (3.3-5x).

{roofline}

## §Perf — hypothesis → change → measure → validate

Protocol: baseline EVERY cell above, hillclimb the three most
interesting pairs.  Chosen: **dbrx-132b/train_4k** (most
collective-bound), **gemma3-1b/prefill_32k** (worst compute fraction +
most collective-heavy prefill), **qwen3-32b/decode_32k** (most
representative of the paper's technique: integer arithmetic on the
serving path).  The paper-faithful implementation is the baseline; all
optimizations are config knobs (`--override`), so both artifacts
coexist.  Stopping rule: three consecutive <5% changes — never reached;
each cell ended on a confirmed multi-x iteration with the next lever
quantified.

{perf_dbrx}

{perf_gemma}

{perf_qwen}

### Beyond-paper results applied to the rest of the fleet

| cell | knob | bound before → after | verdict |
|---|---|---|---|
| llama4-scout/train_4k | moe_local_dispatch (+RS combine) | 2.19e+02 → 4.63e+01 s | confirmed, 4.7x (same pathology as dbrx) |
| paligemma-3b/prefill_32k | attn_fallback=seq | 4.73e+01 → 1.48e+00 s | confirmed, 32x (same kv=1 pathology as gemma3) |
| gemma3-1b/train_4k | attn_fallback=seq | 1.08e+01 → 3.79e+00 s | confirmed, 2.8x |
| qwen3-32b/prefill_32k | attn_schedule=banded | 3.18e+01 → 2.77e+01 s | partially confirmed: memory 3x better, but the banded accumulator scatter adds model-axis resharding (collective 10→28 s) |
| gemma2-9b/prefill_32k | attn_schedule=banded | 1.06e+01 → 1.72e+01 s | REFUTED net: same scatter pathology dominates; a Pallas splash kernel would capture the win without the scatter (recorded as future kernel work) |
| qwen3-32b/prefill_32k | attn_fallback=replicate (KV replicated within TP group, q stays head-sharded) | 4.80e+01 → 1.47e+01 s | confirmed, 3.3x — the hd-sharded decode-cache layout back-propagates into prefill QK scores for every GQA arch; replicating the small KV heads removes the psum-per-block |
| minitron-8b/prefill_32k | attn_fallback=replicate | 2.34e+01 → 5.68e+00 s | confirmed, 4.1x |
| gemma2-9b/prefill_32k | attn_fallback=replicate | 3.20e+01 → 6.45e+00 s | confirmed, 5.0x |
| llama4-scout/prefill_32k | attn_fallback=replicate | 6.86e+02 → 1.59e+02 s | confirmed, 4.3x |
| llama4-scout/prefill_32k | replicate + moe_local_dispatch | 6.86e+02 → 7.80e+01 s | confirmed, 8.8x — the knobs compose |
| qwen3-32b/train_4k | attn_fallback=replicate | 4.55e+01 → 4.64e+01 s | REFUTED for training: train is memory-bound and its collectives are gradient traffic, not QK psums |

### Perf summary

| cell | paper-faithful baseline bound | optimized bound | gain |
|---|---|---|---|
| dbrx-132b train_4k | 1.95e+02 s | 4.45e+01 s | 4.4x |
| gemma3-1b prefill_32k | 3.42e+01 s | 7.98e-01 s | 43x |
| qwen3-32b decode_32k | 1.52e+00 s (post fit-fix) | 5.53e-01 s | 2.7x |
| llama4-scout train_4k | 2.19e+02 s | 4.63e+01 s | 4.7x |
| paligemma-3b prefill_32k | 4.73e+01 s | 1.48e+00 s | 32x |
| llama4-scout prefill_32k | 6.86e+02 s | 7.80e+01 s | 8.8x |
| gemma2-9b prefill_32k | 3.20e+01 s | 6.45e+00 s | 5.0x |
| minitron-8b prefill_32k | 2.34e+01 s | 5.68e+00 s | 4.1x |
| qwen3-32b prefill_32k | 4.80e+01 s | 1.47e+01 s | 3.3x |

Roofline fractions (compute term / step bound) for the optimized cells:
dbrx train 14%, gemma3 prefill 8%, qwen3 decode 0.06% (decode at
batch 128 is intrinsically bandwidth-bound: its roofline *is* the
memory term, which the int8 cache halved), qwen3 train 13% baseline
(memory-estimator-bound; the estimator's upper-bias is the caveat
above).
"""

if __name__ == "__main__":
    main()
