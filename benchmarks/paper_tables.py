"""One benchmark per paper table (Houraniah et al. 2023, Tables II-X).

Every row prints ``name,us_per_call,derived`` CSV.  For area tables the
derived column carries the modeled area + savings and, where the paper
reports a number, the paper's value and the delta -- that comparison IS
the reproduction check.  Areas come from core.area_model (calibrated
only on Star data points); strict-timing rows additionally use
core.timing_model (calibrated only on Star stress anchors).
"""
from __future__ import annotations

import time
from fractions import Fraction

from repro import designs
from repro.core import area_model as am
from repro.core import power_model as pm
from repro.core import timing_model as tm
from repro.core.mcim import MCIMConfig
from repro.core import planner


def _row(name, derived, us=0.0):
    print(f"{name},{us:.2f},{derived}")


def _area(bits, cfg, t_target=None):
    a = am.area_um2(bits, bits, cfg)
    if t_target is not None:
        a *= tm.stress(cfg.arch, bits, t_target)
    return a


def _star(bits, t_target=None):
    return _area(bits, MCIMConfig(arch="star", ct=1), t_target)


def _emit(table, label, bits, cfg, paper_savings=None, t_target=None,
          paper_area=None):
    t0 = time.perf_counter()
    if t_target is not None and not tm.meets_timing(cfg.arch, bits,
                                                    t_target, cfg.adder):
        _row(f"{table}.{label}", "MISSES_TIMING(reproduces paper)")
        return
    ours = _area(bits, cfg, t_target)
    star = _star(bits, t_target)
    sav = 1 - ours / star
    us = (time.perf_counter() - t0) * 1e6
    d = f"area={ours:.0f}um2 savings={sav:.0%}"
    if paper_savings is not None:
        d += f" paper={paper_savings:.0%} delta={sav - paper_savings:+.0%}"
    if paper_area is not None:
        d += f" paper_area={paper_area}"
    _row(f"{table}.{label}", d, us)


def table2_16x16_relaxed():
    """Table II: 16x16 relaxed (10ns). Paper: FB2 ~30%, FB3 ~45%."""
    _emit("table2", "star16", 16, MCIMConfig(arch="star", ct=1),
          paper_savings=0.0, paper_area=1348)
    _emit("table2", "fb_ct2", 16, MCIMConfig(arch="fb", ct=2),
          paper_savings=1 - 942 / 1348)
    _emit("table2", "fb_ct3", 16, MCIMConfig(arch="fb", ct=3),
          paper_savings=1 - 748 / 1348)
    _emit("table2", "ff_ct2", 16, MCIMConfig(arch="ff", ct=2),
          paper_savings=1 - 1051 / 1348)


def table3_128x128_relaxed():
    """Table III: 128x128 relaxed. Paper: Karat-2 3CA best (58%)."""
    _emit("table3", "star128", 128, MCIMConfig(arch="star", ct=1),
          paper_savings=0.0, paper_area=66319)
    _emit("table3", "ff_ct2", 128, MCIMConfig(arch="ff", ct=2),
          paper_savings=1 - 37042 / 66319)
    _emit("table3", "fb_ct2", 128, MCIMConfig(arch="fb", ct=2),
          paper_savings=1 - 42913 / 66319)
    _emit("table3", "fb_ct3", 128, MCIMConfig(arch="fb", ct=3),
          paper_savings=1 - 30217 / 66319)
    for k, paper in [(1, 27929), (2, 27463), (3, 29657)]:
        _emit("table3", f"karat{k}_3ca", 128,
              MCIMConfig(arch="karatsuba", ct=3, levels=k, adder="3ca"),
              paper_savings=1 - paper / 66319)


def table4_16x16_strict():
    """Table IV: 16x16 @ 0.31ns. Paper: FF best 23%; FB misses timing."""
    t = 0.31
    _emit("table4", "star16_strict", 16, MCIMConfig(arch="star", ct=1),
          paper_savings=0.0, t_target=t, paper_area=5178)
    _emit("table4", "ff_ct2_strict", 16, MCIMConfig(arch="ff", ct=2),
          paper_savings=1 - 3963 / 5178, t_target=t)
    _emit("table4", "fb_ct2_strict", 16, MCIMConfig(arch="fb", ct=2),
          t_target=t)      # paper: cannot meet 0.31ns -> MISSES_TIMING


def table5_max_freq():
    """Table V: max frequency of non-pipelineable 128x128 designs."""
    for label, cls, paper_ns in [("fb_ct2", "fb", 0.80),
                                 ("karat1_1ca", "karatsuba", 0.54)]:
        ours = tm.t_comb(cls, 128)
        _row(f"table5.{label}",
             f"t_comb={ours:.2f}ns paper={paper_ns}ns "
             f"delta={ours - paper_ns:+.2f}ns")


def table6_128x128_strict():
    """Table VI: 128x128 @ 0.8ns. Paper: Karat-1 63%, FF 47%."""
    t = 0.8
    _emit("table6", "star128_strict", 128, MCIMConfig(arch="star", ct=1),
          paper_savings=0.0, t_target=t, paper_area=121634)
    _emit("table6", "ff_ct2_strict", 128, MCIMConfig(arch="ff", ct=2),
          paper_savings=1 - 64778 / 121634, t_target=t)
    _emit("table6", "fb_ct3_strict", 128, MCIMConfig(arch="fb", ct=3),
          paper_savings=1 - 48068 / 121634, t_target=t)
    _emit("table6", "karat1_strict", 128,
          MCIMConfig(arch="karatsuba", ct=3, levels=1),
          paper_savings=1 - 44888 / 121634, t_target=t)


def table7_ct_sweep():
    """Table VII: 32x32 FB, CT 2..8. Paper savings 40..72%."""
    paper = {2: 0.40, 3: 0.50, 4: 0.57, 5: 0.60, 6: 0.64, 7: 0.68, 8: 0.72}
    for ct, ps in paper.items():
        _emit("table7", f"fb_ct{ct}", 32, MCIMConfig(arch="fb", ct=ct),
              paper_savings=ps)


def table8_best_designs():
    """Table VIII: best design per width/timing via the designs facade
    (generate() applies the timing filter); planner must agree with the
    paper's pick."""
    rows = [
        (8, 0.57, False, "fb", 0.19),
        (16, 0.31, True, "ff", 0.23),
        (16, 1.00, False, "fb", 0.42),
        (32, 0.31, True, "ff", 0.23),
        (32, 1.29, False, "fb", 0.32),
        (128, 0.80, True, "karatsuba", 0.63),
    ]
    for bits, tgt, strict, paper_arch, paper_sav in rows:
        ct = 3 if paper_arch == "karatsuba" else 2
        spec = designs.DesignSpec(bits, bits, Fraction(1, ct),
                                  clock_ns=tgt if strict else None,
                                  strict_timing=strict, backend="core")
        design = designs.generate(spec)
        (_, pick), = design.plan.configs
        ours = design.area
        star = _star(bits, tgt if strict else None)
        sav = 1 - ours / star
        agree = pick.arch == paper_arch
        _row(f"table8.{bits}b_{tgt}ns",
             f"planner={pick.arch}(ct={pick.ct}) paper={paper_arch} "
             f"agree={agree} savings={sav:.0%} paper_savings={paper_sav:.0%}")


def table9_128x64_vs_array():
    """Table IX: FB CT2 vs [16]'s array designs. Paper: FB 65% vs array."""
    fb = am.area_um2(128, 64, MCIMConfig(arch="fb", ct=2))
    star = am.area_um2(128, 64, MCIMConfig(arch="star", ct=1))
    arr = am.array_area_um2(128, 64)
    _row("table9.fb_vs_array",
         f"fb={fb:.0f} array={arr:.0f} savings={1 - fb / arr:.0%} "
         f"paper=65% (paper fb=21886 array=63387)")
    _row("table9.fb_vs_star",
         f"fb={fb:.0f} star={star:.0f} savings={1 - fb / star:.0%} "
         f"paper=36% (21886 vs 34317)")


def table10_fpga_luts():
    """Table X: 119x119 FPGA LUTs. Map area-model cells -> LUTs using the
    paper's own Star(no-DSP)=14819 LUTs as the single calibration."""
    star_cells = am.star_area(119, 119).total
    lut_per_cell = 14819.0 / star_cells
    for label, cfg, paper_luts in [
            ("karat1", MCIMConfig(arch="karatsuba", ct=3, levels=1), 8017),
            ("ff_ct2", MCIMConfig(arch="ff", ct=2), 14572)]:
        ours = am.mcim_area(119, 119, cfg).total * lut_per_cell
        _row(f"table10.{label}",
             f"luts={ours:.0f} paper={paper_luts} "
             f"ratio={ours / paper_luts:.2f}")


def table_energy():
    """Energy/peak-power sweep (paper Sec. V headline direction): TP=1/2
    folded designs must show double-digit energy-per-op savings (paper:
    up to 33%) and a large peak-power reduction (paper: 65% average)
    vs the Star design at every Table-VIII width."""
    peaks = []
    for bits in (8, 16, 32, 64, 128):
        fb2 = MCIMConfig(arch="fb", ct=2)
        e_sav = pm.energy_savings_vs_star(bits, bits, fb2)
        p_red = pm.peak_power_reduction_vs_star(bits, bits, fb2)
        peaks.append(p_red)
        e = pm.energy_per_op_pj(bits, bits, fb2)
        _row(f"table_energy.fb2_{bits}b",
             f"E={e:.2f}pJ/op energy_savings={e_sav:.0%} "
             f"peak_reduction={p_red:.0%} paper=up-to-33%/65%avg")
    _row("table_energy.avg_peak_reduction",
         f"avg={sum(peaks) / len(peaks):.0%} paper=65%")
    # CT sweep at 32b: energy must fall monotonically with CT
    es = [pm.energy_per_op_pj(32, 32, MCIMConfig(arch="fb", ct=ct))
          for ct in (2, 3, 4, 6, 8)]
    mono = all(a > b for a, b in zip(es, es[1:]))
    _row("table_energy.fb_ct_sweep_32b",
         "E[pJ/op]=" + "/".join(f"{e:.2f}" for e in es)
         + f" monotone_decreasing={mono}")


def use_case_fractional_tp():
    """Sec. V-E use case 1: TP=3.5 bank vs 4x Star (the paper's headline
    deployment story), via the registered design point."""
    design = designs.generate("tp3p5_w32")
    conv = planner.star_bank_area(32, 32, 3.5)
    _row("usecase.tp3_5",
         f"plan=[{design.plan.describe()}] conventional={conv:.0f}um2 "
         f"savings={1 - design.area / conv:.0%}")


ALL = [table2_16x16_relaxed, table3_128x128_relaxed, table4_16x16_strict,
       table5_max_freq, table6_128x128_strict, table7_ct_sweep,
       table8_best_designs, table9_128x64_vs_array, table10_fpga_luts,
       table_energy, use_case_fractional_tp]
