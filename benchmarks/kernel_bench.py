"""Kernel/system microbenchmarks: wall time (CPU, indicative only) +
derived structural metrics (exact on any backend: op counts, footprints).

Run as a script: ``python benchmarks/kernel_bench.py`` (full sweep) or
``--smoke`` for the CI subset (the fused-megakernel launch comparison
plus the structural tables -- the benches that gate on correctness, not
on CPU wall clock).
"""
from __future__ import annotations

import time
from fractions import Fraction

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import limbs as L
from repro.core.schoolbook import star_mul, feedback_mul
from repro.core.karatsuba import karatsuba_mul
from repro.kernels.mcim_fold import vmem_bytes_per_step, mcim_fold_mul
from repro.kernels.int8_matmul import int8_matmul_ref, quantized_matmul
from repro.rng import random_uniform
from repro.exact import exact_sum

RNG = np.random.default_rng(11)


def _time(fn, *args, reps=20):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6


def _row(name, us, derived):
    print(f"{name},{us:.2f},{derived}")


def bench_core_mul():
    """Batched 128-bit multiplies: star vs folded (jnp, jitted)."""
    a = jnp.asarray(L.random_limbs(RNG, (4096,), 128))
    b = jnp.asarray(L.random_limbs(RNG, (4096,), 128))
    star = jax.jit(star_mul)
    us = _time(star, a, b)
    _row("core.star_128x128_b4096", us, "baseline")
    for ct in (2, 3, 4, 8):
        fb = jax.jit(lambda x, y, c=ct: feedback_mul(x, y, ct=c))
        us = _time(fb, a, b)
        ops = 8 * (-(-8 // ct))     # limb-products instantiated per cycle
        _row(f"core.fb_ct{ct}_128x128_b4096", us,
             f"ppm_ops_per_cycle={ops}/64")
    kara = jax.jit(lambda x, y: karatsuba_mul(x, y, levels=2))
    us = _time(kara, a, b)
    _row("core.karat2_128x128_b4096", us, "subquadratic_ppm")


def bench_vmem_fold():
    """The TPU 'area' table: per-step VMEM working set vs CT."""
    base = vmem_bytes_per_step(8, 8, 1, 256)
    for ct in (1, 2, 3, 4, 8):
        v = vmem_bytes_per_step(8, 8, ct, 256)
        _row(f"kernel.vmem_fold_ct{ct}", 0.0,
             f"vmem_bytes={v} saving={1 - v / base:.0%}")


def bench_mcim_kernel_interpret():
    """Pallas interpret-mode sanity timing (not TPU-representative)."""
    a = jnp.asarray(L.random_limbs(RNG, (256,), 64))
    b = jnp.asarray(L.random_limbs(RNG, (256,), 64))
    us = _time(lambda x, y: mcim_fold_mul(x, y, ct=2, tile_b=256,
                                          interpret=True), a, b, reps=3)
    _row("kernel.mcim_fold_interp_64b_b256", us, "interpret_mode")


def bench_int8_matmul():
    x = jnp.asarray(RNG.standard_normal((256, 512)), jnp.float32)
    w = jnp.asarray(RNG.standard_normal((512, 256)), jnp.float32)
    us_ref = _time(jax.jit(lambda a, b: a @ b), x, w)
    _row("kernel.f32_matmul_256x512x256", us_ref, "baseline")
    us_q = _time(lambda a, b: quantized_matmul(a, b, use_kernel=False),
                 x, w)
    _row("kernel.int8_matmul_256x512x256", us_q,
         f"weight_bytes=0.25x activation_bytes=0.25x")


def bench_rng_exact():
    offs = jnp.arange(1 << 16, dtype=jnp.uint32)
    us = _time(jax.jit(lambda o: random_uniform(3, 1, o)), offs)
    _row("rng.philox_64k", us, f"{(1 << 16) / us:.0f} samples/us")
    x = jnp.asarray(RNG.standard_normal(1 << 16), jnp.float32)
    us_f = _time(jax.jit(jnp.sum), x)
    us_e = _time(jax.jit(exact_sum), x)
    _row("exact.sum_64k", us_e,
         f"overhead_vs_f32sum={us_e / max(us_f, 1e-9):.1f}x bit_exact=True")


def bench_bank_fold():
    """Fused bank megakernel vs per-instance launches (TP=3.5 bank).

    The dispatch-tax comparison of the bank_fold work: the same plan,
    batch and operands through ``backend="kernel"`` (one Pallas launch
    per busy instance) and ``backend="fused"`` (one launch for the
    whole round).  Launch counts come from the traced jaxpr, so they
    are exact on any backend; the wall clocks are interpret-mode CPU
    figures, indicative only.
    """
    from repro.core import planner
    from repro.core.bank import Bank
    bits, batch = 16, 14
    plan = planner.plan_throughput(bits, bits, Fraction(7, 2))
    a = jnp.asarray(L.random_limbs(RNG, (batch,), bits))
    b = jnp.asarray(L.random_limbs(RNG, (batch,), bits))
    expect = [L.from_limbs(np.asarray(x)) * L.from_limbs(np.asarray(y))
              for x, y in zip(a, b)]
    times, launches = {}, {}
    for backend in ("kernel", "fused"):
        bk = Bank(plan, bits, bits, backend=backend)
        out = bk.execute(a, b)        # warmup: pays trace + compile
        assert L.batch_from_limbs(np.asarray(out)) == expect, backend
        times[backend] = _time(bk.execute, a, b, reps=5)
        launches[backend] = bk.launch_count(batch)
        _row(f"kernel.bank_{backend}_16b_tp7_2_b14", times[backend],
             f"launches_per_round={launches[backend]}")
    assert launches["fused"] == 1, \
        f"fused bank round traced {launches['fused']} launches, not 1"
    _row("kernel.bank_fold_speedup", 0.0,
         f"fused_vs_per_instance="
         f"{times['kernel'] / times['fused']:.2f}x "
         f"launches={launches['kernel']}->{launches['fused']}")


ALL = [bench_core_mul, bench_vmem_fold, bench_mcim_kernel_interpret,
       bench_bank_fold, bench_int8_matmul, bench_rng_exact]

#: CI subset: structural metrics + the fused launch-count gate; skips
#: the pure wall-clock benches whose CPU numbers gate nothing
SMOKE = [bench_vmem_fold, bench_bank_fold]


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser(
        description="kernel/system microbenchmarks")
    ap.add_argument("--smoke", action="store_true",
                    help="CI subset (fused launch gate + structural "
                         "tables)")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    for fn in (SMOKE if args.smoke else ALL):
        fn()
