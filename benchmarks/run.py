"""Benchmark harness: one function per paper table + system benches.

Prints ``name,us_per_call,derived`` CSV.  Sections:
  paper_tables    -- Tables II..X area/timing reproductions (area model)
  kernel_bench    -- core/kernel/system microbenchmarks
  bank_bench      -- planner design points executed via core.bank
  roofline_report -- dry-run roofline summary (reads experiments/dryrun)
"""
import sys


def main() -> None:
    print("name,us_per_call,derived")
    from . import paper_tables, kernel_bench, bank_bench, roofline_report
    for section in (paper_tables, kernel_bench, bank_bench,
                    roofline_report):
        for fn in section.ALL:
            try:
                fn()
            except Exception as e:      # a bench failure must not hide others
                print(f"{section.__name__}.{fn.__name__},0.00,ERROR:{e!r}",
                      file=sys.stdout)


if __name__ == '__main__':
    main()
