"""Bank execution benchmark: planner design points run for real.

For each (bits, TP) design point from the paper's fractional-throughput
use cases (Sec. V-B / V-E, Table VIII widths), build the planner's bank,
execute a batch through ``core.bank``, and record

  * measured throughput (ops/cycle from the dispatch schedule) vs the
    plan's claimed throughput,
  * per-scheduler makespans (round_robin / greedy / streaming) so the
    policy comparison is tracked per PR -- greedy's earliest-completion
    dispatch must never lose to round-robin,
  * bit-exactness of the executed batch vs the Python-int oracle, on
    BOTH the core path and the fused megakernel path,
  * wall clock per execution backend (core / per-instance kernel /
    fused megakernel): compile cost and steady-state separately, the
    traced Pallas launch count of one bank round, and the
    fused-vs-per-instance speedup (the dispatch-tax payoff),
  * the per-step VMEM working set (the TPU 'area') vs the
    round-up-to-integer Star bank,
  * the planner's ASIC-area estimate vs the conventional Star bank.

Every design is constructed through the ``repro.designs`` facade, and
each emitted row embeds its serialized ``DesignSpec`` so the BENCH
artifact carries full, recompilable provenance
(``DesignSpec.from_dict(row["design_spec"])`` -> the same design).

Emits ``BENCH_bank.json`` (repo root, override with --out) and the
harness CSV rows; the JSON's ``fields`` header documents every
wall-clock column.  ``--smoke`` runs a 6-point subset for CI and
additionally ASSERTS the fused contract: launch_count == 1 on every
point and steady-state speedup >= 1.0 on at least one multi-instance
point.
"""
from __future__ import annotations

import argparse
import json
import math
import os
import time
from fractions import Fraction

import numpy as np
import jax
import jax.numpy as jnp

from repro import designs
from repro.core import limbs as L
from repro.core import planner, bank
from repro.core.bank import Bank
from repro.kernels import runtime
from repro.kernels.mcim_fold import vmem_bytes_per_step
from repro.verify import dataflow

RNG = np.random.default_rng(17)

#: execution backends every design point is timed on
TIMED_BACKENDS = ("core", "kernel", "fused")

#: documentation of the wall-clock fields, embedded in the JSON header
FIELDS = {
    "wall_us_first_call":
        "wall time of the first execute() call (us): includes trace + "
        "compile + one run; kept raw so compile cost is reconstructible",
    "wall_us_steady":
        "median wall time of 5 post-warmup execute() calls (us): the "
        "per-batch execution cost",
    "wall_us_compile":
        "wall_us_first_call - wall_us_steady, clamped at 0 (us): the "
        "one-time trace/compile cost a serving process pays once",
    "launch_count":
        "Pallas launches one bank round issues, counted in the traced "
        "jaxpr: 0 on core (pure jnp), one per busy instance on kernel, "
        "exactly 1 on fused",
    "fused_speedup_vs_kernel":
        "kernel wall_us_steady / fused wall_us_steady: >1 means the "
        "fused megakernel beats the per-instance launch tax",
    "paths":
        "per-backend timing dict {core|kernel|fused: {wall_us_*, "
        "launch_count}}; top-level wall_us_* columns are the core path",
    "vmem_bytes_step":
        "static per-grid-step VMEM residency of the fused megakernel "
        "launch (bytes), measured from the traced kernel jaxpr by the "
        "dataflow analyzer -- the TPU analogue of the paper's folded "
        "silicon area, exact and execution-free",
    "arith_intensity":
        "static FLOPs / HBM-bytes of one fused bank launch, from the "
        "dataflow analyzer's jaxpr interpretation (FLOPs) and "
        "block-index transition counting (bytes); positions each "
        "design point on the roofline without running it",
}

# Paper use cases: pure fractional TPs (one folded instance), the
# headline TP=3.5 mixed bank, and the Sec. V-B CT combination 5/6.
DESIGN_POINTS = [
    (bits, tp)
    for bits in (16, 32, 64, 128)
    for tp in (Fraction(1, 2), Fraction(1, 3), Fraction(1, 4),
               Fraction(1, 6), Fraction(7, 2), Fraction(5, 6))
]

SMOKE_POINTS = [
    (bits, tp)
    for bits in (16, 32)
    for tp in (Fraction(1, 2), Fraction(7, 2), Fraction(5, 6))
]

def _row(name, us, derived):
    print(f"{name},{us:.2f},{derived}")


def _time_path(bk: Bank, a, b) -> dict:
    """Wall-clock one backend path: first call, steady median, compile.

    The old ``wall_us_first_call`` column conflated compile and run
    time; ``wall_us_compile`` is the split-out one-time cost (first
    minus steady, clamped at 0 for paths whose first call happens to
    race under the median).
    """
    t0 = time.perf_counter()
    out = bk.execute(a, b)
    jax.block_until_ready(out)
    first = (time.perf_counter() - t0) * 1e6
    steady = []
    for _ in range(5):
        t0 = time.perf_counter()
        jax.block_until_ready(bk.execute(a, b))
        steady.append((time.perf_counter() - t0) * 1e6)
    steady_us = float(np.median(steady))
    return {
        "wall_us_first_call": first,
        "wall_us_steady": steady_us,
        "wall_us_compile": max(first - steady_us, 0.0),
    }, out


def run_design_point(bits: int, tp: Fraction, batch_mult: int = 4) -> dict:
    spec = designs.DesignSpec(bits, bits, tp, backend="core")
    design = designs.generate(spec)
    plan, bk = design.plan, design.bank
    batch = batch_mult * max(tp.numerator, 1)

    a = jnp.asarray(L.random_limbs(RNG, (batch,), bits))
    b = jnp.asarray(L.random_limbs(RNG, (batch,), bits))

    expect = [L.from_limbs(np.asarray(x)) * L.from_limbs(np.asarray(y))
              for x, y in zip(a, b)]

    # every execution backend over the SAME plan/batch: core (pure
    # jnp), per-instance Pallas launches, and the fused megakernel
    paths = {}
    exact = fused_exact = False
    for name in TIMED_BACKENDS:
        pbk = bk if name == "core" else Bank(plan, bits, bits,
                                             backend=name)
        timing, out = _time_path(pbk, a, b)
        timing["launch_count"] = pbk.launch_count(batch)
        paths[name] = timing
        got = L.batch_from_limbs(np.asarray(out)) == expect
        if name == "core":
            exact = got
        elif name == "fused":
            fused_exact = got
    fused_speedup = (paths["kernel"]["wall_us_steady"] /
                     paths["fused"]["wall_us_steady"])

    rep = bk.last_report
    # scheduler policy comparison on the same (cts, batch) instance set;
    # streaming gets a real arrival trace (ceil(TP) ops/cycle, the rate
    # the bank is provisioned for) -- with all ops at cycle 0 it would
    # just reproduce round_robin
    cts = tuple(cfg.ct for cfg in bk.instances)
    rate = max(1, math.ceil(tp))
    streaming = bank.StreamingScheduler(arrival_rate=rate)
    makespans = {
        "round_robin": bank.round_robin_schedule(cts, batch)[1],
        "greedy": bank.greedy_schedule(cts, batch)[1],
        "streaming": streaming.schedule(cts, batch)[1],
    }
    # conventional bank: ceil(TP) Star instances
    n_star = max(1, math.ceil(tp))
    la = L.n_limbs_for_bits(bits)
    star_ws = n_star * vmem_bytes_per_step(la, la, 1, bk.tile_b)
    conv_area = planner.star_bank_area(bits, bits, tp)
    # static roofline of the fused launch (dataflow analyzer, cached)
    static = dataflow.plan_static_stats(bits, bits, plan.configs)
    return {
        "bits": bits,
        "tp": str(tp),
        "design_spec": spec.to_dict(),   # recompilable provenance
        "backend": design.bank.backend,
        "plan": plan.describe(),
        "latency_cycles": design.latency_cycles,
        "fmax_estimate_ghz": design.fmax_estimate,
        "instances": [
            {"arch": ir.config.arch, "ct": ir.ct, "n_ops": ir.n_ops,
             "busy_cycles": ir.busy_cycles}
            for ir in rep.instances],
        "batch": batch,
        "cycles": rep.cycles,
        "measured_throughput": str(rep.measured_throughput),
        "plan_throughput": str(rep.plan_throughput),
        "utilization": rep.utilization,
        "scheduler_makespans": makespans,
        "streaming_arrival_rate": rate,
        "greedy_vs_round_robin": makespans["greedy"] / makespans["round_robin"],
        "bit_exact": bool(exact),
        "fused_bit_exact": bool(fused_exact),
        "working_set_bytes": rep.working_set_bytes,
        "star_bank_working_set_bytes": star_ws,
        "working_set_saving": 1 - rep.working_set_bytes / star_ws,
        "vmem_bytes_step": static["vmem_bytes_step"],
        "arith_intensity": static["arith_intensity"],
        "area_um2": plan.area,
        "star_bank_area_um2": conv_area,
        "area_saving": 1 - plan.area / conv_area,
        "energy_per_op_pj": design.energy_per_op_pj,
        "peak_power_mw": design.peak_power_mw,
        # top-level wall-clock columns = the core path (see FIELDS)
        "wall_us_first_call": paths["core"]["wall_us_first_call"],
        "wall_us_compile": paths["core"]["wall_us_compile"],
        "wall_us_steady": paths["core"]["wall_us_steady"],
        "paths": paths,
        "launch_count": {name: p["launch_count"]
                         for name, p in paths.items()},
        "fused_speedup_vs_kernel": fused_speedup,
        "n_instances": len(bk.instances),
    }


def _assert_fused_smoke(results) -> None:
    """The CI fused contract: one launch everywhere, a real speedup
    somewhere.

    Every point's fused path must trace to exactly one Pallas launch;
    and on at least one multi-instance point the fused steady-state
    must beat (or tie) the per-instance kernel path -- interpret-mode
    wall clock is noisy per point, so the speedup gate takes the max
    over the multi-instance subset rather than demanding every point
    win.
    """
    bad = [(r["bits"], r["tp"]) for r in results
           if r["launch_count"]["fused"] != 1]
    assert not bad, f"fused path issued != 1 launch on points {bad}"
    assert all(r["fused_bit_exact"] for r in results), \
        "fused path lost bit-exactness on a smoke point"
    multi = [r for r in results if r["n_instances"] > 1]
    assert multi, "smoke grid has no multi-instance design point"
    best = max(r["fused_speedup_vs_kernel"] for r in multi)
    assert best >= 1.0, \
        (f"fused megakernel never reached per-instance parity on any "
         f"multi-instance smoke point (best speedup {best:.2f}x)")
    # static roofline columns: the dataflow analyzer must place every
    # point on the roofline (positive intensity, nonzero residency)
    bad = [(r["bits"], r["tp"]) for r in results
           if not (r.get("vmem_bytes_step", 0) > 0
                   and r.get("arith_intensity", 0) > 0)]
    assert not bad, \
        f"dataflow static roofline columns missing/zero on points {bad}"
    _row("bank.fused_smoke_gate", 0.0,
         f"launches_ok=True best_multi_instance_speedup={best:.2f}x "
         f"static_roofline_ok=True")


def bench_bank(out_path: str | None = None, smoke: bool = False):
    """Execute every design point; emit CSV rows + BENCH_bank.json."""
    points = SMOKE_POINTS if smoke else DESIGN_POINTS
    results = []
    for bits, tp in points:
        r = run_design_point(bits, tp)
        results.append(r)
        ms = r["scheduler_makespans"]
        _row(f"bank.{bits}b_tp{tp.numerator}_{tp.denominator}",
             r["wall_us_steady"],
             f"exact={r['bit_exact']} fused_exact={r['fused_bit_exact']} "
             f"util={r['utilization']:.3f} "
             f"cycles={r['cycles']} "
             f"rr={ms['round_robin']} greedy={ms['greedy']} "
             f"stream={ms['streaming']} "
             f"ws_saving={r['working_set_saving']:.0%} "
             f"area_saving={r['area_saving']:.0%} "
             f"E={r['energy_per_op_pj']:.2f}pJ "
             f"launches={r['launch_count']['kernel']}->"
             f"{r['launch_count']['fused']} "
             f"fused_speedup={r['fused_speedup_vs_kernel']:.2f}x")
    if smoke:
        _assert_fused_smoke(results)
    path = out_path or os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        "BENCH_bank.json")
    with open(path, "w") as f:
        json.dump({"fields": FIELDS,
                   "interpret_mode": runtime.interpret_mode(),
                   "design_points": results, "smoke": smoke}, f, indent=1)
    _row("bank.artifact", 0.0, f"wrote={path} n={len(results)}")
    return results


ALL = [bench_bank]


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("out", nargs="?", default=None,
                    help="output JSON path (default: repo-root "
                         "BENCH_bank.json)")
    ap.add_argument("--out", dest="out_flag", default=None)
    ap.add_argument("--smoke", action="store_true",
                    help="CI subset: 6 design points")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    bench_bank(args.out_flag or args.out, smoke=args.smoke)
