"""Autotuner benchmark: Pareto-front search + paper energy headlines.

Exercises ``repro.autotune`` end to end and emits ``BENCH_autotune.json``:

  * per Table-VIII width (8..128), the TP=1/2 front's best-energy point
    vs the Star design -- the paper's headline energy direction (up to
    33% savings) and peak-power direction (65% average reduction) must
    hold with the correct SIGN at every width;
  * a multi-point front (TP=1/3: FB / FF / folded-Karatsuba trade
    area vs fmax vs energy) with its size and scored-candidate count;
  * the cache contract: the second ``search`` over the same spec space
    must load from cache with ZERO re-scores.

``--smoke`` asserts all of the above and exits non-zero on violation,
so CI catches a power-model or search regression, not just a crash.
Emits ``name,us_per_call,derived`` CSV rows like the other benches.
"""
from __future__ import annotations

import argparse
import json
import os
import tempfile
import time
from fractions import Fraction

from repro import autotune, designs
from repro.core import power_model as pm
from repro.core.mcim import MCIMConfig

WIDTHS = (8, 16, 32, 64, 128)
STAR = MCIMConfig(arch="star", ct=1)


def _row(name, us, derived):
    print(f"{name},{us:.2f},{derived}")


def _plan_switched(bits, configs):
    return sum(c * pm.peak_switched(bits, bits, cfg) for c, cfg in configs)


def headline_tp_half(cache_dir: str) -> list:
    """TP=1/2 best-energy point vs Star at each Table-VIII width."""
    rows = []
    for bits in WIDTHS:
        spec = designs.DesignSpec(bits, bits, Fraction(1, 2))
        t0 = time.perf_counter()
        front = autotune.search(spec, cache_dir=cache_dir)
        us = (time.perf_counter() - t0) * 1e6
        best = front.best("energy")
        star_e = pm.energy_per_op_pj(bits, bits, STAR)
        e_sav = 1 - best.energy_per_op_pj / star_e
        # same-clock comparison: switched capacitance ratio (clock cancels)
        p_red = 1 - _plan_switched(bits, best.configs) / \
            pm.peak_switched(bits, bits, STAR)
        rows.append({
            "bits": bits,
            "tp": "1/2",
            "front_size": len(front),
            "n_scored": front.n_scored,
            "best_energy": best.to_dict(),
            "star_energy_pj": star_e,
            "energy_savings_vs_star": e_sav,
            "peak_power_reduction_vs_star": p_red,
            # the paper's own TP=1/2 design (one FB CT=2 instance), for
            # the apples-to-apples up-to-33%/65% comparison
            "fb2_energy_savings": pm.energy_savings_vs_star(
                bits, bits, MCIMConfig(arch="fb", ct=2)),
            "fb2_peak_reduction": pm.peak_power_reduction_vs_star(
                bits, bits, MCIMConfig(arch="fb", ct=2)),
        })
        _row(f"autotune.tp1_2_{bits}b", us,
             f"front={len(front)} scored={front.n_scored} "
             f"best=[{best.describe()}] "
             f"energy_savings={e_sav:.0%} peak_reduction={p_red:.0%} "
             f"paper=up-to-33%/65%avg")
    return rows


def multi_point_front(cache_dir: str) -> dict:
    """TP=1/3 @ 32b: the arch trade-off front (FB vs FF vs Karatsuba)."""
    spec = designs.DesignSpec(32, 32, Fraction(1, 3))
    t0 = time.perf_counter()
    front = autotune.search(spec, cache_dir=cache_dir)
    us = (time.perf_counter() - t0) * 1e6
    _row("autotune.tp1_3_32b", us,
         f"front={len(front)} dominated={len(front.dominated)} "
         f"scored={front.n_scored} "
         f"best_energy=[{front.best('energy').describe()}] "
         f"best_fmax=[{front.best('fmax').describe()}]")
    return {"spec": spec.to_dict(), "front_size": len(front),
            "n_dominated": len(front.dominated),
            "n_scored": front.n_scored,
            "front": [c.to_dict() for c in front]}


def cached_rerun(cache_dir: str) -> dict:
    """Re-search every space above: must be all cache hits, 0 re-scores."""
    specs = [designs.DesignSpec(b, b, Fraction(1, 2)) for b in WIDTHS]
    specs.append(designs.DesignSpec(32, 32, Fraction(1, 3)))
    t0 = time.perf_counter()
    fronts = [autotune.search(s, cache_dir=cache_dir) for s in specs]
    us = (time.perf_counter() - t0) * 1e6
    hits = sum(f.from_cache for f in fronts)
    rescores = sum(f.n_scored for f in fronts)
    _row("autotune.cached_rerun", us,
         f"searches={len(fronts)} cache_hits={hits} re_scores={rescores}")
    return {"searches": len(fronts), "cache_hits": hits,
            "re_scores": rescores}


def bench_autotune(out_path: str | None = None, smoke: bool = False,
                   cache_dir: str | None = None):
    cache_dir = cache_dir or tempfile.mkdtemp(prefix="repro_autotune_bench_")
    headline = headline_tp_half(cache_dir)
    tp13 = multi_point_front(cache_dir)
    rerun = cached_rerun(cache_dir)

    payload = {
        "smoke": smoke,
        "autotune_version": autotune.AUTOTUNE_VERSION,
        "power_model_version": pm.MODEL_VERSION,
        "tp_half_headline": headline,
        "tp_third_front": tp13,
        "cached_rerun": rerun,
    }
    path = out_path or os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        "BENCH_autotune.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)
    _row("autotune.artifact", 0.0, f"wrote={path}")

    if smoke:
        # regression gates, not just smoke-no-crash
        for r in headline:
            assert r["energy_savings_vs_star"] > 0.10, \
                f"TP=1/2 {r['bits']}b energy saving lost its sign: " \
                f"{r['energy_savings_vs_star']:.1%}"
            assert r["peak_power_reduction_vs_star"] > 0.30, \
                f"TP=1/2 {r['bits']}b peak reduction collapsed: " \
                f"{r['peak_power_reduction_vs_star']:.1%}"
        assert tp13["front_size"] >= 3, \
            f"TP=1/3 front trivial: {tp13['front_size']} points"
        assert rerun["cache_hits"] == rerun["searches"] and \
            rerun["re_scores"] == 0, f"cache contract broken: {rerun}"
        _row("autotune.smoke", 0.0, "asserts=pass")
    return payload


ALL = [bench_autotune]


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("out", nargs="?", default=None,
                    help="output JSON path (default: repo-root "
                         "BENCH_autotune.json)")
    ap.add_argument("--out", dest="out_flag", default=None)
    ap.add_argument("--smoke", action="store_true",
                    help="assert headline signs + cache contract")
    ap.add_argument("--cache-dir", default=None,
                    help="autotune cache dir (default: fresh temp dir)")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    bench_autotune(args.out_flag or args.out, smoke=args.smoke,
                   cache_dir=args.cache_dir)
