"""Aggregate dry-run artifacts into the §Roofline table (markdown + CSV).

Reads experiments/dryrun/*.json (written by repro.launch.dryrun) and
emits one row per (arch x shape x mesh): the three roofline terms, the
dominant bottleneck, and the useful-FLOPs ratio.  Also used by
benchmarks.run to print the summary CSV.
"""
from __future__ import annotations

import glob
import json
import os

HEADERS = ["arch", "shape", "mesh", "kind", "compute_s", "memory_s",
           "collective_s", "dominant", "useful_ratio", "compile_s"]


def load_cells(d: str = "experiments/dryrun"):
    cells = []
    for path in sorted(glob.glob(os.path.join(d, "*.json"))):
        with open(path) as f:
            cells.append(json.load(f))
    return cells


def row(c: dict) -> list:
    r = c["roofline"]
    return [c["arch"], c["shape"], c["mesh"], c["kind"],
            f"{r['compute_s']:.3e}", f"{r['memory_s']:.3e}",
            f"{r['collective_s']:.3e}", r["dominant"],
            f"{min(c.get('useful_flops_ratio', 0), 99):.2f}",
            f"{c.get('compile_s', 0):.0f}"]


def markdown_table(cells) -> str:
    lines = ["| " + " | ".join(HEADERS) + " |",
             "|" + "---|" * len(HEADERS)]
    for c in cells:
        lines.append("| " + " | ".join(str(x) for x in row(c)) + " |")
    return "\n".join(lines)


def print_csv(d: str = "experiments/dryrun"):
    cells = load_cells(d)
    if not cells:
        print("roofline.no_artifacts,0.00,run scripts/dryrun_all.py first")
        return
    for c in cells:
        if c.get("overrides"):
            continue
        r = c["roofline"]
        print(f"roofline.{c['arch']}.{c['shape']}.{c['mesh']},0.00,"
              f"compute={r['compute_s']:.2e}s memory={r['memory_s']:.2e}s "
              f"collective={r['collective_s']:.2e}s "
              f"dominant={r['dominant']} "
              f"useful={c.get('useful_flops_ratio', 0):.2f}")


ALL = [print_csv]
