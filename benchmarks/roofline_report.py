"""Aggregate dry-run artifacts into the §Roofline table (markdown + CSV).

Reads experiments/dryrun/*.json (written by repro.launch.dryrun) and
emits one row per (arch x shape x mesh): the three roofline terms, the
dominant bottleneck, and the useful-FLOPs ratio.  Also used by
benchmarks.run to print the summary CSV.

``print_fused_static`` adds the *execution-free* rows: the fused
megakernel's arithmetic intensity per design point, computed by the
static dataflow analyzer (``repro.verify.dataflow``) from the traced
kernel jaxpr -- FLOPs by abstract interpretation, HBM bytes by
block-index transition counting -- and positioned against the machine
balance point (peak FLOPs / HBM bandwidth from ``repro.launch``'s
hardware model).
"""
from __future__ import annotations

import glob
import json
import os
from fractions import Fraction

HEADERS = ["arch", "shape", "mesh", "kind", "compute_s", "memory_s",
           "collective_s", "dominant", "useful_ratio", "compile_s"]


def load_cells(d: str = "experiments/dryrun"):
    cells = []
    for path in sorted(glob.glob(os.path.join(d, "*.json"))):
        with open(path) as f:
            cells.append(json.load(f))
    return cells


def row(c: dict) -> list:
    r = c["roofline"]
    return [c["arch"], c["shape"], c["mesh"], c["kind"],
            f"{r['compute_s']:.3e}", f"{r['memory_s']:.3e}",
            f"{r['collective_s']:.3e}", r["dominant"],
            f"{min(c.get('useful_flops_ratio', 0), 99):.2f}",
            f"{c.get('compile_s', 0):.0f}"]


def markdown_table(cells) -> str:
    lines = ["| " + " | ".join(HEADERS) + " |",
             "|" + "---|" * len(HEADERS)]
    for c in cells:
        lines.append("| " + " | ".join(str(x) for x in row(c)) + " |")
    return "\n".join(lines)


def print_csv(d: str = "experiments/dryrun"):
    cells = load_cells(d)
    if not cells:
        print("roofline.no_artifacts,0.00,run scripts/dryrun_all.py first")
        return
    for c in cells:
        if c.get("overrides"):
            continue
        r = c["roofline"]
        print(f"roofline.{c['arch']}.{c['shape']}.{c['mesh']},0.00,"
              f"compute={r['compute_s']:.2e}s memory={r['memory_s']:.2e}s "
              f"collective={r['collective_s']:.2e}s "
              f"dominant={r['dominant']} "
              f"useful={c.get('useful_flops_ratio', 0):.2f}")


#: design points positioned on the static roofline (bits, throughput)
STATIC_POINTS = [(16, Fraction(7, 2)), (32, Fraction(7, 2)),
                 (64, Fraction(5, 6)), (128, Fraction(1, 2))]


def print_fused_static(points=None):
    """Static roofline rows for the fused megakernel, no execution.

    One row per design point: the dataflow analyzer's FLOPs, HBM
    bytes, arithmetic intensity and where that sits against the TPU
    balance point (intensity below balance = HBM-bound launch).
    """
    from repro.core import planner
    from repro.launch.roofline import HBM_BW, PEAK_FLOPS
    from repro.verify import dataflow

    balance = PEAK_FLOPS / HBM_BW
    for bits, tp in points or STATIC_POINTS:
        plan = planner.plan_throughput(bits, bits, tp)
        s = dataflow.plan_static_stats(bits, bits, plan.configs)
        bound = ("compute" if s["arith_intensity"] >= balance
                 else "memory")
        print(f"roofline.fused_static.{bits}b_tp"
              f"{tp.numerator}_{tp.denominator},0.00,"
              f"flops={s['flops_per_launch']} "
              f"hbm_bytes={s['hbm_bytes_per_launch']} "
              f"intensity={s['arith_intensity']:.2f} "
              f"balance={balance:.1f} bound={bound} "
              f"vmem_step={s['vmem_bytes_step']}")


ALL = [print_csv, print_fused_static]
