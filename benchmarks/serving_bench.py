"""Online serving benchmark: SLO goodput under sustained load.

For each registry design point, sweep offered load below and above the
provisioned ``Plan.throughput`` and drive seeded synthetic traffic
through the full ``repro.serving`` worker loop (SLO admission control,
EDF dispatch in bank rounds, work stealing, optional autoscaling),
recording per point

  * offered rate vs achieved goodput (deadline-met completions/cycle),
  * p50/p99 end-to-end latency, in bank cycles AND in wall ns at the
    design's modeled fmax,
  * SLO-violation and refusal rates (violations must be zero by
    construction: the admission controller refuses instead),
  * per-instance utilization over the serving horizon,
  * work-steal counts and bank-round counts,
  * bit-exactness of every response vs the Python-bigint oracle.

Two scenario rows ride along: a 2-replica bursty trace with a skewed
router (every request homes to replica 0) so work stealing is load
bearing, and a diurnal trace under the EMA autoscaler so the replica
timeline is tracked per PR.

Emits ``BENCH_serving.json`` (repo root, override with --out) and the
harness CSV rows.  ``--smoke`` runs the reduced sweep for CI and
ASSERTS the serving contract: zero SLO violations everywhere, zero
refusals at offered load <= provisioned TP, graceful goodput
degradation (not collapse) above it, bit-exact responses on every
point, steals > 0 on the skewed scenario, scale-up on the diurnal
scenario, and one fused Pallas launch per bank round.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import math
import os

from repro import designs
from repro.core.bank import Bank
from repro.serving import (Autoscaler, Worker, bursty_arrivals,
                           diurnal_arrivals, poisson_arrivals, synthesize)

#: registry design points served: the paper's headline fractional-TP
#: mixed bank, a pure folded point, and the wide 5/6 CT-combination
DESIGN_POINTS = ("tbl8_w32_relaxed", "tp3p5_w32", "tp5over6_w128")

#: offered load as a multiple of the provisioned throughput; the
#: critical point rho=1.0 is swept in full runs but never gated (its
#: queue is divergent by definition)
FULL_LOADS = (0.5, 0.8, 1.0, 1.5, 2.0)
SMOKE_LOADS = (0.5, 0.8, 2.0)

N_REQUESTS = 400
N_SMOKE = 120

#: documentation of the emitted columns, embedded in the JSON header
FIELDS = {
    "load_factor":
        "offered rate / provisioned per-replica Plan.throughput; <1 is "
        "the under-provisioned regime the zero-refusal gate covers",
    "offered_rate":
        "measured requests/cycle over the serving horizon (first "
        "arrival to last retire) -- the realized, not nominal, load",
    "goodput":
        "deadline-met completions/cycle over the same horizon; above "
        "saturation this must hold near the provisioned TP (graceful "
        "degradation), never collapse",
    "p50_cycles / p99_cycles":
        "end-to-end latency percentiles (arrival to retire) of admitted "
        "requests, in bank cycles, from the shared "
        "core.bank.schedule histogram path",
    "p50_ns / p99_ns":
        "the same percentiles in wall time at the design's modeled "
        "fmax_estimate (cycles / GHz)",
    "slo_violation_rate":
        "admitted requests retired past their deadline / admitted; "
        "structurally 0: admission control refuses instead of missing",
    "refusal_rate":
        "refused / offered; every refusal carries its infeasibility "
        "evidence (earliest_possible > deadline)",
    "utilization":
        "per replica, per instance: busy cycles / horizon",
    "steals":
        "commits rebalanced across replicas by the work stealer",
    "rounds":
        "bank rounds dispatched (one Bank.execute -- one fused Pallas "
        "launch on the fused backend -- per replica per window)",
    "fused_launches_per_round":
        "Pallas launches one bank round traces to on the fused backend "
        "at the largest observed round batch (must be exactly 1)",
    "bit_exact":
        "every response checked against the Python-bigint oracle",
}


def _row(name, us, derived):
    print(f"{name},{us:.2f},{derived}")


def _report_row(name, load, budget, rep) -> dict:
    design = designs.generate(name)
    ghz = design.fmax_estimate
    p50, p99 = rep.latency_p50, rep.latency_p99
    return {
        "design": name,
        "design_spec": design.spec.to_dict(),
        "plan": design.plan.describe(),
        "provisioned_tp": rep.provisioned_tp,
        "load_factor": load,
        "budget_cycles": budget,
        "n_requests": rep.n_requests,
        "n_admitted": rep.n_admitted,
        "n_refused": rep.n_refused,
        "offered_rate": rep.offered_rate,
        "goodput": rep.goodput,
        "p50_cycles": p50,
        "p99_cycles": p99,
        "p50_ns": None if p50 is None else p50 / ghz,
        "p99_ns": None if p99 is None else p99 / ghz,
        "slo_violations": rep.slo_violations,
        "slo_violation_rate": rep.slo_violation_rate,
        "refusal_rate": rep.refusal_rate,
        "utilization": [list(u) for u in rep.utilization],
        "steals": rep.steals,
        "rounds": rep.rounds,
        "max_round_batch": rep.max_round_batch,
        "horizon_cycles": rep.horizon_cycles,
        "replica_timeline": [list(t) for t in rep.replica_timeline],
        "wall_s": rep.wall_s,
        "n_checked": rep.n_checked,
        "bit_exact": rep.bit_exact,
    }


def _budget(design) -> int:
    """SLO budget in cycles for one design: generous vs the transient
    queues of sub-critical load (which stretch with 1/TP -- service is
    slow relative to arrival bursts on low-TP banks), tight vs the
    divergent queue of sustained overload, so refusals appear exactly
    where queueing theory says they must."""
    tp = float(design.plan.throughput)
    max_ct = max(cfg.ct for cfg in design.bank.instances)
    return max(4 * max_ct, math.ceil(32 / tp))


def run_sweep_point(name: str, load: float, n: int, seed: int) -> dict:
    """One (design, load-factor) cell: Poisson traffic, 1 replica."""
    design = designs.generate(name)
    tp = float(design.plan.throughput)
    budget = _budget(design)
    arr = poisson_arrivals(n, load * tp, seed=seed)
    reqs = synthesize(arr, design.spec.bits_a, design.spec.bits_b,
                      budget=budget, seed=seed + 1)
    rep, _ = design.serve(reqs, check=True)
    return _report_row(name, load, budget, rep)


def run_steal_scenario(name: str, n: int, seed: int) -> dict:
    """2 replicas, bursty traffic, a skewed front-end router.

    The worker's router homes request ``rid % n_live``; giving every
    request an even rid pins the whole stream to replica 0, so ONLY the
    work stealer can use replica 1.  The gate asserts it does.
    """
    design = designs.generate(name)
    tp = float(design.plan.throughput)
    budget = 2 * _budget(design)     # 2 replicas: twice the capacity
    arr = bursty_arrivals(n, 1.2 * tp, seed=seed, burst=8)
    reqs = tuple(dataclasses.replace(r, rid=2 * r.rid)
                 for r in synthesize(arr, design.spec.bits_a,
                                     design.spec.bits_b,
                                     budget=budget, seed=seed + 1))
    rep, _ = design.serve(reqs, replicas=2, check=True)
    row = _report_row(name, 1.2, budget, rep)
    row["scenario"] = "steal_skewed_router"
    row["replicas"] = 2
    return row


def run_autoscale_scenario(name: str, n: int, seed: int) -> dict:
    """Diurnal traffic peaking above one replica's TP, EMA autoscaler.

    Run on a low-TP design so the trace spans many dispatch windows
    (the EMA needs windows to track the envelope up and back down);
    ``ema=0.6`` reacts within ~2 windows of a rate change.
    """
    design = designs.generate(name)
    tp = float(design.plan.throughput)
    budget = 4 * _budget(design)     # autoscale absorbs load, SLO lax
    scaler = Autoscaler(design.plan.throughput, min_replicas=1,
                        max_replicas=4, ema=0.6, patience=2)
    arr = diurnal_arrivals(n, 1.2 * tp, seed=seed, period=128)
    reqs = synthesize(arr, design.spec.bits_a, design.spec.bits_b,
                      budget=budget, seed=seed + 1)
    rep, _ = design.serve(reqs, replicas=1, autoscaler=scaler, check=True)
    row = _report_row(name, 1.2, budget, rep)
    row["scenario"] = "autoscale_diurnal"
    row["autoscaler"] = scaler.describe()
    return row


def _fused_launch_evidence(name: str, max_batch: int) -> int:
    """Trace (not run) one fused bank round at the largest observed
    round batch: the launch count IS the per-round Pallas launch cost."""
    design = designs.generate(name)
    bucket = 1
    while bucket < max(max_batch, 1):
        bucket <<= 1
    bank = Bank(design.plan, design.spec.bits_a, design.spec.bits_b,
                backend="fused")
    return bank.launch_count(bucket)


def _assert_serving_smoke(sweep, steal_row, scale_row) -> None:
    """The CI serving contract (see module docstring)."""
    rows = sweep + [steal_row, scale_row]
    bad = [(r["design"], r["load_factor"]) for r in rows
           if r["slo_violations"]]
    assert not bad, f"admitted requests missed their SLO on {bad}"
    assert all(r["bit_exact"] for r in rows), \
        "a serving response diverged from the bigint oracle"
    below = [r for r in sweep if r["load_factor"] < 1.0]
    assert below, "smoke sweep has no below-provisioned point"
    bad = [(r["design"], r["load_factor"]) for r in below
           if r["n_refused"]]
    assert not bad, \
        f"refusals below provisioned throughput on {bad}"
    bad = [(r["design"], r["load_factor"]) for r in below
           if r["p99_cycles"] > r["budget_cycles"]]
    assert not bad, f"p99 over the SLO budget below provisioned TP: {bad}"
    above = [r for r in sweep if r["load_factor"] > 1.0]
    assert above, "smoke sweep has no overload point"
    for r in above:
        # graceful degradation: the overloaded bank must keep serving
        # near its provisioned rate (refusing the excess), not collapse
        floor = 0.6 * float(eval_fraction(r["provisioned_tp"]))
        assert r["goodput"] >= floor, \
            (f"goodput collapsed under overload on {r['design']}: "
             f"{r['goodput']:.3f}/cy < {floor:.3f}/cy")
        assert r["n_refused"] > 0, \
            (f"{r['design']} overloaded with no refusals -- admission "
             f"control is not engaging")
    assert steal_row["steals"] > 0, \
        "skewed-router scenario produced no work steals"
    peak = max(n for _, n in scale_row["replica_timeline"])
    assert peak > 1, "diurnal scenario never scaled past 1 replica"
    _row("serving.smoke_gate", 0.0,
         f"zero_viol=True zero_refusals_below_tp=True "
         f"graceful_overload=True steals={steal_row['steals']} "
         f"peak_replicas={peak}")


def eval_fraction(s: str) -> float:
    from fractions import Fraction
    return float(Fraction(s))


def bench_serving(out_path: str | None = None, smoke: bool = False):
    """Serve every (design, load) cell; emit CSV + BENCH_serving.json."""
    loads = SMOKE_LOADS if smoke else FULL_LOADS
    n = N_SMOKE if smoke else N_REQUESTS
    sweep = []
    for name in DESIGN_POINTS:
        for load in loads:
            r = run_sweep_point(name, load, n, seed=17)
            sweep.append(r)
            _row(f"serving.{name}_rho{load}", r["wall_s"] * 1e6,
                 f"offered={r['offered_rate']:.3f}/cy "
                 f"goodput={r['goodput']:.3f}/cy "
                 f"p50={r['p50_cycles']} p99={r['p99_cycles']}cy "
                 f"refused={r['n_refused']} viol={r['slo_violations']} "
                 f"rounds={r['rounds']} exact={r['bit_exact']}")
    steal_row = run_steal_scenario("tp3p5_w32", n, seed=23)
    _row("serving.steal_scenario", steal_row["wall_s"] * 1e6,
         f"steals={steal_row['steals']} "
         f"refused={steal_row['n_refused']} "
         f"viol={steal_row['slo_violations']} "
         f"exact={steal_row['bit_exact']}")
    scale_row = run_autoscale_scenario("tbl8_w32_relaxed", n, seed=29)
    _row("serving.autoscale_scenario", scale_row["wall_s"] * 1e6,
         f"timeline={scale_row['replica_timeline']} "
         f"viol={scale_row['slo_violations']} "
         f"exact={scale_row['bit_exact']}")
    # one fused Pallas launch per bank round: traced, not executed, at
    # the largest round batch the sweep actually produced
    max_batch = max(r["max_round_batch"] for r in sweep)
    launches = _fused_launch_evidence("tp3p5_w32", max_batch)
    _row("serving.fused_round_launches", 0.0,
         f"launches={launches} round_batch<={max_batch}")
    if smoke:
        assert launches == 1, \
            f"a fused bank round traces to {launches} launches, not 1"
        _assert_serving_smoke(sweep, steal_row, scale_row)
    path = out_path or os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        "BENCH_serving.json")
    with open(path, "w") as f:
        json.dump({"fields": FIELDS,
                   "design_points": sweep,
                   "scenarios": [steal_row, scale_row],
                   "fused_launches_per_round": launches,
                   "smoke": smoke}, f, indent=1)
    _row("serving.artifact", 0.0,
         f"wrote={path} n={len(sweep) + 2}")
    return sweep


ALL = [bench_serving]


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("out", nargs="?", default=None,
                    help="output JSON path (default: repo-root "
                         "BENCH_serving.json)")
    ap.add_argument("--out", dest="out_flag", default=None)
    ap.add_argument("--smoke", action="store_true",
                    help="CI subset: reduced load grid and request count")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    bench_serving(args.out_flag or args.out, smoke=args.smoke)
