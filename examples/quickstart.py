"""Quickstart: the paper's design generator as a JAX library.

One declarative ``DesignSpec`` -- throughput, clock target, latency
budget, signedness -- compiles into an executable ``CompiledDesign``
via ``repro.designs.generate``.  No planner/bank hand-wiring.

  PYTHONPATH=src python examples/quickstart.py
"""
from fractions import Fraction

from repro import designs


def main():
    # -- multiply two 128-bit integers through generated designs ---------
    a = 0xDEADBEEF_CAFEBABE_01234567_89ABCDEF
    b = 0xFEEDFACE_8BADF00D_00C0FFEE_DEADC0DE
    print("one 128x128 multiply per throughput point:")
    for tp in (1, Fraction(1, 2), Fraction(1, 3)):
        d = designs.generate(designs.DesignSpec(128, 128, tp))
        ok = "OK " if d.mul(a, b) == a * b else "FAIL"
        print(f"  {ok} TP={tp!s:4} -> {d.plan.describe()}")

    # -- clock-frequency customization (the paper's strict tables) -------
    # a 0.31 ns target rejects the feedback-loop design the relaxed
    # planner would pick; generate() falls back per timing_model
    relaxed = designs.generate(designs.DesignSpec(32, 32, Fraction(1, 3)))
    tight = designs.generate(
        designs.DesignSpec(32, 32, Fraction(1, 3), clock_ns=0.31))
    print(f"\nrelaxed pick : {relaxed.plan.describe()}")
    print(f"0.31ns pick  : {tight.plan.describe()} "
          f"(fallback={tight.timing_fallback})")
    print(f"  latency {tight.latency_cycles} cycles, "
          f"fmax ~{tight.fmax_estimate:.2f} GHz, "
          f"area {tight.area:.0f} um2 (incl. synthesis stress), "
          f"energy {tight.energy_per_op_pj:.2f} pJ/op, "
          f"peak {tight.peak_power_mw:.2f} mW")

    # -- energy/peak-power model + autotuner (paper Sec. V headlines) ----
    from repro import autotune
    front = autotune.search(designs.DesignSpec(32, 32, Fraction(1, 3)))
    print(f"\nPareto front over every TP=1/3 decomposition "
          f"({len(front)} non-dominated of "
          f"{len(front.front) + len(front.dominated)}):")
    for c in front:
        print(f"  {c.describe()}")
    low = front.best("energy").compile()
    print(f"best-energy point compiles + multiplies exactly: "
          f"{low.mul(a % 2**32, b % 2**32) == (a % 2**32) * (b % 2**32)}")
    lp = designs.generate("tbl8_w32_lowpower")   # objective='energy' spec
    print(f"registered low-power design: {lp.describe()}")

    # -- fractional-throughput planning (use case 1, Sec. V-E) -----------
    d = designs.generate("tp3p5_w32")          # pre-registered point
    from repro.core import planner
    conv = planner.star_bank_area(32, 32, 3.5)
    print(f"\nTP=3.5 multipliers/cycle: {d.plan.describe()}")
    print(f"  vs conventional 4x Star bank: saves {1 - d.area / conv:.0%}")

    # -- lossless provenance ---------------------------------------------
    blob = d.to_json()
    again = designs.generate(designs.DesignSpec.from_json(blob))
    print(f"\nspec json round-trip recompiles bit-exactly: "
          f"{again.mul(a % 2**32, b % 2**32) == d.mul(a % 2**32, b % 2**32)}")


if __name__ == "__main__":
    main()
