"""Quickstart: the paper's folded multipliers as a JAX library.

  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax.numpy as jnp

from repro.core import limbs as L
from repro.core import MCIMConfig, mcim_mul, planner, area_model


def main():
    # -- multiply two 128-bit integers with every architecture ----------
    a_int = 0xDEADBEEF_CAFEBABE_01234567_89ABCDEF
    b_int = 0xFEEDFACE_8BADF00D_00C0FFEE_DEADC0DE
    a = jnp.asarray(L.to_limbs(a_int, 8))[None]
    b = jnp.asarray(L.to_limbs(b_int, 8))[None]
    expect = a_int * b_int
    for cfg in [MCIMConfig(arch="star", ct=1),
                MCIMConfig(arch="fb", ct=2),
                MCIMConfig(arch="fb", ct=4),
                MCIMConfig(arch="ff", ct=2),
                MCIMConfig(arch="karatsuba", ct=3, levels=2)]:
        out = L.from_limbs(np.asarray(mcim_mul(a, b, cfg))[0])
        status = "OK " if out == expect else "FAIL"
        print(f"{status} {cfg.arch:10s} ct={cfg.ct} -> 0x{out:064x}")

    # -- the paper's area story ------------------------------------------
    print("\nArea savings vs Star (32x32, FB architecture, Table VII):")
    for ct in (2, 3, 4, 8):
        s = area_model.savings_vs_star(32, 32, MCIMConfig(arch="fb", ct=ct))
        print(f"  CT={ct}: TP=1/{ct}, saves {s:.0%} silicon")

    # -- fractional-throughput planning (use case 1, Sec. V-E) -----------
    plan = planner.plan_throughput(32, 32, 3.5)
    conv = planner.star_bank_area(32, 32, 3.5)
    print(f"\nTP=3.5 multipliers/cycle: {plan.describe()}")
    print(f"  vs conventional 4x Star bank: saves {1 - plan.area/conv:.0%}")


if __name__ == "__main__":
    main()
