"""MCIM fixed-point reductions: bit-exact, order-invariant accumulation.

  PYTHONPATH=src python examples/exact_determinism.py
"""
import numpy as np
import jax.numpy as jnp

from repro.exact import exact_sum


def main():
    rng = np.random.default_rng(0)
    x = rng.standard_normal(100_000).astype(np.float32)

    f32_fwd = float(jnp.sum(jnp.asarray(x)))
    f32_rev = float(jnp.sum(jnp.asarray(x[::-1].copy())))
    ex_fwd = float(exact_sum(jnp.asarray(x)))
    ex_rev = float(exact_sum(jnp.asarray(x[::-1].copy())))

    print(f"f32 sum   forward: {f32_fwd:.10f}")
    print(f"f32 sum   reversed: {f32_rev:.10f}   equal: {f32_fwd == f32_rev}")
    print(f"MCIM sum  forward: {ex_fwd:.10f}")
    print(f"MCIM sum  reversed: {ex_rev:.10f}   equal: {ex_fwd == ex_rev}")
    assert ex_fwd == ex_rev, "exact path must be order-invariant"
    print("\n128-bit fixed-point accumulation is bit-exact under any "
          "reduction order -> reproducible distributed training.")


if __name__ == "__main__":
    main()
