"""Fractional throughput, executed: the paper's TP=3.5 use case (Sec. V-E).

An application needs 3.5 multiplications per cycle.  The conventional
bank rounds up to 4 Star multipliers; ``designs.generate`` instead
compiles 3 Star + one CT=2 folded MCIM from one declarative spec.  This
demo *runs* that design on a real batch and shows that

  * the results are bit-exact vs Python's bigints,
  * the round-robin schedule sustains exactly 3.5 ops/cycle,
  * the design costs less area (ASIC model) and VMEM (TPU analogue)
    than the 4x Star bank.

  PYTHONPATH=src python examples/fractional_throughput.py
"""
import numpy as np
import jax.numpy as jnp

from repro import designs
from repro.core import limbs as L
from repro.core import planner, bank

BITS = 32
TP = 3.5
BATCH = 56                      # 16 hyperperiods of 7 ops / 2 cycles


def main():
    design = designs.generate(designs.DesignSpec(BITS, BITS, TP))
    print(f"design: {design.describe()}")

    rng = np.random.default_rng(0)
    a = jnp.asarray(L.random_limbs(rng, (BATCH,), BITS))
    b = jnp.asarray(L.random_limbs(rng, (BATCH,), BITS))

    out = design.mul(a, b)
    got = L.batch_from_limbs(np.asarray(out))
    expect = [L.from_limbs(np.asarray(x)) * L.from_limbs(np.asarray(y))
              for x, y in zip(a, b)]
    print(f"bit-exact over {BATCH} ops: {got == expect}")

    rep = design.report(BATCH)
    print(f"\nschedule: {BATCH} ops in {rep.cycles} cycles "
          f"-> {rep.measured_throughput} ops/cycle "
          f"(design claims {design.throughput}, "
          f"utilization {rep.utilization:.3f})")
    for i, ir in enumerate(rep.instances):
        print(f"  instance {i}: {ir.config.arch}(ct={ir.ct})  "
              f"{ir.n_ops} ops, busy {ir.busy_cycles} cycles")

    # pluggable dispatch: same design, three scheduling policies
    print("\nscheduler makespans for this batch:")
    for name in ("round_robin", "greedy", "streaming"):
        makespan = design.bank.report(BATCH, scheduler=name).cycles
        print(f"  {name:12s} {makespan} cycles")
    _, tail = bank.greedy_schedule((1, 3), 2)
    _, tail_rr = bank.round_robin_schedule((1, 3), 2)
    print(f"  (on a heterogeneous tail, cts=(1,3) x 2 ops: "
          f"round_robin={tail_rr}, greedy={tail})")

    conv_area = planner.star_bank_area(BITS, BITS, TP)
    print(f"\narea: design {design.area:.0f}um2 vs 4x Star "
          f"{conv_area:.0f}um2 -> saves {1 - design.area / conv_area:.0%}")
    from repro.kernels.mcim_fold import vmem_bytes_per_step
    la = L.n_limbs_for_bits(BITS)
    star_ws = 4 * vmem_bytes_per_step(la, la, 1, design.bank.tile_b)
    print(f"vmem: design {rep.working_set_bytes} B vs 4x Star {star_ws} B "
          f"-> saves {1 - rep.working_set_bytes / star_ws:.0%}")


if __name__ == "__main__":
    main()
