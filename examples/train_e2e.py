"""End-to-end training driver: a ~100M-class qwen3-family model on the
learnable pattern stream, with checkpoint/resume and the MCIM exact
accumulation path. CPU-sized by default; flags scale it up.

  PYTHONPATH=src python examples/train_e2e.py [--steps 60]
"""
import argparse
import sys

from repro.launch.train import main as train_main


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--preset-100m", action="store_true",
                    help="full ~100M preset (slow on 1 CPU core)")
    args = ap.parse_args()
    argv = ["--arch", "qwen3-32b", "--steps", str(args.steps),
            "--seq-len", "128", "--global-batch", "8",
            "--source", "pattern", "--microbatches", "2", "--exact-accum",
            "--checkpoint-dir", "/tmp/repro_e2e_ckpt"]
    argv += (["--preset", "100m"] if args.preset_100m else ["--smoke"])
    res = train_main(argv)
    assert res.losses[-1] < res.losses[0], "training must reduce loss"


if __name__ == "__main__":
    main()
