"""Batched serving example: continuous batching over prefill/decode.

  PYTHONPATH=src python examples/serve_quant.py
"""
from repro.launch.serve import main as serve_main


def main():
    eng = serve_main(["--arch", "gemma3-1b", "--smoke",
                      "--requests", "6", "--slots", "2",
                      "--prompt-len", "16", "--max-new", "8"])
    assert len(eng.outputs) == 6
    assert all(len(toks) >= 8 for toks in eng.outputs.values())
    assert len(eng.arrival_trace()) == 6


if __name__ == "__main__":
    main()
