"""Sharded multi-bank execution: TP=3.5 replicated over a 2-device mesh.

The paper's Sec. V-E bank sustains 3.5 multiplications/cycle on one
chip.  Production serving replicates it: this demo forces a 2-device
CPU mesh, runs ``bank.sharded_execute`` so each device executes one
full bank replica on half the batch, and shows that

  * the gathered results are bit-exact vs Python's bigints (and vs the
    single-bank engine),
  * the output really lives sharded along the mesh axis,
  * the aggregate throughput is N_devices x the per-replica rate
    (2 x 3.5 = 7 ops/cycle here),
  * the greedy scheduler's makespan never loses to round-robin.

  PYTHONPATH=src python examples/sharded_bank.py
"""
import os

# must be set before the first jax init: fake 2 CPU devices
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=2")

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import limbs as L
from repro.core import planner, bank

BITS = 32
TP = 3.5
BATCH = 56                      # 28 ops per device = 8 hyperperiods each


def main():
    mesh = jax.make_mesh((len(jax.devices()),), ("data",))
    n_dev = mesh.shape["data"]
    plan = planner.plan_throughput(BITS, BITS, TP)
    print(f"mesh: {n_dev} devices over axis 'data'")
    print(f"plan per replica: {plan.describe()}")

    rng = np.random.default_rng(0)
    a = jnp.asarray(L.random_limbs(rng, (BATCH,), BITS))
    b = jnp.asarray(L.random_limbs(rng, (BATCH,), BITS))

    out = bank.sharded_execute(plan, a, b, mesh, "data")
    got = L.batch_from_limbs(np.asarray(out))
    expect = [L.from_limbs(np.asarray(x)) * L.from_limbs(np.asarray(y))
              for x, y in zip(a, b)]
    single = bank.execute(plan, a, b)
    print(f"\nbit-exact over {BATCH} ops: {got == expect}")
    print(f"identical to the single-bank engine: "
          f"{np.array_equal(np.asarray(out), np.asarray(single))}")
    print(f"output sharding spec: {out.sharding.spec}")

    rep = bank.sharded_report(plan, BATCH, BITS, BITS, mesh, "data")
    agg = n_dev * rep.measured_throughput
    print(f"\nper replica: {rep.batch} ops in {rep.cycles} cycles "
          f"-> {rep.measured_throughput} ops/cycle")
    print(f"aggregate: {n_dev} replicas -> {agg} ops/cycle "
          f"(plan claims {n_dev} x {rep.plan_throughput})")

    # policy comparison on one replica's shard
    local = BATCH // n_dev
    cts = tuple(cfg.ct for count, cfg in plan.configs for _ in range(count))
    _, rr = bank.round_robin_schedule(cts, local)
    _, greedy = bank.greedy_schedule(cts, local)
    print(f"\nscheduler makespans on a {local}-op shard: "
          f"round_robin={rr}, greedy={greedy} (greedy never loses)")


if __name__ == "__main__":
    main()
