"""Sharded multi-bank execution: TP=3.5 replicated over a 2-device mesh.

The paper's Sec. V-E bank sustains 3.5 multiplications/cycle on one
chip.  Production serving replicates it: this demo forces a 2-device
CPU mesh and compiles ONE ``DesignSpec`` with ``replicas=2`` -- the
facade routes ``mul`` through ``bank.sharded_execute`` so each device
executes one full bank replica on half the batch -- and shows that

  * the gathered results are bit-exact vs Python's bigints (and vs the
    single-replica design),
  * the output really lives sharded along the mesh axis,
  * the aggregate throughput is N_devices x the per-replica rate
    (2 x 3.5 = 7 ops/cycle here),
  * the greedy scheduler's makespan never loses to round-robin.

  PYTHONPATH=src python examples/sharded_bank.py
"""
import dataclasses
import os

# must be set before the first jax init: fake 2 CPU devices
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=2")

import numpy as np
import jax
import jax.numpy as jnp

from repro import designs
from repro.core import limbs as L

BITS = 32
TP = 3.5
BATCH = 56                      # 28 ops per device = 8 hyperperiods each


def main():
    n_dev = len(jax.devices())
    spec = designs.DesignSpec(BITS, BITS, TP, replicas=n_dev,
                              mesh_axis="data")
    design = designs.generate(spec)
    print(f"mesh: {n_dev} devices over axis {spec.mesh_axis!r}")
    print(f"design: {design.describe()}")

    rng = np.random.default_rng(0)
    a = jnp.asarray(L.random_limbs(rng, (BATCH,), BITS))
    b = jnp.asarray(L.random_limbs(rng, (BATCH,), BITS))

    out = design.mul(a, b)
    got = L.batch_from_limbs(np.asarray(out))
    expect = [L.from_limbs(np.asarray(x)) * L.from_limbs(np.asarray(y))
              for x, y in zip(a, b)]
    single = designs.generate(
        dataclasses.replace(spec, replicas=1)).mul(a, b)
    print(f"\nbit-exact over {BATCH} ops: {got == expect}")
    print(f"identical to the single-replica design: "
          f"{np.array_equal(np.asarray(out), np.asarray(single))}")
    print(f"output sharding spec: {out.sharding.spec}")

    rep = design.report(BATCH)          # per-replica accounting
    print(f"\nper replica: {rep.batch} ops in {rep.cycles} cycles "
          f"-> {rep.measured_throughput} ops/cycle")
    print(f"aggregate: {n_dev} replicas -> "
          f"{design.throughput} ops/cycle "
          f"(plan claims {n_dev} x {rep.plan_throughput})")

    # policy comparison on one replica's shard
    local = BATCH // n_dev
    rr = design.bank.report(local, scheduler="round_robin").cycles
    greedy = design.bank.report(local, scheduler="greedy").cycles
    print(f"\nscheduler makespans on a {local}-op shard: "
          f"round_robin={rr}, greedy={greedy} (greedy never loses)")


if __name__ == "__main__":
    main()
