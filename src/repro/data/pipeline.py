"""Deterministic, shardable data pipeline.

Two sources behind one iterator interface:

  * SyntheticLM   -- Philox counter-RNG token streams (repro.rng): batch
    i of host h is a pure function of (seed, step, h), so restart /
    elastic re-shard never replays or skips data and needs no state.
  * BinTokenFile  -- memory-mapped packed token file (.bin uint16/32)
    with deterministic Philox shuffling of window offsets.

Batches are *global*: each host materializes only its slice
(process_index-based), then device_put with the batch sharding -- the
standard multi-host JAX pattern (works identically on 1 host here).
"""
from __future__ import annotations

import dataclasses

import numpy as np
import jax
import jax.numpy as jnp

from ..rng import random_tokens, random_u32


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    source: str = "synthetic"       # synthetic | binfile
    path: str = ""


class SyntheticLM:
    """Infinite deterministic LM batches; resume = set step."""

    def __init__(self, cfg: DataConfig, host_index: int = 0,
                 host_count: int = 1):
        assert cfg.global_batch % host_count == 0
        self.cfg = cfg
        self.host_batch = cfg.global_batch // host_count
        self.host_index = host_index
        self._tok = jax.jit(
            lambda offs: random_tokens(cfg.seed, 1, offs, cfg.vocab_size))

    def batch_at(self, step: int) -> dict:
        cfg = self.cfg
        n = self.host_batch * (cfg.seq_len + 1)
        base = (step * cfg.global_batch
                + self.host_index * self.host_batch) * (cfg.seq_len + 1)
        offs = jnp.arange(base, base + n, dtype=jnp.uint32)
        toks = np.asarray(self._tok(offs)).reshape(
            self.host_batch, cfg.seq_len + 1)
        return {
            "tokens": toks[:, :-1].astype(np.int32),
            "labels": toks[:, 1:].astype(np.int32),
            "mask": np.ones((self.host_batch, cfg.seq_len), np.float32),
        }


class PatternLM(SyntheticLM):
    """Learnable synthetic stream: token_{t+1} = (token_t + 1) % V.

    Deterministic (Philox start token per sequence); a working model
    drives CE to ~0 within tens of steps -- used by convergence tests
    and the end-to-end example to prove the training loop learns.
    """

    def batch_at(self, step: int) -> dict:
        cfg = self.cfg
        base = step * cfg.global_batch + self.host_index * self.host_batch
        starts = np.asarray(random_u32(
            cfg.seed, 3,
            jnp.arange(base, base + self.host_batch, dtype=jnp.uint32)
        ))[:, 0] % cfg.vocab_size
        t = np.arange(cfg.seq_len + 1)
        toks = ((starts[:, None] + t[None, :]) % cfg.vocab_size
                ).astype(np.int32)
        return {
            "tokens": toks[:, :-1],
            "labels": toks[:, 1:],
            "mask": np.ones((self.host_batch, cfg.seq_len), np.float32),
        }


class BinTokenFile:
    """Memory-mapped token corpus with deterministic window shuffling."""

    def __init__(self, cfg: DataConfig, host_index: int = 0,
                 host_count: int = 1, dtype=np.uint16):
        self.cfg = cfg
        self.data = np.memmap(cfg.path, dtype=dtype, mode="r")
        self.n_windows = (len(self.data) - 1) // cfg.seq_len
        assert self.n_windows >= 1, "corpus shorter than one window"
        self.host_batch = cfg.global_batch // host_count
        self.host_index = host_index

    def batch_at(self, step: int) -> dict:
        cfg = self.cfg
        idx0 = (step * cfg.global_batch + self.host_index * self.host_batch)
        sample_ids = np.arange(idx0, idx0 + self.host_batch, dtype=np.uint32)
        # Philox-shuffled window assignment (deterministic, stateless)
        rnd = np.asarray(random_u32(cfg.seed, 2, jnp.asarray(sample_ids)))
        windows = rnd[:, 0] % self.n_windows
        toks = np.stack([
            self.data[w * cfg.seq_len: w * cfg.seq_len + cfg.seq_len + 1]
            for w in windows]).astype(np.int32)
        return {
            "tokens": toks[:, :-1],
            "labels": toks[:, 1:],
            "mask": np.ones((self.host_batch, cfg.seq_len), np.float32),
        }


def make_source(cfg: DataConfig, host_index: int = 0, host_count: int = 1):
    if cfg.source == "synthetic":
        return SyntheticLM(cfg, host_index, host_count)
    if cfg.source == "pattern":
        return PatternLM(cfg, host_index, host_count)
    return BinTokenFile(cfg, host_index, host_count)


def device_batch(batch: dict, mesh, batch_sharding=None) -> dict:
    """Host batch -> sharded global arrays on the mesh."""
    if mesh is None:
        return {k: jnp.asarray(v) for k, v in batch.items()}
    from jax.sharding import NamedSharding, PartitionSpec as P
    data_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    out = {}
    for k, v in batch.items():
        spec = P(data_axes) if v.ndim >= 1 else P()
        out[k] = jax.device_put(v, NamedSharding(mesh, spec))
    return out
