from .pipeline import DataConfig, SyntheticLM, PatternLM, BinTokenFile, make_source, \
    device_batch

__all__ = ["DataConfig", "SyntheticLM", "PatternLM", "BinTokenFile", "make_source",
           "device_batch"]
