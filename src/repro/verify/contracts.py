"""Schedule-contract checking: does the design compute A*B, and does the
machinery around it keep its static promises?

Four independent contracts, all checkable without executing a multiply:

coverage
    Every folded schedule must touch every partial product a_i * b_j
    exactly once at weight 2**(16*(i+j)).  For fb/ff the per-cycle
    B-windows from :func:`~repro.kernels.mcim_fold.fold_geometry` are
    checked symbolically as a bilinear form; for Karatsuba the combine
    step ``T0 + T1<<2h + (T2-T1-T0)<<h`` is expanded as a polynomial
    identity over free symbols A0/A1/B0/B1 (the signed NOT+1 encodings
    cancel exactly like the hardware's wraps do), per recursion level.

widths
    The kernel's declared scratch/out widths must dominate the widths
    the interval analyzer (:mod:`.intervals`) proves the dataflow needs.
    A scratch one column too narrow silently truncates a compress -- the
    classic folded-multiplier bug this contract exists to reject.

throughput
    A ``planner.Plan``'s instance throughputs (count / CT each) must sum
    exactly to ``Plan.throughput`` as Fractions.

schedulers / bank staticness
    Every registered :class:`~repro.core.bank.schedule.Scheduler` must
    map (cts, n_ops) to a deterministic assignment that covers
    ``range(n_ops)`` exactly once with a makespan no smaller than its
    busiest instance; ``Bank.dispatch_fn`` must trace under
    ``jax.eval_shape`` (proof that dispatch depends on static shapes
    only, never on operand values).
"""
from __future__ import annotations

from fractions import Fraction

from repro.core import limbs as L
from repro.core.mcim import MCIMConfig
from repro.core.bank.schedule import SCHEDULERS
from repro.kernels.mcim_fold import fold_geometry
from repro.kernels.bank_fold.geometry import (fused_windows,
                                              super_geometry)

from . import intervals
from .intervals import Violation


# ------------------------------------------------------------- coverage

def coverage_form(la: int, lb: int, windows) -> dict:
    """Bilinear form of a windowed schoolbook schedule.

    Cycle ``t`` of an fb/ff fold computes ``A * B[lo:hi]`` and retires it
    at limb offset ``lo``, contributing ``a_i * b_j * 2**(16*(i+j))`` for
    every ``j`` in the window.  The returned dict maps ``(i, j)`` to the
    coefficient in units of the target weight ``2**(16*(i+j))`` -- a
    correct schedule yields exactly 1 everywhere.
    """
    form = {}
    for lo, hi in windows:
        for j in range(lo, min(hi, lb)):
            for i in range(la):
                form[(i, j)] = form.get((i, j), 0) + 1
    return form


def check_windows(la: int, lb: int, windows, where: str) -> list:
    """Coverage violations of one windowed schedule (fb/ff/star)."""
    form = coverage_form(la, lb, windows)
    out = []
    for i in range(la):
        for j in range(lb):
            coeff = form.pop((i, j), 0)
            if coeff == 0:
                out.append(Violation(
                    "contracts", "missing-product", where,
                    f"partial product a[{i}]*b[{j}] is never computed"))
            elif coeff != 1:
                out.append(Violation(
                    "contracts", "double-cover", where,
                    f"partial product a[{i}]*b[{j}] accumulated "
                    f"{coeff} times"))
    for (i, j), coeff in form.items():
        out.append(Violation(
            "contracts", "out-of-range", where,
            f"schedule touches nonexistent product a[{i}]*b[{j}] "
            f"({coeff}x)"))
    return out


def _poly_mul(p: dict, q: dict) -> dict:
    out = {}
    for (ma, sa), ca in p.items():
        for (mb, sb), cb in q.items():
            key = (tuple(sorted(ma + mb)), sa + sb)
            out[key] = out.get(key, 0) + ca * cb
    return out


def _poly_add(p: dict, q: dict, scale: int = 1, shift: int = 0) -> dict:
    out = dict(p)
    for (m, s), c in q.items():
        key = (m, s + shift)
        out[key] = out.get(key, 0) + scale * c
        if out[key] == 0:
            del out[key]
    return out


def check_karatsuba_identity(half: int, where: str) -> list:
    """Expand the combine step symbolically and compare against A*B.

    Polynomials live over monomials ((symbols...), limb_shift): the
    value is sum(coeff * prod(symbols) * 2**(16*shift)).  With
    A = A0 + A1<<h and T2 = (A0+A1)(B0+B1), the combine
    ``T0 + T1<<2h + T2<<h - T1<<h - T0<<h`` (the subtractions being what
    the NOT+1 columns encode mod the wrap) must equal A*B identically.
    """
    sym = lambda name: {((name,), 0): 1}
    a0, a1, b0, b1 = sym("A0"), sym("A1"), sym("B0"), sym("B1")
    t0 = _poly_mul(a0, b0)
    t1 = _poly_mul(a1, b1)
    t2 = _poly_mul(_poly_add(a0, a1), _poly_add(b0, b1))
    combine = {}
    combine = _poly_add(combine, t0)
    combine = _poly_add(combine, t1, shift=2 * half)
    combine = _poly_add(combine, t2, shift=half)
    combine = _poly_add(combine, t1, scale=-1, shift=half)
    combine = _poly_add(combine, t0, scale=-1, shift=half)
    target = _poly_mul(_poly_add(a0, a1, shift=half),
                       _poly_add(b0, b1, shift=half))
    diff = _poly_add(combine, target, scale=-1)
    if diff:
        return [Violation(
            "contracts", "karatsuba-identity", where,
            f"combine step differs from A*B by {diff}")]
    return []


def check_coverage(bits_a: int, bits_b: int, cfg: MCIMConfig,
                   windows=None) -> list:
    """Partial-product coverage of one instance's folded schedule.

    ``windows`` overrides the geometry-derived per-cycle B-windows
    (fb/ff only) so tests can seed corrupted schedules.
    """
    la = L.n_limbs_for_bits(bits_a)
    lb = L.n_limbs_for_bits(bits_b)
    where = f"{cfg.arch}(ct={cfg.ct}) {bits_a}x{bits_b}b"
    if cfg.arch == "star":
        return check_windows(la, lb, ((0, lb),), where)
    if cfg.arch in ("fb", "ff"):
        geo = fold_geometry(la, lb, cfg.ct, cfg.arch)
        wins = geo.b_windows if windows is None else tuple(windows)
        out = check_windows(la, lb, wins, where)
        if windows is None and geo.ct_run * geo.chunk < lb:
            out.append(Violation(
                "contracts", "grid-undercover", where,
                f"{geo.ct_run} grid steps x {geo.chunk}-limb chunks "
                f"cover only {geo.ct_run * geo.chunk} of {lb} B limbs"))
        return out
    if cfg.arch == "karatsuba":
        out = []
        n = max(la, lb)
        for level in range(cfg.levels):
            n += n % 2
            half = n // 2
            if half < 1:
                break
            out.extend(check_karatsuba_identity(
                half, f"{where} level {level}"))
            n = half + 1          # next level splits the shared-PPM port
        return out
    return [Violation("contracts", "unknown-arch", where,
                      f"no coverage model for arch {cfg.arch!r}")]


# ---------------------------------------------------------------- widths

def check_widths(bits_a: int, bits_b: int, cfg: MCIMConfig,
                 scratch_width=None, out_width=None) -> list:
    """Kernel scratch/out widths vs the interval analyzer's requirement.

    ``scratch_width``/``out_width`` override the geometry's declared
    values so tests can seed a scratch one column too narrow.
    """
    la = L.n_limbs_for_bits(bits_a)
    lb = L.n_limbs_for_bits(bits_b)
    where = f"{cfg.arch}(ct={cfg.ct}) {bits_a}x{bits_b}b"
    schedule = {"star": "fb", "fb": "fb", "ff": "ff",
                "karatsuba": "karatsuba"}.get(cfg.arch)
    if schedule is None:
        return [Violation("contracts", "unknown-arch", where,
                          f"no kernel geometry for arch {cfg.arch!r}")]
    ct = 1 if cfg.arch == "star" else (3 if cfg.arch == "karatsuba"
                                       else cfg.ct)
    geo = fold_geometry(la, lb, ct, schedule)
    declared_scratch = geo.scratch_width if scratch_width is None \
        else scratch_width
    declared_out = geo.out_width if out_width is None else out_width
    required = intervals.required_scratch_width(bits_a, bits_b, cfg,
                                                substrate="kernel")
    out = []
    if declared_scratch < required:
        out.append(Violation(
            "contracts", "scratch-too-narrow", where,
            f"declared scratch holds {declared_scratch} columns but the "
            f"interval analysis needs {required}: the compress would "
            f"silently truncate high columns"))
    if declared_out != la + lb:
        out.append(Violation(
            "contracts", "out-width", where,
            f"declared out width {declared_out} != product width "
            f"{la + lb}"))
    return out


# ----------------------------------------------------------------- fused

def check_fused_schedule(bits_a: int, bits_b: int, cfg: MCIMConfig,
                         windows=None) -> list:
    """Coverage of one instance's fused-megakernel window schedule.

    The fused datapath is a windowed schoolbook for EVERY arch
    (Karatsuba included: its CT=3 fused row is three B-windows, not the
    combine identity), so the bilinear-form check applies uniformly.
    ``windows`` overrides the geometry-derived schedule so tests can
    seed corrupted tables.
    """
    la = L.n_limbs_for_bits(bits_a)
    lb = L.n_limbs_for_bits(bits_b)
    where = f"fused {cfg.arch}(ct={cfg.ct}) {bits_a}x{bits_b}b"
    wins = fused_windows(cfg, la, lb) if windows is None else tuple(windows)
    return check_windows(la, lb, wins, where)


def check_fused_widths(bits_a: int, bits_b: int, cfg: MCIMConfig,
                       scratch_width=None, out_width=None) -> list:
    """Fused scratch/out widths vs the fused interval walk's requirement.

    Overrides let tests seed a scratch one column too narrow, the same
    silent-truncation bug class the per-instance widths contract
    rejects.
    """
    la = L.n_limbs_for_bits(bits_a)
    lb = L.n_limbs_for_bits(bits_b)
    where = f"fused {cfg.arch}(ct={cfg.ct}) {bits_a}x{bits_b}b"
    sg = super_geometry((cfg,), la, lb)
    declared_scratch = sg.scratch_width if scratch_width is None \
        else scratch_width
    declared_out = sg.out_width if out_width is None else out_width
    required = intervals.required_scratch_width(bits_a, bits_b, cfg,
                                                substrate="fused")
    out = []
    if declared_scratch < required:
        out.append(Violation(
            "contracts", "scratch-too-narrow", where,
            f"fused scratch holds {declared_scratch} columns but the "
            f"interval analysis needs {required}: the accumulator would "
            f"silently truncate high columns"))
    if declared_out != la + lb:
        out.append(Violation(
            "contracts", "out-width", where,
            f"fused out width {declared_out} != product width {la + lb}"))
    return out


def check_fused_plan(bits_a: int, bits_b: int, configs) -> list:
    """Bank-level contracts of the fused super-geometry.

    ``configs`` is the plan's ``(count, cfg)`` list.  Beyond the
    per-instance coverage/width contracts, the super-geometry itself
    promises: every padded row step beyond an instance's real fold is
    the idle mask ``(0, 0)`` (so heterogeneous CTs are architectural
    no-ops, not garbage accumulation), and the materialized SMEM table
    agrees entry-for-entry with the per-row windows the coverage proof
    ran over.
    """
    la = L.n_limbs_for_bits(bits_a)
    lb = L.n_limbs_for_bits(bits_b)
    flat = tuple(cfg for count, cfg in configs for _ in range(count))
    where = f"fused bank {bits_a}x{bits_b}b ({len(flat)} instances)"
    if not flat:
        return [Violation("contracts", "fused-empty-bank", where,
                          "fused launch needs at least one instance")]
    sg = super_geometry(flat, la, lb)
    out = []
    table = sg.table()
    for i, (cfg, geo) in enumerate(zip(sg.configs, sg.rows)):
        wins = sg.windows(i)
        if len(wins) != sg.max_steps:
            out.append(Violation(
                "contracts", "fused-row-length", where,
                f"instance {i} has {len(wins)} padded steps, grid "
                f"expects {sg.max_steps}"))
        for j in range(geo.ct_run, sg.max_steps):
            if wins[j] != (0, 0):
                out.append(Violation(
                    "contracts", "fused-idle-mask", where,
                    f"instance {i} idle step {j} is {wins[j]}, not the "
                    f"(0, 0) mask -- it would accumulate garbage"))
        for j, (lo, hi) in enumerate(wins):
            if tuple(table[i, j]) != (lo, hi):
                out.append(Violation(
                    "contracts", "fused-table-mismatch", where,
                    f"SMEM table[{i}, {j}] = {tuple(table[i, j])} "
                    f"differs from geometry window {(lo, hi)}"))
        if geo.scratch_width != sg.scratch_width or \
                geo.out_width != sg.out_width:
            out.append(Violation(
                "contracts", "fused-row-width", where,
                f"instance {i} declares scratch/out "
                f"{geo.scratch_width}/{geo.out_width}, super-geometry "
                f"shares {sg.scratch_width}/{sg.out_width}"))
    return out


# ------------------------------------------------------------ throughput

def check_throughput(configs, throughput, where: str = "plan") -> list:
    """Instance throughputs (count/CT each) must sum exactly to the
    plan's aggregate -- Fractions, no float slack."""
    achieved = sum((Fraction(count, cfg.ct) for count, cfg in configs),
                   Fraction(0))
    if achieved != Fraction(throughput):
        return [Violation(
            "contracts", "throughput-sum", where,
            f"instance throughputs sum to {achieved}, plan claims "
            f"{Fraction(throughput)}")]
    return []


# ------------------------------------------------------------ schedulers

#: (cts, n_ops) cases every registered scheduler is checked against;
#: mixes homogeneous, heterogeneous and degenerate banks
SCHEDULER_CASES = (
    ((1,), 0), ((1,), 7), ((2,), 5),
    ((1, 2), 9), ((1, 1, 1, 2), 11), ((2, 3), 8),
    ((1, 2, 3, 12), 25), ((12,), 3),
)


def check_scheduler(sched, cts: tuple, n_ops: int) -> list:
    """Determinism + completeness + makespan sanity of one policy."""
    where = f"scheduler {sched.name} cts={cts} n_ops={n_ops}"
    try:
        first = sched.schedule(cts, n_ops)
        second = sched.schedule(cts, n_ops)
    except Exception as e:                         # noqa: BLE001
        return [Violation("contracts", "scheduler-crash", where, repr(e))]
    out = []
    if first != second:
        out.append(Violation(
            "contracts", "scheduler-nondeterministic", where,
            "two identical calls returned different schedules; dispatch "
            "would recompile per call and break jit staticness"))
    assignment, makespan = first
    if len(assignment) != len(cts):
        out.append(Violation(
            "contracts", "scheduler-shape", where,
            f"{len(assignment)} instance lists for {len(cts)} instances"))
        return out
    flat = sorted(op for ops in assignment for op in ops)
    if flat != list(range(n_ops)):
        out.append(Violation(
            "contracts", "scheduler-coverage", where,
            f"assignment covers {flat[:8]}... not range({n_ops}) "
            f"exactly once"))
    busiest = max((len(ops) * ct for ops, ct in zip(assignment, cts)),
                  default=0)
    if makespan < busiest:
        out.append(Violation(
            "contracts", "scheduler-makespan", where,
            f"makespan {makespan} below the busiest instance's "
            f"{busiest} busy cycles"))
    if n_ops == 0 and makespan != 0:
        out.append(Violation(
            "contracts", "scheduler-makespan", where,
            f"empty batch reports makespan {makespan}"))
    return out


def check_all_schedulers(cases=SCHEDULER_CASES) -> list:
    out = []
    for sched in SCHEDULERS.values():
        for cts, n_ops in cases:
            out.extend(check_scheduler(sched, cts, n_ops))
    return out


# ---------------------------------------------------------- bank statics

def check_bank_static(plan, bits_a: int, bits_b: int,
                      backend: str = "core", batch: int = 8) -> list:
    """Prove ``Bank.dispatch_fn`` is a function of static shapes only.

    ``jax.eval_shape`` traces the dispatch closure with abstract values
    carrying shape/dtype but NO data: success means no Python control
    flow inspected operand values, and the output shape is the full
    product batch.  Assignment determinism across calls is checked via
    the scheduler contract; here we additionally diff the gather indices
    two independently-built dispatches close over.
    """
    import jax
    from repro.core.bank import Bank
    where = f"bank[{plan.describe()}] backend={backend}"
    try:
        bank = Bank(plan, bits_a, bits_b, backend=backend)
    except Exception as e:                         # noqa: BLE001
        return [Violation("contracts", "bank-construct", where, repr(e))]
    out = []
    a_spec = jax.ShapeDtypeStruct((batch, bank.la), L.LIMB_DTYPE)
    b_spec = jax.ShapeDtypeStruct((batch, bank.lb), L.LIMB_DTYPE)
    try:
        shape = jax.eval_shape(bank.dispatch_fn(batch), a_spec, b_spec)
    except Exception as e:                         # noqa: BLE001
        return out + [Violation(
            "contracts", "bank-not-traceable", where,
            f"dispatch_fn failed under eval_shape (operand-value "
            f"dependence or tracer leak): {e!r}")]
    if shape.shape != (batch, bank.la + bank.lb):
        out.append(Violation(
            "contracts", "bank-out-shape", where,
            f"dispatch returns {shape.shape}, expected "
            f"{(batch, bank.la + bank.lb)}"))
    assign1, _ = bank.scheduler.schedule(bank._cts, batch)
    assign2, _ = bank.scheduler.schedule(bank._cts, batch)
    if assign1 != assign2:
        out.append(Violation(
            "contracts", "bank-dispatch-unstable", where,
            "gather indices differ between two schedule calls for the "
            "same static batch"))
    return out


# ------------------------------------------------------------- aggregate

def check_plan(bits_a: int, bits_b: int, configs, throughput,
               substrates=("core", "kernel", "fused")) -> list:
    """Full contract sweep of one plan: throughput sum + per-instance
    coverage, widths and interval safety on every substrate, plus the
    fused super-geometry contracts when the fused substrate is swept."""
    out = list(check_throughput(configs, throughput))
    for _, cfg in configs:
        out.extend(check_coverage(bits_a, bits_b, cfg))
        out.extend(check_widths(bits_a, bits_b, cfg))
        for sub in substrates:
            if sub == "kernel" and cfg.signed:
                continue          # the kernel capability is unsigned-only
            rep = intervals.analyze(bits_a, bits_b, cfg, substrate=sub)
            out.extend(rep.violations)
        if "fused" in substrates:
            out.extend(check_fused_schedule(bits_a, bits_b, cfg))
            out.extend(check_fused_widths(bits_a, bits_b, cfg))
    if "fused" in substrates:
        out.extend(check_fused_plan(bits_a, bits_b, configs))
    return out
