"""One shared recursive jaxpr walker for every jaxpr-level analyzer.

Both ``launch.roofline.count_pallas_launches`` (the dispatch-tax metric)
and ``verify.dataflow`` (the static hazard/bounds/roofline analyzer)
need to find equations inside arbitrarily nested jaxprs: a jitted call
site wraps the program in a ``pjit`` equation whose body is a
ClosedJaxpr, ``lax.cond`` branches are ClosedJaxprs, ``scatter-add``
carries a raw update Jaxpr, and ``pallas_call`` holds the kernel body
as a raw Jaxpr.  The traversal rules for all of those live here, in
exactly one place -- an analyzer that re-implemented them would drift
the moment a jax upgrade moves a sub-jaxpr to a new param name.

``walk`` yields every equation reachable from a jaxpr; by default it
does NOT descend into ``pallas_call`` kernel bodies (launch counting
wants the host program only; the dataflow analyzer interprets kernel
bodies itself, step by step).
"""
from __future__ import annotations


def subjaxprs(eqn):
    """Every jaxpr nested in one equation's params (open or closed)."""
    for val in eqn.params.values():
        vals = val if isinstance(val, (tuple, list)) else (val,)
        for v in vals:
            inner = getattr(v, "jaxpr", None)   # ClosedJaxpr -> Jaxpr
            if inner is not None:
                yield inner
            elif hasattr(v, "eqns"):            # raw Jaxpr param
                yield v


def walk(jaxpr, into_pallas: bool = False):
    """Yield every equation in ``jaxpr`` and its nested jaxprs.

    Descends through pjit / closed-call / cond / scan bodies; kernel
    jaxprs inside ``pallas_call`` equations are skipped unless
    ``into_pallas`` (the host-program and kernel-body instruction
    streams are different machines and almost every analysis wants
    exactly one of them).
    """
    for eqn in jaxpr.eqns:
        yield eqn
        if eqn.primitive.name == "pallas_call" and not into_pallas:
            # still descend params OTHER than the kernel body (none
            # today, but the rule is: skip the kernel, not the eqn)
            kernel = eqn.params.get("jaxpr")
            for inner in subjaxprs(eqn):
                if inner is not kernel:
                    yield from walk(inner, into_pallas)
            continue
        for inner in subjaxprs(eqn):
            yield from walk(inner, into_pallas)


def count_primitive(jaxpr, name: str, into_pallas: bool = False) -> int:
    """Number of ``name`` equations reachable from ``jaxpr``."""
    return sum(1 for eqn in walk(jaxpr, into_pallas)
               if eqn.primitive.name == name)


def find_pallas_calls(jaxpr) -> list:
    """Every ``pallas_call`` equation reachable from ``jaxpr``."""
    return [eqn for eqn in walk(jaxpr)
            if eqn.primitive.name == "pallas_call"]
