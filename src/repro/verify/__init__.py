"""repro.verify: static design verification -- no execution required.

Four analyzers prove properties of every design the repo can generate:

  * :mod:`.intervals`  -- abstract interpretation of the limb pipeline:
    every uint32 carry-save column provably stays below 2**32, for the
    exact dataflow of each architecture on each substrate;
  * :mod:`.contracts`  -- schedule contracts: partial-product coverage
    (each a_i*b_j exactly once, Karatsuba combine as a polynomial
    identity), kernel scratch/out widths vs the proven requirement,
    Plan throughput sums, scheduler determinism/completeness, bank
    dispatch staticness under ``jax.eval_shape``;
  * :mod:`.dataflow`   -- jaxpr-level abstract interpretation of every
    Pallas launch a plan implies (with :mod:`.vmem`): hazard freedom
    over scratch/output refs, BlockSpec/window bounds, the per-step
    VMEM model and budget, and a static FLOPs/HBM-bytes roofline;
  * :mod:`.lint`       -- AST taint pass over the source tree flagging
    Python control flow on traced values, non-static scheduler state
    and interpret-mode environment reads outside the runtime shim.

``python -m repro.verify`` sweeps the full design registry plus the
autotuner's enumeration vocabulary and writes ``VERIFY_report.json``
(CI gates on its exit status).  ``designs.generate`` and
``autotune.search`` call :func:`assert_plan` at plan time, so a design
that cannot be proven safe errors before it ever executes.
"""
from __future__ import annotations

import functools

from . import intervals, contracts, lint
from .intervals import IntervalReport, Violation, analyze
from .contracts import (check_coverage, check_widths, check_throughput,
                        check_fused_schedule, check_fused_widths,
                        check_fused_plan, check_all_schedulers,
                        check_bank_static)
from .lint import lint_tree, lint_source

__all__ = [
    "intervals", "contracts", "lint", "dataflow", "vmem", "jaxpr_walk",
    "IntervalReport", "Violation", "VerificationError", "DataflowError",
    "analyze", "check_coverage", "check_widths", "check_throughput",
    "check_fused_schedule", "check_fused_widths", "check_fused_plan",
    "check_all_schedulers", "check_bank_static",
    "lint_tree", "lint_source",
    "verify_instance", "verify_plan", "assert_plan", "verify_design",
    "verify_plan_dataflow", "assert_plan_dataflow",
]

#: substrates swept per instance (kernel skipped for signed configs,
#: whose capability is core-only; fused handles signedness through the
#: bank-wide correction pass, so it is swept unconditionally)
_SUBSTRATES = ("core", "kernel", "fused")


class VerificationError(ValueError):
    """A design the static analyzers cannot prove safe.

    Raised by :func:`assert_plan` at plan time: the design never
    executes.  ``violations`` carries the structured findings.
    """

    def __init__(self, violations):
        self.violations = tuple(violations)
        lines = [v.describe() for v in self.violations]
        super().__init__(
            f"{len(lines)} verification violation(s):\n  " +
            "\n  ".join(lines))


class DataflowError(VerificationError):
    """A Pallas launch the dataflow analyzer cannot prove safe.

    Raised by :func:`assert_plan_dataflow`: a hazard, bounds, VMEM or
    window-table finding on the launches a plan implies.
    """


# after DataflowError: dataflow imports the class at raise time
from . import dataflow, jaxpr_walk, vmem            # noqa: E402
from .dataflow import verify_plan_dataflow          # noqa: E402


@functools.lru_cache(maxsize=4096)
def verify_instance(bits_a: int, bits_b: int, cfg) -> tuple:
    """All violations of one MCIMConfig at the given widths.

    Cached (MCIMConfig is frozen/hashable) so plan-time gating in
    ``generate()``/``search()`` costs one analysis per distinct design
    point per process, not one per call.
    """
    out = []
    out.extend(contracts.check_coverage(bits_a, bits_b, cfg))
    out.extend(contracts.check_widths(bits_a, bits_b, cfg))
    out.extend(contracts.check_fused_schedule(bits_a, bits_b, cfg))
    out.extend(contracts.check_fused_widths(bits_a, bits_b, cfg))
    for sub in _SUBSTRATES:
        if sub == "kernel" and cfg.signed:
            continue
        out.extend(intervals.analyze(bits_a, bits_b, cfg,
                                     substrate=sub).violations)
    return tuple(out)


def verify_plan(bits_a: int, bits_b: int, configs,
                throughput=None) -> tuple:
    """All violations of a plan: throughput sum + every instance + the
    fused super-geometry (idle-step masks, SMEM table consistency)."""
    out = []
    configs = tuple(configs)
    if throughput is not None:
        out.extend(contracts.check_throughput(configs, throughput))
    for _, cfg in configs:
        out.extend(verify_instance(bits_a, bits_b, cfg))
    out.extend(contracts.check_fused_plan(bits_a, bits_b, configs))
    return tuple(out)


def assert_plan(bits_a: int, bits_b: int, configs,
                throughput=None) -> None:
    """Raise :class:`VerificationError` unless the plan proves safe.

    The plan-time gate ``designs.generate`` / ``designs.compile_plan``
    and ``autotune.search`` run on every candidate before compiling or
    scoring it.
    """
    violations = verify_plan(bits_a, bits_b, configs, throughput)
    if violations:
        raise VerificationError(violations)


def assert_plan_dataflow(bits_a: int, bits_b: int, configs,
                         budget=None) -> None:
    """Raise :class:`DataflowError` unless every launch proves safe.

    The fourth plan-time gate: traces (never executes) the per-instance
    and fused Pallas launches the plan implies and rejects hazards,
    out-of-bounds windows/block indices and VMEM model/budget breaks.
    Results are cached per distinct launch geometry inside
    :mod:`.dataflow`, so repeated gating is cheap.
    """
    violations = dataflow.verify_plan_dataflow(bits_a, bits_b,
                                               tuple(configs),
                                               budget=budget)
    if violations:
        raise DataflowError(violations)


def verify_design(design) -> tuple:
    """All violations of a ``CompiledDesign`` (post-hoc checking)."""
    return verify_plan(design.spec.bits_a, design.spec.bits_b,
                       design.plan.configs, design.plan.throughput)
