"""Static per-grid-step VMEM byte model of a Pallas launch.

The paper equates a folded multiplier's "area" with the register/PPM
resources live per clock; the TPU analogue is the VMEM working set live
per grid step -- the operand blocks, the output block, every scratch
ref and the prefetched SMEM scalars.  Each kernel package *declares*
that figure (``vmem_bytes_per_step`` of its geometry module, carried on
the :class:`~repro.kernels.introspect.LaunchContract`); this module
measures the true figure from the traced launch and proves two rules:

  vmem-model   the declared model must dominate the measured *fold
               working set* (inputs + scratch + SMEM -- the state the
               folded datapath keeps live; fb/ff models equal it
               exactly, by construction from ``fold_geometry``)
  vmem-budget  the full per-step residency (fold working set + output
               block) must fit a configurable budget, default the TPU
               v5e per-core VMEM

A model that undercounts would let the autotuner's area/energy scoring
(and the paper-table reproduction built on it) silently flatter a
design; a budget overflow would fail at kernel compile time on real
hardware -- both are caught here at *plan* time, with no execution.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.verify.intervals import Violation

#: default per-step budget: one TPU v5e core's VMEM (16 MiB)
DEFAULT_VMEM_BUDGET = 16 * 2 ** 20

_ANALYZER = "dataflow"


@dataclasses.dataclass(frozen=True)
class VmemBreakdown:
    """Measured per-grid-step bytes of one launch, by ref class."""
    in_bytes: int
    out_bytes: int
    scratch_bytes: int
    smem_bytes: int

    @property
    def fold_bytes(self) -> int:
        """The folded datapath's live state (model-domination target)."""
        return self.in_bytes + self.scratch_bytes + self.smem_bytes

    @property
    def total_bytes(self) -> int:
        return self.fold_bytes + self.out_bytes

    def as_dict(self) -> dict:
        return {"in_bytes": self.in_bytes, "out_bytes": self.out_bytes,
                "scratch_bytes": self.scratch_bytes,
                "smem_bytes": self.smem_bytes,
                "total_bytes": self.total_bytes}


def _aval_bytes(aval) -> int:
    n = 1
    for d in aval.shape:
        n *= int(d)
    return n * np.dtype(aval.dtype).itemsize


def measure(eqn) -> VmemBreakdown:
    """Per-grid-step bytes of one traced ``pallas_call`` equation.

    Reads the kernel jaxpr's ref avals (block-shaped, i.e. already
    per-step) and classifies them with the grid mapping's operand
    counts -- the measured figure can therefore never disagree with
    what the kernel body actually addresses.
    """
    gm = eqn.params["grid_mapping"]
    avals = [v.aval for v in eqn.params["jaxpr"].invars]
    ni, nin = gm.num_index_operands, gm.num_inputs
    nout = gm.num_outputs
    smem = sum(_aval_bytes(a) for a in avals[:ni])
    inb = sum(_aval_bytes(a) for a in avals[ni:ni + nin])
    outb = sum(_aval_bytes(a) for a in avals[ni + nin:ni + nin + nout])
    scr = sum(_aval_bytes(a) for a in avals[ni + nin + nout:])
    return VmemBreakdown(in_bytes=inb, out_bytes=outb,
                         scratch_bytes=scr, smem_bytes=smem)


def check(breakdown: VmemBreakdown, model_bytes: int, where: str,
          budget: int = None) -> list:
    """Violations of the model-domination and budget rules."""
    if budget is None:
        budget = DEFAULT_VMEM_BUDGET
    out = []
    if model_bytes < breakdown.fold_bytes:
        out.append(Violation(
            _ANALYZER, "vmem-model", where,
            f"declared vmem_bytes_per_step {model_bytes} undercounts the "
            f"measured fold working set {breakdown.fold_bytes} "
            f"(in={breakdown.in_bytes} scratch={breakdown.scratch_bytes} "
            f"smem={breakdown.smem_bytes})"))
    if breakdown.total_bytes > budget:
        out.append(Violation(
            _ANALYZER, "vmem-budget", where,
            f"per-step residency {breakdown.total_bytes} B exceeds the "
            f"VMEM budget {budget} B"))
    return out
