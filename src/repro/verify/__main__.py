"""``python -m repro.verify``: sweep every generatable design and report.

Sections of the sweep (each contributes to ``VERIFY_report.json``):

  registry        every named design in ``repro.designs.registry``,
                  planned exactly as ``generate()`` plans it;
  vocabulary      every instance architecture the autotuner can emit
                  (star; fb/ff over the CT set; Karatsuba levels x
                  adders; signed variants) at widths 8..128, on both
                  substrates;
  decompositions  sample fractional TPs decomposed by
                  ``autotune.candidates.enumerate_configs``, every
                  candidate checked for throughput + instance safety;
  fused           bank-level fused-megakernel contracts of every
                  registry plan (super-geometry idle masks, SMEM table
                  consistency, window coverage, scratch domination);
  dataflow        jaxpr-level static proofs of every Pallas launch the
                  registry + vocabulary imply (both substrates): hazard
                  freedom, window/block bounds, VMEM model/budget, and
                  the static FLOPs/HBM roofline per launch;
  schedulers      determinism/completeness/makespan contracts of every
                  registered dispatch policy;
  bank            ``Bank.dispatch_fn`` staticness under eval_shape;
  lint            AST jit-safety pass over ``src/repro``.

Exit status 1 when any violation is found (the CI gate).  ``--smoke``
shrinks the width/TP grids for fast pre-merge runs; the full sweep is
the release gate.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import pathlib
import sys
from fractions import Fraction

from repro.core.mcim import MCIMConfig

from . import contracts, intervals, lint, verify_instance

FULL_WIDTHS = (8, 16, 24, 32, 48, 64, 96, 128)
SMOKE_WIDTHS = (8, 32, 128)
FULL_TPS = ("1/2", "1/3", "5/6", "11/12", "7/2")
SMOKE_TPS = ("1/2", "5/6")


def _vocabulary():
    """Every instance design the planner/autotuner can emit."""
    from repro.autotune.candidates import CT_SET, KARATSUBA_LEVELS
    vocab = [MCIMConfig(arch="star", ct=1)]
    for ct in CT_SET:
        vocab.append(MCIMConfig(arch="fb", ct=ct))
        vocab.append(MCIMConfig(arch="ff", ct=ct))
    for levels in KARATSUBA_LEVELS:
        for adder in ("1ca", "3ca"):
            vocab.append(MCIMConfig(arch="karatsuba", ct=3,
                                    levels=levels, adder=adder))
    vocab.extend(dataclasses.replace(cfg, signed=True) for cfg in list(vocab))
    return tuple(vocab)


def _cfg_label(cfg: MCIMConfig) -> str:
    parts = [cfg.arch, f"ct={cfg.ct}"]
    if cfg.arch == "karatsuba":
        parts.append(f"K={cfg.levels}")
    if cfg.adder != "1ca":
        parts.append(cfg.adder)
    if cfg.signed:
        parts.append("signed")
    return "(".join([parts[0], ",".join(parts[1:])]) + ")"


def _viol_json(v) -> dict:
    return dataclasses.asdict(v)


def sweep_registry() -> tuple:
    """Plan every registered design the way generate() would, verify."""
    from repro.designs import registry
    from repro.designs.compile import _plan_with_timing
    from . import VerificationError
    results, violations = [], []
    for name in sorted(registry.names()):
        spec = registry.get(name)
        try:
            plan, _ = _plan_with_timing(spec)
        except VerificationError as e:
            violations.extend(e.violations)
            results.append({"design": name, "ok": False,
                            "violations": len(e.violations)})
            continue
        entry = {"design": name, "ok": True,
                 "throughput": str(plan.throughput), "instances": []}
        for count, cfg in plan.configs:
            rep = intervals.analyze(spec.bits_a, spec.bits_b, cfg)
            entry["instances"].append({
                "config": _cfg_label(cfg), "count": count,
                "headroom_bits": rep.headroom_bits,
                "required_width": rep.required_width})
        results.append(entry)
    return results, violations


def sweep_vocabulary(widths) -> tuple:
    results, violations = [], []
    for w in widths:
        for cfg in _vocabulary():
            vs = verify_instance(w, w, cfg)
            violations.extend(vs)
            rep = intervals.analyze(w, w, cfg)
            results.append({
                "bits": w, "config": _cfg_label(cfg),
                "ok": not vs, "headroom_bits": rep.headroom_bits,
                "required_width": rep.required_width})
    return results, violations


def sweep_decompositions(tps, bits: int = 32) -> tuple:
    from repro.designs import DesignSpec
    from repro.autotune.candidates import enumerate_configs
    results, violations = [], []
    for tp in tps:
        spec = DesignSpec(bits, bits, Fraction(tp))
        n_checked = 0
        bad = 0
        for configs in enumerate_configs(spec):
            vs = list(contracts.check_throughput(configs, spec.throughput))
            for _, cfg in configs:
                vs.extend(verify_instance(bits, bits, cfg))
            n_checked += 1
            if vs:
                bad += 1
                violations.extend(vs)
        results.append({"tp": tp, "bits": bits,
                        "candidates": n_checked, "failing": bad})
    return results, violations


def sweep_fused() -> tuple:
    """Fused-megakernel contracts of every registry plan.

    Per design: the bank-level super-geometry promises (idle-step
    masks, SMEM table consistency, shared widths) plus the fused
    window coverage, scratch domination and interval walk of every
    instance -- the proof obligations of running that plan as ONE
    Pallas launch.  (The vocabulary sweep already covers fused
    per-instance checks width-by-width via ``verify_instance``.)
    """
    from repro.designs import registry
    from repro.designs.compile import _plan_with_timing
    from . import VerificationError
    results, violations = [], []
    for name in sorted(registry.names()):
        spec = registry.get(name)
        try:
            plan, _ = _plan_with_timing(spec)
        except VerificationError:
            continue                  # already reported by sweep_registry
        vs = list(contracts.check_fused_plan(spec.bits_a, spec.bits_b,
                                             plan.configs))
        worst = None
        for _, cfg in plan.configs:
            vs.extend(contracts.check_fused_schedule(
                spec.bits_a, spec.bits_b, cfg))
            vs.extend(contracts.check_fused_widths(
                spec.bits_a, spec.bits_b, cfg))
            rep = intervals.analyze(spec.bits_a, spec.bits_b, cfg,
                                    substrate="fused")
            vs.extend(rep.violations)
            if worst is None or rep.headroom_bits < worst:
                worst = rep.headroom_bits
        violations.extend(vs)
        results.append({"design": name, "ok": not vs,
                        "fused_headroom_bits": worst})
    return results, violations


def sweep_dataflow(widths) -> tuple:
    """Static dataflow proofs of every Pallas launch the repo can plan.

    Registry plans and the full autotuner vocabulary (both substrates:
    per-instance ``mcim_fold`` launches and the fused megakernel), the
    standalone kernels, and ragged/prime batch shapes through the
    tiler.  Per launch: hazard freedom, window/block bounds, the VMEM
    model/budget and the static roofline (``arith_intensity``).
    Distinct launch geometries are analyzed once (cached), so the sweep
    cost scales with geometry variety, not design count.
    """
    from repro.designs import registry
    from repro.designs.compile import _plan_with_timing
    from . import VerificationError, dataflow
    results, violations = [], []

    def plan_entry(bits_a, bits_b, configs):
        reps = []
        for substrate in ("kernel", "fused"):
            reps.extend(dataflow.analyze_plan(bits_a, bits_b, configs,
                                              substrate=substrate))
        vs = [v for rep in reps for v in rep.violations]
        return reps, vs

    for name in sorted(registry.names()):
        spec = registry.get(name)
        try:
            plan, _ = _plan_with_timing(spec)
        except VerificationError:
            continue              # already reported by sweep_registry
        reps, vs = plan_entry(spec.bits_a, spec.bits_b, plan.configs)
        violations.extend(vs)
        results.append({
            "design": name, "ok": not vs,
            "launches": [{
                "launch": r.name, "grid": list(r.grid),
                "flops": r.flops, "hbm_bytes": r.hbm_bytes,
                "arith_intensity": round(r.arith_intensity, 4),
                "vmem_total_bytes": r.vmem.get("total_bytes"),
                "ok": r.ok} for r in reps]})

    for w in widths:
        for cfg in _vocabulary():
            reps, vs = plan_entry(w, w, ((1, cfg),))
            violations.extend(vs)
            results.append({"bits": w, "config": _cfg_label(cfg),
                            "ok": not vs,
                            "launches": [r.name for r in reps]})

    for rep in dataflow.analyze_standalone():
        violations.extend(rep.violations)
        results.append({
            "launch": rep.name, "grid": list(rep.grid),
            "flops": rep.flops,
            "arith_intensity": round(rep.arith_intensity, 4),
            "ok": rep.ok})

    for batch, rep in zip(dataflow.RAGGED_BATCHES,
                          dataflow.analyze_tiling()):
        violations.extend(rep.violations)
        results.append({"launch": rep.name, "batch": batch,
                        "grid": list(rep.grid), "ok": rep.ok})
    return results, violations


def sweep_bank(bits: int = 32) -> tuple:
    from repro.core import planner
    violations = []
    for tp in (Fraction(7, 2), Fraction(5, 6)):
        plan = planner.plan_throughput(bits, bits, tp)
        violations.extend(contracts.check_bank_static(plan, bits, bits))
    return ([{"checked_plans": 2, "ok": not violations}], violations)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.verify",
        description="statically verify every generatable design")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced width/TP grids (the pre-merge CI gate)")
    ap.add_argument("--out", default="VERIFY_report.json",
                    help="report path (default: %(default)s)")
    args = ap.parse_args(argv)

    widths = SMOKE_WIDTHS if args.smoke else FULL_WIDTHS
    tps = SMOKE_TPS if args.smoke else FULL_TPS

    sections, all_violations = {}, []

    print(f"repro.verify sweep ({'smoke' if args.smoke else 'full'}): "
          f"widths {widths}, TPs {tps}")

    sections["registry"], vs = sweep_registry()
    all_violations.extend(vs)
    print(f"  registry:       {len(sections['registry'])} designs, "
          f"{len(vs)} violations")

    sections["vocabulary"], vs = sweep_vocabulary(widths)
    all_violations.extend(vs)
    print(f"  vocabulary:     {len(sections['vocabulary'])} design "
          f"points, {len(vs)} violations")

    sections["decompositions"], vs = sweep_decompositions(tps)
    all_violations.extend(vs)
    n_cand = sum(r["candidates"] for r in sections["decompositions"])
    print(f"  decompositions: {n_cand} candidates, {len(vs)} violations")

    sections["fused"], vs = sweep_fused()
    all_violations.extend(vs)
    print(f"  fused:          {len(sections['fused'])} plans as one "
          f"launch, {len(vs)} violations")

    sections["dataflow"], vs = sweep_dataflow(widths)
    all_violations.extend(vs)
    print(f"  dataflow:       {len(sections['dataflow'])} launch "
          f"points, {len(vs)} violations")

    # the serving package registers its slo_edf policy at import: pull
    # it in before the sweep so an unverifiable serving scheduler fails
    # CI here (and is therefore unplannable)
    import repro.serving  # noqa: F401
    from repro.core.bank.schedule import SCHEDULERS
    vs = contracts.check_all_schedulers()
    sections["schedulers"] = [{"cases": len(contracts.SCHEDULER_CASES),
                               "policies": sorted(SCHEDULERS),
                               "ok": not vs}]
    all_violations.extend(vs)
    print(f"  schedulers:     {len(contracts.SCHEDULER_CASES)} cases x "
          f"{len(SCHEDULERS)} policies, {len(vs)} violations")

    sections["bank"], vs = sweep_bank()
    all_violations.extend(vs)
    print(f"  bank statics:   {len(vs)} violations")

    import repro
    src_root = pathlib.Path(repro.__file__).parent
    vs = lint.lint_tree(src_root)
    sections["lint"] = [{"root": str(src_root), "ok": not vs}]
    all_violations.extend(vs)
    print(f"  lint:           {src_root}, {len(vs)} violations")

    report = {
        "smoke": args.smoke,
        "widths": list(widths),
        "summary": {
            "sections": {k: len(v) for k, v in sections.items()},
            "violations": len(all_violations),
            "ok": not all_violations,
        },
        "violations": [_viol_json(v) for v in all_violations],
        **sections,
    }
    out_path = pathlib.Path(args.out)
    out_path.write_text(json.dumps(report, indent=2, sort_keys=True))
    print(f"report: {out_path}")

    if all_violations:
        print(f"FAIL: {len(all_violations)} violation(s)")
        for v in all_violations[:20]:
            print(f"  {v.describe()}")
        if len(all_violations) > 20:
            print(f"  ... and {len(all_violations) - 20} more")
        return 1
    print("OK: every design proved overflow-safe and contract-conformant")
    return 0


if __name__ == "__main__":
    sys.exit(main())
