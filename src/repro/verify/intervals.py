"""Interval / overflow analysis: prove every uint32 column stays < 2**32.

The repo's arithmetic discipline (core.limbs module docstring) rests on
one invariant: carry-save column sums accumulated in uint32 lanes never
overflow.  Until now that invariant lived in a comment; this module is
an *abstract interpreter* over the limb pipeline that proves it per
design, symbolically in (bits_a, bits_b, CT, schedule) -- no execution.

The abstract domain is a vector of per-column worst-case magnitudes
(exact Python ints, so no precision is lost at any width).  Each
analysis mirrors one architecture's dataflow step by step:

  * ``ppm`` scatters lo/hi product halves -> per-column sums of
    ``min(amax*bmax, MASK)`` / ``(amax*bmax) >> 16`` contributions;
  * ``compress`` adds bound vectors (uint32 addition of non-negative
    terms overflows iff the final bound does, so one check suffices);
  * the final adders thread a worst-case carry through the column walk,
    checking ``col + carry < 2**32`` at every position -- the exact
    uint32 expression the 1CA/3CA scans and the Pallas kernels compute.

``analyze(bits_a, bits_b, cfg, substrate)`` walks the full design --
core (pure-jnp mcim_mul) or kernel (Pallas mcim_fold) dataflow, the
Karatsuba NOT+1 subtraction columns and recursive sub-PPMs included --
and returns an :class:`IntervalReport` with the worst column bound, the
headroom in bits, and the accumulator width the design *requires*
(checked against the kernel's declared scratch by
:mod:`repro.verify.contracts`).

Soundness: every abstract op maps bound vectors to bound vectors that
dominate the concrete columns for ALL operand values of the given
widths (monotonicity of +, *, >>, and min against MASK); the property
suite in tests/test_verify.py additionally samples random batches and
checks domination empirically.
"""
from __future__ import annotations

import dataclasses
import math

from repro.core import limbs as L
from repro.core.mcim import MCIMConfig
from repro.kernels.mcim_fold import fold_geometry
# geometry module directly: keeps verify import-light (no Pallas pull-in)
from repro.kernels.bank_fold.geometry import fused_windows

U32_MAX = L.U32_MAX

#: execution substrates a design can be proven for (cf. bank.backends)
SUBSTRATES = ("core", "kernel", "fused")


@dataclasses.dataclass(frozen=True)
class Violation:
    """One provable-unsafety finding (shared by all three analyzers)."""
    analyzer: str             # intervals | contracts | lint
    rule: str                 # e.g. "u32-overflow", "double-cover"
    where: str                # pipeline site, e.g. "fb(ct=2) cycle 1"
    detail: str

    def describe(self) -> str:
        return f"[{self.analyzer}/{self.rule}] {self.where}: {self.detail}"


@dataclasses.dataclass(frozen=True)
class IntervalReport:
    """Overflow-safety verdict for one (widths, config, substrate)."""
    bits_a: int
    bits_b: int
    config: MCIMConfig
    substrate: str
    ok: bool
    max_column: int           # worst bound over every intermediate column
    headroom_bits: float      # 32 - log2(max_column)
    required_width: int       # accumulator columns the design needs
    violations: tuple

    def describe(self) -> str:
        tag = "proved" if self.ok else "OVERFLOW"
        return (f"{tag} {self.config.arch}(ct={self.config.ct}) "
                f"{self.bits_a}x{self.bits_b}b [{self.substrate}]: "
                f"max column 2^{math.log2(self.max_column):.1f}, "
                f"headroom {self.headroom_bits:.1f} bits, "
                f"width {self.required_width}")


class _Ctx:
    """Violation collector tracking the worst column bound seen."""

    def __init__(self):
        self.violations = []
        self.max_seen = 1

    def note(self, bounds) -> None:
        m = max(bounds, default=0)
        if m > self.max_seen:
            self.max_seen = m

    def check(self, bounds, where: str) -> None:
        self.note(bounds)
        for k, bound in enumerate(bounds):
            if bound > U32_MAX:
                self.violations.append(Violation(
                    analyzer="intervals", rule="u32-overflow", where=where,
                    detail=f"column {k} bound {bound} = "
                           f"2^{math.log2(bound):.2f} exceeds uint32"))


# --------------------------------------------------------------- domain ops

def operand_bounds(bits: int) -> list:
    """Per-limb worst-case values of a ``bits``-bit canonical operand."""
    n = L.n_limbs_for_bits(bits)
    out = [L.MASK] * n
    rem = bits - (n - 1) * L.RADIX_BITS
    out[-1] = (1 << rem) - 1
    return out


def canonical_bounds(width: int) -> list:
    """Bounds of a normalized (post-final-adder) limb vector."""
    return [L.MASK] * width


def ppm_bounds(amax, bmax) -> list:
    """Abstract ``limbs.ppm``: column bounds of the lo/hi scatter."""
    la, lb = len(amax), len(bmax)
    cols = [0] * (la + lb)
    for i in range(la):
        for j in range(lb):
            p = amax[i] * bmax[j]
            cols[i + j] += min(p, L.MASK)           # lo half
            cols[i + j + 1] += p >> L.RADIX_BITS    # hi half
    return cols


def compress_bounds(terms, width: int, ctx: _Ctx, where: str) -> list:
    """Abstract ``limbs.compress``: shifted addition of bound vectors.

    uint32 addition of non-negative terms is monotone, so intermediate
    partial sums are dominated by the final bound -- one check covers
    the whole reduction.
    """
    acc = [0] * width
    for bounds, shift in terms:
        take = min(len(bounds), width - shift)
        for k in range(max(take, 0)):
            acc[shift + k] += bounds[k]
    ctx.check(acc, where)
    return acc


def adder_bounds(cols, out_limbs: int, ctx: _Ctx, where: str) -> list:
    """Abstract final adder (1CA and 3CA share the carry recurrence).

    Threads the worst-case carry through the column walk and checks the
    uint32 expression ``tot = col + carry`` at every position -- the
    overflow surface of final_adder_1ca/_3ca, the kernels' unrolled
    carry loops, and _kara_carry alike.  Returns canonical bounds.
    """
    carry = 0
    width = len(cols)
    for k in range(max(width, out_limbs)):
        col = cols[k] if k < width else 0
        tot = col + carry
        if tot > U32_MAX:
            ctx.violations.append(Violation(
                analyzer="intervals", rule="u32-overflow", where=where,
                detail=f"final-adder column {k}: col {col} + carry "
                       f"{carry} = {tot} exceeds uint32"))
        if tot > ctx.max_seen:
            ctx.max_seen = tot
        carry = tot >> L.RADIX_BITS
    return canonical_bounds(out_limbs)


def negate_bounds(width: int) -> tuple:
    """Abstract ``limbs.negate_cols``: (NOT columns, +1 correction)."""
    inv = [L.MASK] * width            # MASK - placed <= MASK columnwise
    one = [1] + [0] * (width - 1)
    return inv, one


# ------------------------------------------------------- architecture walks

def _fb_walk(amax, bmax, geo, adder, ctx):
    """FB dataflow (core feedback_mul == kernel _fb_kernel bounds)."""
    la, chunk = len(amax), geo.chunk
    width = la + chunk + 1
    r = [0] * width                                  # acc starts zeroed
    for t, (lo, hi) in enumerate(geo.b_windows):
        bchunk = [bmax[j] if j < len(bmax) else 0 for j in range(lo, hi)]
        shifted = r[chunk:] + [0] * chunk            # feedback >> chunk
        cols = ppm_bounds(amax, bchunk)
        acc = compress_bounds([(cols, 0), (shifted, 0)], width, ctx,
                              f"fb cycle {t} compressor")
        r = adder_bounds(acc, width, ctx, f"fb cycle {t} final adder")
    return width


def _ff_walk(amax, bmax, geo, adder, ctx):
    """FF dataflow: register file accumulation, one final-adder pass."""
    la, chunk = len(amax), geo.chunk
    width = la + geo.ct_run * chunk + 1
    terms = []
    for t, (lo, hi) in enumerate(geo.b_windows):
        bchunk = [bmax[j] if j < len(bmax) else 0 for j in range(lo, hi)]
        terms.append((ppm_bounds(amax, bchunk), t * chunk))
    acc = compress_bounds(terms, width, ctx, "ff register file")
    adder_bounds(acc, len(amax) + len(bmax), ctx, "ff final adder")
    return width


def _half_sum_bounds(x0, x1, out, ctx, where):
    """Abstract ``add_canonical(x0, x1, out)`` (the A0+A1 port sums)."""
    width = max(len(x0), len(x1)) + 1
    acc = compress_bounds([(x0, 0), (x1, 0)], width, ctx, where)
    return adder_bounds(acc, out, ctx, where)


def _kara_ppm_walk(amax, bmax, levels, ctx, depth=0):
    """Abstract ``karatsuba.karatsuba_ppm`` recursion -> column bounds."""
    la, lb = len(amax), len(bmax)
    if levels == 0 or la <= 1 or lb <= 1:
        cols = ppm_bounds(amax, bmax)
        ctx.check(cols, f"karatsuba L{depth} schoolbook PPM")
        return cols
    n = max(la, lb)
    n += n % 2
    half = n // 2
    pad = lambda x: x + [0] * (n - len(x))
    a0, a1 = pad(amax)[:half], pad(amax)[half:]
    b0, b1 = pad(bmax)[:half], pad(bmax)[half:]
    w = f"karatsuba L{depth}"
    sa = _half_sum_bounds(a0, a1, half + 1, ctx, f"{w} A0+A1")
    sb = _half_sum_bounds(b0, b1, half + 1, ctx, f"{w} B0+B1")
    width = la + lb
    t0 = adder_bounds(_kara_ppm_walk(a0, b0, levels - 1, ctx, depth + 1),
                      2 * half, ctx, f"{w} T0 normalize")
    t1 = adder_bounds(_kara_ppm_walk(a1, b1, levels - 1, ctx, depth + 1),
                      2 * half, ctx, f"{w} T1 normalize")
    t2 = adder_bounds(_kara_ppm_walk(sa, sb, levels - 1, ctx, depth + 1),
                      2 * half + 2, ctx, f"{w} T2 normalize")
    neg0, one0 = negate_bounds(width)
    neg1, one1 = negate_bounds(width)
    return compress_bounds(
        [(t0, 0), (t1, 2 * half), (t2, half),
         (neg0, 0), (one0, 0), (neg1, 0), (one1, 0)],
        width, ctx, f"{w} combine compressor")


def _kara_core_walk(amax, bmax, levels, adder, ctx):
    """Core ``karatsuba_mul``: CT=3 scan + compressor feedback."""
    la, lb = len(amax), len(bmax)
    n = max(la, lb)
    n += n % 2
    half = n // 2
    pad = lambda x: x + [0] * (n - len(x))
    a0, a1 = pad(amax)[:half], pad(amax)[half:]
    b0, b1 = pad(bmax)[:half], pad(bmax)[half:]
    sa = _half_sum_bounds(a0, a1, half + 1, ctx, "kara top A0+A1")
    sb = _half_sum_bounds(b0, b1, half + 1, ctx, "kara top B0+B1")
    width = la + lb
    acc = [0] * width
    pairs = ((a0, b0, "T0"), (a1, b1, "T1"), (sa, sb, "T2"))
    for av, bv, name in pairs:
        cols = _kara_ppm_walk(list(av), list(bv), levels - 1, ctx)
        t = adder_bounds(cols, 2 * half + 2, ctx, f"kara top {name}")
        neg, one = negate_bounds(width)
        if name == "T2":
            contrib = compress_bounds([(t, half)], width, ctx,
                                      f"kara top place {name}")
        else:
            shift = 0 if name == "T0" else 2 * half
            contrib = compress_bounds([(t, shift), (neg, 0), (one, 0)],
                                      width, ctx, f"kara top place {name}")
        acc = [x + y for x, y in zip(acc, contrib)]
        ctx.check(acc, f"kara top feedback after {name}")
    adder_bounds(acc, width, ctx, "kara top final adder")
    return width


def _kara_kernel_walk(amax, bmax, ctx):
    """Pallas ``_kara_kernel``: scratch accumulator + NOT+1 columns."""
    la, lb = len(amax), len(bmax)
    geo = fold_geometry(la, lb, 3, "karatsuba")
    width = geo.scratch_width                        # 2 * n
    n = width // 2
    half = n // 2
    hp = half + 1
    pad = lambda x: x + [0] * (n - len(x))
    a0, a1 = pad(amax)[:half], pad(amax)[half:]
    b0, b1 = pad(bmax)[:half], pad(bmax)[half:]
    # _kara_carry(a0 + a1, hp): raw column sums then carry walk
    sums_a = [x + y for x, y in zip(a0, a1)]
    sums_b = [x + y for x, y in zip(b0, b1)]
    ctx.check(sums_a, "kara kernel A0+A1 columns")
    ctx.check(sums_b, "kara kernel B0+B1 columns")
    adder_bounds(sums_a, hp, ctx, "kara kernel A0+A1 carry")
    adder_bounds(sums_b, hp, ctx, "kara kernel B0+B1 carry")
    # worst cycle operands: canonical hp-limb ports (covers a0p/a1p/sa)
    port = canonical_bounds(hp)
    cols = ppm_bounds(port, port)[:2 * hp]
    ctx.check(cols, "kara kernel shared PPM")
    t = adder_bounds(cols, 2 * hp, ctx, "kara kernel T normalize")

    def place(shift):
        take = min(2 * hp, width - shift)
        return [0] * shift + t[:take] + [0] * (width - shift - take)

    def neg_place():
        out = [L.MASK] * width
        out[0] += 1                                  # the +1 correction
        return out

    acc = [x + y for x, y in zip(place(0), neg_place())]          # j=0
    ctx.check(acc, "kara kernel feedback j=0")
    acc = [x + y + z for x, y, z in zip(acc, place(2 * half),
                                        neg_place())]             # j=1
    ctx.check(acc, "kara kernel feedback j=1")
    acc = [x + y for x, y in zip(acc, place(half))]               # j=2
    ctx.check(acc, "kara kernel feedback j=2")
    adder_bounds(acc, la + lb, ctx, "kara kernel final carry")
    return width


def _star_walk(amax, bmax, adder, ctx):
    cols = ppm_bounds(amax, bmax)
    ctx.check(cols, "star PPM")
    adder_bounds(cols, len(amax) + len(bmax), ctx, "star final adder")
    return len(amax) + len(bmax)


def _fused_walk(amax, bmax, cfg, ctx):
    """Fused bank megakernel dataflow (``kernels.bank_fold``).

    Every arch runs the same windowed-schoolbook datapath there: grid
    step t masks B to its ``fused_windows`` limb range, the masked PPM
    columns land at absolute positions in the full-width carry-save
    accumulator (no per-step shift), and one final carry pass retires
    the product on the last step.  Idle padded steps have empty windows
    and contribute exactly zero, so checking the real windows covers
    the padded super-geometry row.
    """
    la, lb = len(amax), len(bmax)
    width = la + lb
    acc = [0] * width
    for t, (lo, hi) in enumerate(fused_windows(cfg, la, lb)):
        bm = [bmax[j] if lo <= j < hi else 0 for j in range(lb)]
        cols = ppm_bounds(amax, bm)
        acc = [x + y for x, y in zip(acc, cols)]
        ctx.check(acc, f"fused step {t} accumulator")
    adder_bounds(acc, width, ctx, "fused final carry")
    return width


def _signed_walk(la, lb, ctx):
    """The _signed_mul correction pass on top of the unsigned product."""
    width = la + lb
    prod = canonical_bounds(width)
    nb, ob = negate_bounds(width)
    na, oa = negate_bounds(width)
    acc = compress_bounds([(prod, 0), (nb, 0), (ob, 0), (na, 0), (oa, 0)],
                          width, ctx, "signed correction compressor")
    adder_bounds(acc, width, ctx, "signed correction final adder")


# ----------------------------------------------------------------- analyze

def analyze(bits_a: int, bits_b: int, cfg: MCIMConfig,
            substrate: str = "core") -> IntervalReport:
    """Prove (or refute) overflow-safety of one design on one substrate.

    Walks the exact dataflow ``mcim_mul`` (substrate="core"), the
    ``mcim_fold`` Pallas kernels (substrate="kernel") or the
    ``bank_fold`` megakernel (substrate="fused") execute for a
    ``bits_a x bits_b`` multiply under ``cfg``, propagating worst-case
    per-column magnitudes.  ``required_width`` is the accumulator width
    the walk needed -- the figure the scratch contract checks against.
    """
    if substrate not in SUBSTRATES:
        raise ValueError(f"substrate must be one of {SUBSTRATES}")
    amax = operand_bounds(bits_a)
    bmax = operand_bounds(bits_b)
    la, lb = len(amax), len(bmax)
    ctx = _Ctx()
    if substrate == "fused":
        required = _fused_walk(amax, bmax, cfg, ctx)
    elif cfg.arch == "star":
        required = _star_walk(amax, bmax, cfg.adder, ctx)
    elif cfg.arch == "fb":
        geo = fold_geometry(la, lb, cfg.ct, "fb")
        required = _fb_walk(amax, bmax, geo, cfg.adder, ctx)
    elif cfg.arch == "ff":
        geo = fold_geometry(la, lb, cfg.ct, "ff")
        required = _ff_walk(amax, bmax, geo, cfg.adder, ctx)
    elif cfg.arch == "karatsuba":
        if substrate == "kernel":
            # the kernel realizes Karat-1 regardless of cfg.levels
            required = _kara_kernel_walk(amax, bmax, ctx)
        else:
            required = _kara_core_walk(amax, bmax, cfg.levels, cfg.adder,
                                       ctx)
    else:
        raise ValueError(f"unknown arch {cfg.arch!r}")
    if cfg.signed:
        _signed_walk(la, lb, ctx)
    headroom = 32.0 - math.log2(max(ctx.max_seen, 1))
    return IntervalReport(
        bits_a=bits_a, bits_b=bits_b, config=cfg, substrate=substrate,
        ok=not ctx.violations, max_column=ctx.max_seen,
        headroom_bits=round(headroom, 3), required_width=required,
        violations=tuple(ctx.violations))


def required_scratch_width(bits_a: int, bits_b: int, cfg: MCIMConfig,
                           substrate: str = "kernel") -> int:
    """Accumulator width the interval walk proves the design needs."""
    return analyze(bits_a, bits_b, cfg, substrate).required_width
