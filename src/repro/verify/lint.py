"""jit-safety lint: AST taint analysis over the repro source tree.

The whole repo rests on an unwritten rule: anything a jitted function
computes from its *traced* operands must stay inside jnp/lax -- a Python
``if``/``for``/``int()`` on a traced value either raises a
ConcretizationTypeError at trace time or, worse, silently bakes one
concrete value into the compiled program.  This pass makes the rule
checkable: it walks every module under ``src/repro``, marks traced
parameters, propagates taint through assignments, and flags the
constructs that leak traced values into Python control flow.

What counts as *traced*:

  * parameters of a ``@jax.jit`` / ``functools.partial(jax.jit,
    static_argnames=(...))`` function that are NOT listed static;
  * any parameter annotated ``jax.Array`` (the repo's convention for
    array-path functions, jitted by their callers).

What launders taint back to static:

  * the static metadata attributes ``shape`` / ``ndim`` / ``size`` /
    ``dtype`` (compile-time constants under tracing);
  * ``len(x)`` (always the static leading dim).

Rules:

``traced-branch``     ``if``/``while`` whose test involves a traced value
``traced-ternary``    conditional expression on a traced value
``traced-assert``     ``assert`` on a traced value
``traced-loop``       ``for`` iterating over a traced value
``python-int-cast``   ``int()``/``float()``/``bool()`` of a traced value
``scheduler-state``   a ``Scheduler.schedule`` method writing ``self``
                      attributes -- per-call state breaks the static
                      (cts, n_ops) -> assignment contract the bank's
                      jitted dispatch relies on
``interpret-env``     reading the ``REPRO_INTERPRET`` /
                      ``REPRO_PALLAS_INTERPRET`` environment variables
                      anywhere but ``kernels/runtime.py`` -- the one
                      shim that owns interpret-mode resolution; a
                      second reader can disagree with it mid-process
                      and silently mix compiled and interpreted
                      launches
"""
from __future__ import annotations

import ast
import pathlib

from .intervals import Violation

#: attribute reads on a traced array that are static under tracing
STATIC_ATTRS = frozenset({"shape", "ndim", "size", "dtype"})
#: builtins that force a Python scalar out of a traced value
_CASTS = frozenset({"int", "float", "bool"})


def _is_jax_jit(node: ast.expr) -> bool:
    """Matches ``jax.jit`` or bare ``jit`` in an expression position."""
    if isinstance(node, ast.Attribute) and node.attr == "jit":
        return True
    return isinstance(node, ast.Name) and node.id == "jit"


def _jit_static_names(dec: ast.expr):
    """If ``dec`` is a jit decorator, return its static_argnames set
    (empty for plain ``@jax.jit``); else None."""
    if _is_jax_jit(dec):
        return frozenset()
    if isinstance(dec, ast.Call):
        if _is_jax_jit(dec.func):
            return _literal_names(dec.keywords, "static_argnames")
        # functools.partial(jax.jit, static_argnames=(...))
        if isinstance(dec.func, ast.Attribute) and \
                dec.func.attr == "partial" and dec.args and \
                _is_jax_jit(dec.args[0]):
            return _literal_names(dec.keywords, "static_argnames")
    return None


def _literal_names(keywords, key: str) -> frozenset:
    for kw in keywords:
        if kw.arg == key:
            try:
                val = ast.literal_eval(kw.value)
            except (ValueError, SyntaxError):
                return frozenset()
            if isinstance(val, str):
                return frozenset({val})
            return frozenset(v for v in val if isinstance(v, str))
    return frozenset()


def _is_jax_array_annotation(ann) -> bool:
    if ann is None:
        return False
    if isinstance(ann, ast.Attribute) and ann.attr == "Array":
        return True
    if isinstance(ann, ast.Constant) and isinstance(ann.value, str):
        return ann.value.replace(" ", "").endswith("jax.Array")
    return False


def _traced_params(fn: ast.FunctionDef) -> set:
    """Parameter names of ``fn`` that carry traced arrays."""
    static = None
    for dec in fn.decorator_list:
        names = _jit_static_names(dec)
        if names is not None:
            static = names
            break
    traced = set()
    args = fn.args
    for a in list(args.posonlyargs) + list(args.args) + \
            list(args.kwonlyargs):
        if static is not None:
            if a.arg not in static and a.arg != "self":
                traced.add(a.arg)
        elif _is_jax_array_annotation(a.annotation):
            traced.add(a.arg)
    return traced


class _TaintWalker(ast.NodeVisitor):
    """One function body: propagate taint, record rule violations."""

    def __init__(self, path: str, fn: ast.FunctionDef):
        self.path = path
        self.fn = fn
        self.tainted = _traced_params(fn)
        self.violations = []

    # ------------------------------------------------------ taint queries
    def _expr_tainted(self, node) -> bool:
        """Does evaluating ``node`` yield a traced value?"""
        if node is None:
            return False
        if isinstance(node, ast.Name):
            return node.id in self.tainted
        if isinstance(node, ast.Attribute):
            if node.attr in STATIC_ATTRS:
                return False              # static metadata launders taint
            return self._expr_tainted(node.value)
        if isinstance(node, ast.Subscript):
            return self._expr_tainted(node.value)
        if isinstance(node, ast.Call):
            fname = node.func
            if isinstance(fname, ast.Name) and fname.id == "len":
                return False              # len() is the static batch dim
            parts = [node.func] + list(node.args) + \
                [kw.value for kw in node.keywords]
            return any(self._expr_tainted(p) for p in parts)
        if isinstance(node, (ast.BinOp,)):
            return self._expr_tainted(node.left) or \
                self._expr_tainted(node.right)
        if isinstance(node, ast.UnaryOp):
            return self._expr_tainted(node.operand)
        if isinstance(node, ast.BoolOp):
            return any(self._expr_tainted(v) for v in node.values)
        if isinstance(node, ast.Compare):
            return self._expr_tainted(node.left) or \
                any(self._expr_tainted(c) for c in node.comparators)
        if isinstance(node, (ast.Tuple, ast.List, ast.Set)):
            return any(self._expr_tainted(e) for e in node.elts)
        if isinstance(node, ast.IfExp):
            return (self._expr_tainted(node.body) or
                    self._expr_tainted(node.orelse) or
                    self._expr_tainted(node.test))
        if isinstance(node, ast.Starred):
            return self._expr_tainted(node.value)
        return False

    def _flag(self, rule: str, node, detail: str) -> None:
        self.violations.append(Violation(
            "lint", rule,
            f"{self.path}:{node.lineno} in {self.fn.name}", detail))

    # ------------------------------------------------- taint propagation
    def _assign_targets(self, target, tainted: bool) -> None:
        if isinstance(target, ast.Name):
            if tainted:
                self.tainted.add(target.id)
            else:
                self.tainted.discard(target.id)
        elif isinstance(target, (ast.Tuple, ast.List)):
            for elt in target.elts:
                self._assign_targets(elt, tainted)
        # subscript/attribute targets mutate an existing binding: the
        # base name's taint already reflects it

    def visit_Assign(self, node: ast.Assign) -> None:
        tainted = self._expr_tainted(node.value)
        for t in node.targets:
            self._assign_targets(t, tainted)
        self.generic_visit(node)

    def visit_AugAssign(self, node: ast.AugAssign) -> None:
        if self._expr_tainted(node.value):
            self._assign_targets(node.target, True)
        self.generic_visit(node)

    def visit_AnnAssign(self, node: ast.AnnAssign) -> None:
        if node.value is not None:
            self._assign_targets(node.target,
                                 self._expr_tainted(node.value))
        self.generic_visit(node)

    # ------------------------------------------------------------- rules
    def visit_If(self, node: ast.If) -> None:
        if self._expr_tainted(node.test):
            self._flag("traced-branch", node,
                       "`if` on a traced value: trace-time "
                       "ConcretizationTypeError (or a silently baked-in "
                       "constant); use jnp.where / lax.cond")
        self.generic_visit(node)

    def visit_While(self, node: ast.While) -> None:
        if self._expr_tainted(node.test):
            self._flag("traced-branch", node,
                       "`while` on a traced value; use lax.while_loop")
        self.generic_visit(node)

    def visit_IfExp(self, node: ast.IfExp) -> None:
        if self._expr_tainted(node.test):
            self._flag("traced-ternary", node,
                       "conditional expression on a traced value; use "
                       "jnp.where")
        self.generic_visit(node)

    def visit_Assert(self, node: ast.Assert) -> None:
        if self._expr_tainted(node.test):
            self._flag("traced-assert", node,
                       "assert on a traced value; use "
                       "checkify or a shape/static assert")
        self.generic_visit(node)

    def visit_For(self, node: ast.For) -> None:
        if self._expr_tainted(node.iter):
            self._flag("traced-loop", node,
                       "Python `for` over a traced value unrolls (or "
                       "fails) at trace time; use lax.scan/fori_loop")
        self.generic_visit(node)

    def visit_Call(self, node: ast.Call) -> None:
        if isinstance(node.func, ast.Name) and node.func.id in _CASTS \
                and node.args and self._expr_tainted(node.args[0]):
            self._flag("python-int-cast", node,
                       f"{node.func.id}() forces a traced value to a "
                       f"Python scalar at trace time")
        self.generic_visit(node)

    # nested defs get their own walker; don't descend with parent taint
    def visit_FunctionDef(self, node: ast.FunctionDef) -> None:
        if node is not self.fn:
            return
        self.generic_visit(node)

    visit_AsyncFunctionDef = visit_FunctionDef


def _scheduler_state_writes(tree: ast.Module, path: str) -> list:
    """Flag ``self.x = ...`` inside any ``schedule`` method."""
    out = []
    for cls in ast.walk(tree):
        if not isinstance(cls, ast.ClassDef):
            continue
        for fn in cls.body:
            if not isinstance(fn, ast.FunctionDef) or \
                    fn.name != "schedule":
                continue
            for node in ast.walk(fn):
                targets = []
                if isinstance(node, ast.Assign):
                    targets = node.targets
                elif isinstance(node, (ast.AugAssign, ast.AnnAssign)):
                    targets = [node.target]
                for t in targets:
                    if isinstance(t, ast.Attribute) and \
                            isinstance(t.value, ast.Name) and \
                            t.value.id == "self":
                        out.append(Violation(
                            "lint", "scheduler-state",
                            f"{path}:{node.lineno} in "
                            f"{cls.name}.schedule",
                            f"schedule() writes self.{t.attr}: per-call "
                            f"state makes the (cts, n_ops) -> assignment "
                            f"map non-static and breaks jitted dispatch"))
    return out


#: interpret-mode env vars only ``kernels/runtime.py`` may read
_INTERPRET_ENV = frozenset({"REPRO_INTERPRET", "REPRO_PALLAS_INTERPRET"})


def _reads_environ(node: ast.expr) -> str:
    """The interpret-env key ``node`` reads, or None.

    Matches ``os.environ[K]``, ``os.environ.get(K, ...)`` and
    ``os.getenv(K, ...)`` for K in :data:`_INTERPRET_ENV` (any base
    object named/ending in ``environ``/``getenv``, so aliased imports
    are caught too).
    """
    def key_of(expr) -> str:
        if isinstance(expr, ast.Constant) and \
                isinstance(expr.value, str) and \
                expr.value in _INTERPRET_ENV:
            return expr.value
        return None

    def names_environ(expr) -> bool:
        return (isinstance(expr, ast.Attribute)
                and expr.attr == "environ") or \
               (isinstance(expr, ast.Name) and expr.id == "environ")

    if isinstance(node, ast.Subscript) and names_environ(node.value):
        return key_of(node.slice)
    if isinstance(node, ast.Call) and node.args:
        f = node.func
        if isinstance(f, ast.Attribute) and f.attr == "get" and \
                names_environ(f.value):
            return key_of(node.args[0])
        if (isinstance(f, ast.Attribute) and f.attr == "getenv") or \
                (isinstance(f, ast.Name) and f.id == "getenv"):
            return key_of(node.args[0])
    return None


def _interpret_env_reads(tree: ast.Module, path: str) -> list:
    """Flag interpret-mode env reads outside the runtime shim."""
    norm = path.replace("\\", "/")
    if norm.endswith("kernels/runtime.py"):
        return []
    out = []
    for node in ast.walk(tree):
        if not isinstance(node, (ast.Subscript, ast.Call)):
            continue
        key = _reads_environ(node)
        if key is not None:
            out.append(Violation(
                "lint", "interpret-env", f"{path}:{node.lineno}",
                f"reads {key} directly; interpret-mode resolution "
                f"belongs to repro.kernels.runtime (a second reader "
                f"can disagree with the shim and mix compiled and "
                f"interpreted launches)"))
    return out


def lint_source(source: str, path: str = "<string>") -> list:
    """Lint one module's source text; returns Violations."""
    try:
        tree = ast.parse(source, filename=path)
    except SyntaxError as e:
        return [Violation("lint", "syntax-error", f"{path}:{e.lineno}",
                          str(e))]
    out = []
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            walker = _TaintWalker(path, node)
            walker.visit(node)
            out.extend(walker.violations)
    out.extend(_scheduler_state_writes(tree, path))
    out.extend(_interpret_env_reads(tree, path))
    return out


def lint_file(path) -> list:
    p = pathlib.Path(path)
    return lint_source(p.read_text(), str(p))


def lint_tree(root) -> list:
    """Lint every ``*.py`` under ``root`` (deterministic order)."""
    rootp = pathlib.Path(root)
    out = []
    for p in sorted(rootp.rglob("*.py")):
        out.extend(lint_file(p))
    return out
