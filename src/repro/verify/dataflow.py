"""Static dataflow analyzer for every Pallas launch in the tree.

The folded schedules are only correct if their *memory* behavior is:
the fused megakernel accumulates into a shared VMEM scratch ref across
a ``(row tile, instance, grid step)`` grid with idle-step masking and a
scalar-prefetch window table -- exactly where a silent read of
uninitialized scratch, a write-after-write between instances, or an
out-of-bounds window would corrupt products without any test noticing
(a wrong schedule can still be bit-exact on the batches a test happens
to draw).  This module proves four properties per launch *without
executing it*, by abstract interpretation of the traced kernel jaxpr:

  hazards    per-grid-step read/write sets over scratch/output refs:
             no read-before-first-write within a run (a maximal
             sequence of steps sharing output blocks), no two runs
             colliding on the same output block (WAW between
             instances), and declared-idle steps provably no-ops on
             scratch (zero/no-op propagation through the mask);
  bounds     every BlockSpec index-map output lands inside the padded
             operand extents for every grid step, and every
             scalar-prefetch window ``(lo, hi)`` respects the
             super-geometry (:func:`check_window_table`);
  vmem       the measured per-step byte residency obeys the package's
             declared ``vmem_bytes_per_step`` model and a configurable
             budget (:mod:`repro.verify.vmem`);
  roofline   FLOPs per grid step (counted while interpreting) and
             HBM<->VMEM bytes (block-index transition counting) give a
             static ``arith_intensity`` per design point -- the fused
             kernel's deferred roofline model.

The interpreter runs on two value kinds: *concrete* numpy arrays
(program ids, iota, SMEM table scalars, masks -- everything the grid
step determines) and *data* values carrying only shape/dtype, a
maybe-nonzero mask and a provenance token.  A value whose maybe-nonzero
mask is empty is provably zero; a write whose value provably equals the
ref's current contents is a no-op.  That is exactly enough to prove the
idle-step contract of the fused kernel (masked steps add provable
zeros and write back unchanged scratch) while rejecting any corrupted
window table that lets real data through.

Kernel packages declare what their launches look like
(:mod:`repro.kernels.introspect`); the analyzer verifies the traced
jaxpr against the declaration and fails loudly -- an unknown primitive
or indexing pattern is an ``analyzer-gap`` violation, never a silent
pass.
"""
from __future__ import annotations

import dataclasses
import functools

import numpy as np

from repro.verify import jaxpr_walk, vmem
from repro.verify.intervals import Violation

_ANALYZER = "dataflow"

#: ragged/prime batch sizes the tiler must produce safe launches for
RAGGED_BATCHES = (8, 56, 64, 100, 256, 512, 513, 977)


# --------------------------------------------------------------- values

class Data:
    """Abstract array: shape/dtype + maybe-nonzero mask + provenance.

    ``nz`` is an upper bound on where the value can be nonzero;
    ``src = (ref id, version)`` marks a value bitwise-identical to the
    full contents of that ref at that version (a round-trip write of
    such a value is a no-op).
    """
    __slots__ = ("shape", "dtype", "nz", "src")

    def __init__(self, shape, dtype, nz=None, src=None):
        self.shape = tuple(int(d) for d in shape)
        self.dtype = np.dtype(dtype)
        if nz is None:
            nz = True
        self.nz = np.broadcast_to(np.asarray(nz, bool), self.shape)
        self.src = src


def _is_data(v) -> bool:
    return isinstance(v, Data)


def _nz(v) -> np.ndarray:
    return v.nz if _is_data(v) else np.asarray(v) != 0


def _norm(v):
    """Provably-zero data is concrete zeros (zero propagation)."""
    if _is_data(v) and not v.nz.any():
        return np.zeros(v.shape, v.dtype)
    return v


def _shape(v) -> tuple:
    """Shape of either value kind (np.shape sees Data as a scalar)."""
    return v.shape if _is_data(v) else np.shape(v)


class AnalyzerGap(Exception):
    """Kernel construct the analyzer cannot model -- never a pass."""


# ----------------------------------------------------------------- refs

class RefState:
    """One kernel ref's per-run abstract contents.

    Tracks, elementwise: ``written`` (initialized this run), ``nz``
    (maybe-nonzero), and ``known``/``val`` (exact concrete contents
    where known -- scratch starts each run as known zeros after its
    init write, which is what lets idle-step writes of zeros be
    recognized as no-ops).
    """

    def __init__(self, rid: int, name: str, kind: str, shape, dtype,
                 backing=None):
        self.rid, self.name, self.kind = rid, name, kind
        self.shape = tuple(int(d) for d in shape)
        self.dtype = np.dtype(dtype)
        self.backing = backing          # concrete SMEM contents
        self.version = 0
        self.touched = False            # effective write this step
        self.reset_run()

    def reset_run(self):
        self.written = np.zeros(self.shape, bool)
        self.nz = np.zeros(self.shape, bool)
        self.known = np.zeros(self.shape, bool)
        self.val = np.zeros(self.shape, self.dtype)
        self.version += 1

    # -- region helpers -------------------------------------------------
    def _full(self, region) -> bool:
        sel = np.zeros(self.shape, bool)
        sel[region] = True
        return bool(sel.all())

    def read(self, region, where: str, violations: list):
        if self.kind == "smem":
            return np.asarray(self.backing)[region]
        if self.kind == "in":
            return Data(np.empty(self.shape, bool)[region].shape,
                        self.dtype)
        if not self.written[region].all():
            violations.append(Violation(
                _ANALYZER, "read-before-write", where,
                f"ref {self.name} read at {_fmt_region(region)} before "
                f"every element was written this run"))
        if self.known[region].all():
            return self.val[region].copy()
        src = (self.rid, self.version) if self._full(region) else None
        return Data(self.nz[region].shape, self.dtype,
                    nz=self.nz[region].copy(), src=src)

    def write(self, region, v, where: str, violations: list):
        if self.kind in ("smem", "in"):
            violations.append(Violation(
                _ANALYZER, "write-to-readonly", where,
                f"ref {self.name} ({self.kind}) is written"))
            return
        # no-op detection: full-ref round trip, or rewriting contents
        # that are concretely known to be identical already
        if (_is_data(v) and v.src == (self.rid, self.version)
                and self._full(region)):
            return
        if (not _is_data(v) and self.written[region].all()
                and self.known[region].all()
                and np.array_equal(self.val[region],
                                   np.broadcast_to(
                                       np.asarray(v, self.dtype),
                                       self.val[region].shape))):
            return
        self.touched = True
        self.version += 1
        self.written[region] = True
        if _is_data(v):
            self.known[region] = False
            self.nz[region] = np.broadcast_to(v.nz,
                                              self.nz[region].shape)
        else:
            arr = np.broadcast_to(np.asarray(v, self.dtype),
                                  self.val[region].shape)
            self.known[region] = True
            self.val[region] = arr
            self.nz[region] = arr != 0


def _fmt_region(region) -> str:
    parts = []
    for r in region:
        if isinstance(r, slice):
            parts.append(f"{r.start or 0}:{r.stop}")
        else:
            parts.append(str(r))
    return "[" + ", ".join(parts) + "]"


# ---------------------------------------------------------- interpreter

_ELEMENTWISE_ZERO_STRICT = ("mul", "and")
_ELEMENTWISE_UNION = ("add", "sub", "or", "xor", "max", "min", "rem",
                      "div")
_ELEMENTWISE_UNARY = ("neg",)                      # nz-preserving
_SHIFTS = ("shift_right_logical", "shift_right_arithmetic",
           "shift_left")
_COMPARES = ("eq", "ne", "lt", "le", "gt", "ge")
_NP_OPS = {
    "add": np.add, "sub": np.subtract, "mul": np.multiply,
    "and": np.bitwise_and, "or": np.bitwise_or, "xor": np.bitwise_xor,
    "max": np.maximum, "min": np.minimum,
    "shift_right_logical": np.right_shift,
    "shift_right_arithmetic": np.right_shift,
    "shift_left": np.left_shift,
    # lax.rem/div truncate toward zero; index maps only ever apply them
    # to nonnegative grid indices, where they equal numpy's flooring
    "rem": np.remainder, "div": np.floor_divide,
    "eq": np.equal, "ne": np.not_equal, "lt": np.less,
    "le": np.less_equal, "gt": np.greater, "ge": np.greater_equal,
    "neg": np.negative,
}


class _Interp:
    """Abstract interpreter for one grid step of one kernel body."""

    def __init__(self, step, where: str, violations: list):
        self.step = step
        self.where = where
        self.violations = violations
        self.flops = 0

    # -- plumbing -------------------------------------------------------
    def run_jaxpr(self, jaxpr, consts, args):
        env = {}

        def read(v):
            if hasattr(v, "val"):                  # Literal
                return np.asarray(v.val)
            return env[v]

        for var, c in zip(jaxpr.constvars, consts):
            env[var] = c
        for var, a in zip(jaxpr.invars, args):
            env[var] = a
        for eqn in jaxpr.eqns:
            vals = [read(v) for v in eqn.invars]
            name = eqn.primitive.name
            handler = getattr(self, "_p_" + name.replace("-", "_"),
                              None)
            if handler is None:
                handler = self._generic(name)
            if handler is None:
                raise AnalyzerGap(
                    f"primitive {name!r} not modeled")
            outs = handler(eqn, vals)
            for var, out in zip(eqn.outvars, outs):
                if var.__class__.__name__ != "DropVar":
                    env[var] = _norm(out) if out is not None else None
        return [read(v) for v in jaxpr.outvars]

    def _out_aval(self, eqn, i=0):
        return eqn.outvars[i].aval

    def _data(self, eqn, nz=True, i=0):
        aval = self._out_aval(eqn, i)
        return Data(aval.shape, aval.dtype, nz=nz)

    # -- generic elementwise -------------------------------------------
    def _generic(self, name):
        if name in _ELEMENTWISE_ZERO_STRICT:
            return self._ew_strict
        if name in _ELEMENTWISE_UNION:
            return self._ew_union
        if name in _SHIFTS:
            return self._ew_shift
        if name in _COMPARES:
            return self._ew_compare
        if name in _ELEMENTWISE_UNARY:
            return self._ew_unary
        return None

    def _np2(self, eqn, a, b):
        op = _NP_OPS[eqn.primitive.name]
        with np.errstate(over="ignore"):
            out = op(np.asarray(a), np.asarray(b))
        return np.asarray(out, self._out_aval(eqn).dtype)

    def _ew_strict(self, eqn, vals):
        a, b = vals
        self.flops += int(np.prod(self._out_aval(eqn).shape))
        if not (_is_data(a) or _is_data(b)):
            return [self._np2(eqn, a, b)]
        shape = self._out_aval(eqn).shape
        nz = (np.broadcast_to(_nz(a), shape)
              & np.broadcast_to(_nz(b), shape))
        return [Data(shape, self._out_aval(eqn).dtype, nz=nz)]

    def _ew_union(self, eqn, vals):
        a, b = vals
        shape = tuple(self._out_aval(eqn).shape)
        self.flops += int(np.prod(shape))
        if not (_is_data(a) or _is_data(b)):
            return [self._np2(eqn, a, b)]
        # x + 0 (or 0 + x, x | 0 ...) preserves x, provenance included
        if eqn.primitive.name in ("add", "or", "xor"):
            for keep, other in ((a, b), (b, a)):
                if (not _is_data(other) and not np.any(other)
                        and tuple(_shape(keep)) == shape):
                    return [keep]
        if (eqn.primitive.name == "sub" and not _is_data(b)
                and not np.any(b) and tuple(_shape(a)) == shape):
            return [a]
        nz = (np.broadcast_to(_nz(a), shape)
              | np.broadcast_to(_nz(b), shape))
        return [Data(shape, self._out_aval(eqn).dtype, nz=nz)]

    def _ew_shift(self, eqn, vals):
        a, b = vals
        self.flops += int(np.prod(self._out_aval(eqn).shape))
        if not (_is_data(a) or _is_data(b)):
            return [self._np2(eqn, a, b)]
        shape = self._out_aval(eqn).shape
        # shifting can only clear bits: zero stays zero
        nz = np.broadcast_to(_nz(a), shape)
        return [Data(shape, self._out_aval(eqn).dtype, nz=nz)]

    def _ew_compare(self, eqn, vals):
        a, b = vals
        if not (_is_data(a) or _is_data(b)):
            return [self._np2(eqn, a, b)]
        return [self._data(eqn)]

    def _ew_unary(self, eqn, vals):
        (a,) = vals
        self.flops += int(np.prod(self._out_aval(eqn).shape))
        if not _is_data(a):
            with np.errstate(over="ignore"):
                return [np.asarray(_NP_OPS[eqn.primitive.name](
                    np.asarray(a)), self._out_aval(eqn).dtype)]
        return [Data(a.shape, self._out_aval(eqn).dtype, nz=a.nz)]

    # -- structural primitives -----------------------------------------
    def _p_program_id(self, eqn, vals):
        if self.step is None:
            raise AnalyzerGap("program_id outside a grid step")
        return [np.int32(self.step[eqn.params["axis"]])]

    def _p_iota(self, eqn, vals):
        shape = tuple(eqn.params["shape"])
        dim = eqn.params["dimension"]
        ar = np.arange(shape[dim], dtype=eqn.params["dtype"])
        view = [1] * len(shape)
        view[dim] = shape[dim]
        return [np.broadcast_to(ar.reshape(view), shape).copy()]

    def _p_broadcast_in_dim(self, eqn, vals):
        (a,) = vals
        shape = tuple(eqn.params["shape"])
        bdims = eqn.params["broadcast_dimensions"]

        def bcast(x):
            view = [1] * len(shape)
            for i, d in enumerate(bdims):
                view[d] = np.shape(x)[i]
            return np.broadcast_to(np.reshape(x, view), shape)

        if not _is_data(a):
            return [bcast(np.asarray(a)).copy()]
        return [Data(shape, a.dtype, nz=bcast(a.nz))]

    def _p_convert_element_type(self, eqn, vals):
        (a,) = vals
        dt = self._out_aval(eqn).dtype
        if not _is_data(a):
            with np.errstate(over="ignore", invalid="ignore"):
                return [np.asarray(a).astype(dt)]
        return [Data(a.shape, dt, nz=a.nz, src=None)]

    def _p_reshape(self, eqn, vals):
        (a,) = vals
        shape = tuple(self._out_aval(eqn).shape)
        if not _is_data(a):
            return [np.reshape(np.asarray(a), shape)]
        return [Data(shape, a.dtype, nz=np.reshape(a.nz, shape))]

    def _p_squeeze(self, eqn, vals):
        return self._p_reshape(eqn, vals)

    def _p_transpose(self, eqn, vals):
        (a,) = vals
        perm = eqn.params["permutation"]
        if not _is_data(a):
            return [np.transpose(np.asarray(a), perm)]
        return [Data(self._out_aval(eqn).shape, a.dtype,
                     nz=np.transpose(a.nz, perm))]

    def _p_slice(self, eqn, vals):
        (a,) = vals
        starts = eqn.params["start_indices"]
        limits = eqn.params["limit_indices"]
        strides = eqn.params["strides"] or (1,) * len(starts)
        region = tuple(slice(s, l, st)
                       for s, l, st in zip(starts, limits, strides))
        if not _is_data(a):
            return [np.asarray(a)[region].copy()]
        return [Data(self._out_aval(eqn).shape, a.dtype,
                     nz=a.nz[region])]

    def _p_concatenate(self, eqn, vals):
        dim = eqn.params["dimension"]
        if all(not _is_data(v) for v in vals):
            return [np.concatenate([np.asarray(v) for v in vals],
                                   axis=dim)]
        nz = np.concatenate([_nz(v) for v in vals], axis=dim)
        return [Data(self._out_aval(eqn).shape,
                     self._out_aval(eqn).dtype, nz=nz)]

    def _p_pad(self, eqn, vals):
        a, pv = vals
        config = eqn.params["padding_config"]
        if any(interior != 0 for _, _, interior in config):
            raise AnalyzerGap("interior padding not modeled")
        out_shape = tuple(self._out_aval(eqn).shape)

        def padded(x, fill):
            out = np.full(out_shape, fill, dtype=bool if isinstance(
                fill, (bool, np.bool_)) else None)
            src_region, dst_region = [], []
            for (lo, _hi, _), n in zip(config, np.shape(x)):
                src_region.append(slice(max(0, -lo),
                                        min(n, out.shape[len(dst_region)]
                                            - lo)))
                dst_region.append(slice(max(0, lo),
                                        max(0, lo) + (src_region[-1].stop
                                                      - src_region[-1]
                                                      .start)))
            out[tuple(dst_region)] = x[tuple(src_region)]
            return out

        if not (_is_data(a) or _is_data(pv)):
            out = np.full(out_shape, np.asarray(pv),
                          dtype=self._out_aval(eqn).dtype)
            sub = padded(np.asarray(a) != np.asarray(a).dtype.type(0),
                         False)  # placement mask
            # place the actual values (mask tells us where they went)
            vals_out = np.full(out_shape, np.asarray(pv),
                               dtype=self._out_aval(eqn).dtype)
            region = tuple(slice(max(0, lo), max(0, lo) + min(
                n, out_shape[d] - max(0, lo)) - max(0, -lo))
                for d, ((lo, _h, _i), n)
                in enumerate(zip(config, np.shape(a))))
            src = tuple(slice(max(0, -lo), max(0, -lo)
                              + (r.stop - r.start))
                        for (lo, _h, _i), r in zip(config, region))
            vals_out[region] = np.asarray(a)[src]
            del out, sub
            return [vals_out]
        nz = padded(_nz(a), bool(np.any(_nz(pv))))
        return [Data(out_shape, self._out_aval(eqn).dtype, nz=nz)]

    def _p_select_n(self, eqn, vals):
        pred, *cases = vals
        if not _is_data(pred):
            p = np.asarray(pred)
            flat = p.reshape(-1)
            if flat.size and np.all(flat == flat[0]):
                return [cases[int(flat[0])]]
            # elementwise concrete selection
            if all(not _is_data(c) for c in cases):
                out = np.choose(p.astype(np.int64),
                                [np.broadcast_to(np.asarray(c), p.shape)
                                 for c in cases])
                return [np.asarray(out, self._out_aval(eqn).dtype)]
        shape = tuple(self._out_aval(eqn).shape)
        nz = np.zeros(shape, bool)
        for c in cases:
            nz |= np.broadcast_to(_nz(c), shape)
        return [Data(shape, self._out_aval(eqn).dtype, nz=nz)]

    def _p_dot_general(self, eqn, vals):
        a, b = vals
        (lc, rc), _ = eqn.params["dimension_numbers"]
        k = 1
        for d in lc:
            k *= int(_shape(a)[d])
        out_shape = tuple(self._out_aval(eqn).shape)
        self.flops += 2 * k * int(np.prod(out_shape))
        if (not _is_data(a) and not np.any(a)) or \
           (not _is_data(b) and not np.any(b)):
            return [np.zeros(out_shape, self._out_aval(eqn).dtype)]
        return [self._data(eqn)]

    def _p_scatter_add(self, eqn, vals):
        operand, indices, updates = vals
        if _is_data(indices):
            raise AnalyzerGap("dynamic scatter indices not modeled")
        dn = eqn.params["dimension_numbers"]
        upd_shape = _shape(updates)
        if tuple(dn.update_window_dims) != tuple(range(len(upd_shape))):
            raise AnalyzerGap(
                f"scatter pattern {dn} not modeled")
        # reconstruct the full operand-rank window (inserted dims are
        # size-1 slots at the scattered index)
        win_shape, k = [], 0
        for d in range(len(_shape(operand))):
            if d in dn.inserted_window_dims:
                win_shape.append(1)
            else:
                win_shape.append(int(upd_shape[k]))
                k += 1
        if k != len(upd_shape):
            raise AnalyzerGap(f"scatter pattern {dn} not modeled")
        if _is_data(updates):
            updates = Data(win_shape, updates.dtype,
                           nz=np.reshape(updates.nz, win_shape),
                           src=None)
        else:
            updates = np.reshape(np.asarray(updates), win_shape)
        idx = np.asarray(indices).reshape(-1)
        offsets = [0] * len(_shape(operand))
        for pos, od in enumerate(dn.scatter_dims_to_operand_dims):
            offsets[od] = int(idx[pos])
        region = tuple(slice(off, off + size) for off, size
                       in zip(offsets, win_shape))
        for r, n in zip(region, _shape(operand)):
            if r.start < 0 or r.stop > n:
                self.violations.append(Violation(
                    _ANALYZER, "scatter-bounds", self.where,
                    f"scatter-add window {region} exceeds operand "
                    f"shape {_shape(operand)} (FILL_OR_DROP would "
                    f"silently drop it)"))
                return [operand]
        self.flops += int(np.prod(_shape(updates)))
        updates = _norm(updates)
        if not _is_data(updates) and not np.any(updates):
            return [operand]              # identity: provenance kept
        if not (_is_data(operand) or _is_data(updates)):
            out = np.array(operand)
            with np.errstate(over="ignore"):
                out[region] = out[region] + np.asarray(
                    updates, out.dtype)
            return [out]
        nz = np.array(_nz(operand))
        nz[region] |= _nz(updates)
        return [Data(_shape(operand), self._out_aval(eqn).dtype,
                     nz=nz)]

    # -- control flow ---------------------------------------------------
    def _p_cond(self, eqn, vals):
        pred, *ops = vals
        if _is_data(pred):
            raise AnalyzerGap(
                "cond predicate not statically resolvable from the "
                "grid step")
        idx = int(np.asarray(pred).reshape(()))
        branches = eqn.params["branches"]
        idx = max(0, min(idx, len(branches) - 1))
        closed = branches[idx]
        return self.run_jaxpr(closed.jaxpr, closed.consts, ops)

    def _p_pjit(self, eqn, vals):
        closed = eqn.params["jaxpr"]
        return self.run_jaxpr(closed.jaxpr, closed.consts, vals)

    def _p_closed_call(self, eqn, vals):
        closed = eqn.params["call_jaxpr"]
        return self.run_jaxpr(closed.jaxpr, closed.consts, vals)

    # -- state primitives -----------------------------------------------
    def _decode_indexer(self, tree, leaves, ref):
        import jax.tree_util as jtu
        indexers = jtu.tree_unflatten(tree, list(leaves))
        if len(indexers) != 1:
            raise AnalyzerGap("stacked ref indexers not modeled")
        region = []
        for entry in indexers[0].indices:
            if hasattr(entry, "start") and hasattr(entry, "size"):
                start, size = entry.start, entry.size
                stride = getattr(entry, "stride", 1)
                if _is_data(start) or _is_data(size):
                    raise AnalyzerGap("data-dependent slice bounds")
                start = int(np.asarray(start).reshape(()))
                size = int(np.asarray(size).reshape(()))
                stride = int(np.asarray(stride).reshape(()))
                region.append(slice(start, start + size * stride,
                                    stride))
            elif _is_data(entry):
                raise AnalyzerGap("data-dependent scalar index")
            elif np.ndim(entry) == 0:
                region.append(int(np.asarray(entry).reshape(())))
            else:
                raise AnalyzerGap("advanced ref indexing not modeled")
        # bounds of the decoded region vs the ref extents
        for r, n in zip(region, ref.shape):
            lo = r.start if isinstance(r, slice) else r
            hi = (r.stop if isinstance(r, slice) else r + 1)
            if lo < 0 or hi > n:
                self.violations.append(Violation(
                    _ANALYZER, "ref-bounds", self.where,
                    f"ref {ref.name} indexed at {_fmt_region(region)} "
                    f"outside its extents {ref.shape}"))
        return tuple(region)

    def _p_get(self, eqn, vals):
        ref, *leaves = vals
        region = self._decode_indexer(eqn.params["tree"], leaves, ref)
        return [ref.read(region, self.where, self.violations)]

    def _p_swap(self, eqn, vals):
        ref, value, *leaves = vals
        region = self._decode_indexer(eqn.params["tree"], leaves, ref)
        old_nz = ref.nz[region].copy()
        ref.write(region, value, self.where, self.violations)
        return [Data(self._out_aval(eqn).shape,
                     self._out_aval(eqn).dtype, nz=old_nz)]

    def _p_addupdate(self, eqn, vals):
        ref, value, *leaves = vals
        region = self._decode_indexer(eqn.params["tree"], leaves, ref)
        value = _norm(value)
        if not _is_data(value) and not np.any(value):
            return [None]
        old = ref.read(region, self.where, self.violations)
        if _is_data(old) or _is_data(value):
            merged = Data(_shape(old), ref.dtype,
                          nz=_nz(old) | _nz(value))
        else:
            with np.errstate(over="ignore"):
                merged = np.asarray(old) + np.asarray(value, ref.dtype)
        ref.write(region, merged, self.where, self.violations)
        return [None]


# ------------------------------------------------------- launch decoding

@dataclasses.dataclass(frozen=True)
class LaunchReport:
    """Static analysis result of one Pallas launch."""
    name: str
    grid: tuple
    n_steps: int
    flops: int
    hbm_bytes: int
    arith_intensity: float
    vmem: dict                  # VmemBreakdown.as_dict()
    vmem_model_bytes: int
    violations: tuple

    @property
    def ok(self) -> bool:
        return not self.violations

    def as_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["grid"] = list(self.grid)
        d["ok"] = self.ok
        d["violations"] = [dataclasses.asdict(v)
                           for v in self.violations]
        return d


def _eval_index_map(interp, closed, args):
    outs = interp.run_jaxpr(closed.jaxpr, closed.consts, args)
    idx = []
    for o in outs:
        if _is_data(o):
            raise AnalyzerGap("index map output not static")
        idx.append(int(np.asarray(o).reshape(())))
    return tuple(idx)


def _program_id_axes(kernel_jaxpr) -> tuple:
    axes = set()
    for eqn in jaxpr_walk.walk(kernel_jaxpr, into_pallas=True):
        if eqn.primitive.name == "program_id":
            axes.add(eqn.params["axis"])
    return tuple(sorted(axes))


def analyze_contract(contract, budget=None):
    """Full static analysis of one declared launch -> LaunchReport.

    Proves conformance (traced grid/scratch match the declaration),
    bounds, hazards, idle-step no-ops, VMEM model/budget and the
    static roofline.
    """
    violations = []

    def fail(rule, detail, grid=()):
        violations.append(Violation(_ANALYZER, rule, contract.name,
                                    detail))
        return LaunchReport(
            name=contract.name, grid=tuple(grid), n_steps=0, flops=0,
            hbm_bytes=0, arith_intensity=0.0, vmem={},
            vmem_model_bytes=contract.vmem_model_bytes,
            violations=tuple(violations))

    try:
        closed = contract.trace()
    except Exception as e:                   # noqa: BLE001
        return fail("trace-error", f"tracing raised {e!r}")
    calls = jaxpr_walk.find_pallas_calls(closed.jaxpr)
    if len(calls) != 1:
        return fail("launch-count",
                    f"expected exactly 1 pallas_call, traced "
                    f"{len(calls)}")
    eqn = calls[0]
    gm = eqn.params["grid_mapping"]
    kernel = eqn.params["jaxpr"]
    grid = tuple(int(g) for g in gm.grid)

    # -- conformance against the package's declaration ------------------
    if grid != tuple(contract.grid):
        return fail("grid-mismatch",
                    f"declared grid {tuple(contract.grid)}, traced "
                    f"{grid}", grid)
    ni, nin = gm.num_index_operands, gm.num_inputs
    nout, nscr = gm.num_outputs, gm.num_scratch_operands
    scratch_avals = [v.aval for v in kernel.invars[ni + nin + nout:]]
    declared = [(tuple(s), np.dtype(d))
                for s, d in contract.scratch_shapes]
    traced = [(tuple(a.shape), np.dtype(a.dtype))
              for a in scratch_avals]
    if declared != traced:
        return fail("scratch-mismatch",
                    f"declared scratch {declared}, traced {traced}",
                    grid)

    # -- window-table checks (super-geometry launches) -------------------
    sg = contract.meta.get("super_geometry")
    if sg is not None:
        violations.extend(check_window_table(sg, contract.table))

    # -- VMEM model / budget --------------------------------------------
    breakdown = vmem.measure(eqn)
    violations.extend(vmem.check(breakdown,
                                 contract.vmem_model_bytes,
                                 contract.name, budget))

    # -- per-step block-index bounds + run segmentation ------------------
    block_mappings = list(gm.block_mappings)    # inputs then outputs
    smem_args = []
    for v in kernel.invars[:ni]:
        if contract.table is not None and not smem_args:
            smem_args.append(np.asarray(contract.table))
        else:
            smem_args.append(np.zeros(v.aval.shape,
                                      np.dtype(v.aval.dtype)))
    steps = [tuple(int(c) for c in s) for s in np.ndindex(*grid)]
    idxer = _Interp(None, contract.name, violations)
    per_map_indices = []
    try:
        for bm in block_mappings:
            bs = tuple(bm.block_shape)
            if not all(isinstance(b, (int, np.integer)) for b in bs):
                raise AnalyzerGap(f"block shape {bs} not static")
            arr_shape = tuple(bm.array_shape_dtype.shape)
            nblocks = tuple(-(-a // b) for a, b in zip(arr_shape, bs))
            seq = []
            for s in steps:
                idx = _eval_index_map(idxer, bm.index_map_jaxpr,
                                      list(s) + smem_args)
                for d, (i, nb) in enumerate(zip(idx, nblocks)):
                    if i < 0 or i >= nb:
                        violations.append(Violation(
                            _ANALYZER, "block-bounds",
                            f"{contract.name} step {s}",
                            f"index map emits block {idx} on dim {d} "
                            f"outside the padded extent "
                            f"({nb} blocks of {bs} over {arr_shape})"))
                seq.append(idx)
            per_map_indices.append(seq)
    except AnalyzerGap as e:
        return fail("analyzer-gap", str(e), grid)

    out_maps = per_map_indices[nin:nin + nout]
    out_sig = [tuple(m[t] for m in out_maps) for t in range(len(steps))]

    # runs: maximal consecutive step groups sharing all output blocks
    runs = []
    for t, s in enumerate(steps):
        if t == 0 or out_sig[t] != out_sig[t - 1]:
            runs.append([t])
        else:
            runs[-1].append(t)

    # WAW between runs: a later run revisiting an earlier run's output
    # block interleaves writes from different grid coordinates
    seen_sigs = {}
    for rn, run in enumerate(runs):
        sig = out_sig[run[0]]
        if sig in seen_sigs:
            violations.append(Violation(
                _ANALYZER, "waw-out",
                f"{contract.name} step {steps[run[0]]}",
                f"output block {sig} already written by the run at "
                f"step {steps[seen_sigs[sig]]} -- write-after-write "
                f"between grid instances"))
        else:
            seen_sigs[sig] = run[0]

    # -- hazard + idle interpretation, deduped by behavior key -----------
    axes = _program_id_axes(kernel)
    flops_total = 0
    run_flops = {}
    for run in runs:
        key = tuple(tuple(steps[t][a] for a in axes) for t in run)
        if key in run_flops:
            flops_total += run_flops[key]
            continue
        refs = []
        for rid, v in enumerate(kernel.invars):
            aval = v.aval
            if rid < ni:
                kind, backing = "smem", smem_args[rid]
            elif rid < ni + nin:
                kind, backing = "in", None
            elif rid < ni + nin + nout:
                kind, backing = "out", None
            else:
                kind, backing = "scratch", None
            refs.append(RefState(rid, f"{kind}{rid}", kind,
                                 aval.shape, aval.dtype,
                                 backing=backing))
        flops = 0
        try:
            for t in run:
                step = steps[t]
                where = f"{contract.name} step {step}"
                interp = _Interp(step, where, violations)
                for r in refs:
                    r.touched = False
                interp.run_jaxpr(kernel, [], refs)
                flops += interp.flops
                if contract.matches_idle(step):
                    for r in refs:
                        if r.kind == "scratch" and r.touched:
                            violations.append(Violation(
                                _ANALYZER, "idle-step-effect", where,
                                f"declared-idle step {step} performs "
                                f"an effective write to scratch ref "
                                f"{r.name} despite its mask"))
        except AnalyzerGap as e:
            violations.append(Violation(
                _ANALYZER, "analyzer-gap",
                f"{contract.name} step {steps[run[0]]}", str(e)))
            run_flops[key] = flops
            flops_total += flops
            continue
        run_flops[key] = flops
        flops_total += flops

    # -- static roofline: HBM<->VMEM traffic by block transitions --------
    hbm = breakdown.smem_bytes                 # table prefetched once
    for mi, seq in enumerate(per_map_indices):
        bm = block_mappings[mi]
        bs = tuple(bm.block_shape)
        blk_bytes = int(np.prod(bs)) * np.dtype(
            bm.array_shape_dtype.dtype).itemsize
        transfers = sum(1 for t in range(len(seq))
                        if t == 0 or seq[t] != seq[t - 1])
        hbm += blk_bytes * transfers
    intensity = flops_total / hbm if hbm else 0.0

    return LaunchReport(
        name=contract.name, grid=grid, n_steps=len(steps),
        flops=flops_total, hbm_bytes=hbm,
        arith_intensity=intensity,
        vmem=breakdown.as_dict(),
        vmem_model_bytes=contract.vmem_model_bytes,
        violations=tuple(violations))


# ----------------------------------------------------- window-table rules

def check_window_table(sg, table=None) -> list:
    """Static rules over a fused launch's scalar-prefetch window table.

    Checked directly on the (instance, step, 2) table so seeded
    corruptions (tests) and the real :meth:`SuperGeometry.table` go
    through one code path:

      window-shape     table shape matches the super-geometry
      window-bounds    0 <= lo <= hi <= LB on every real step
      window-empty     real steps consume at least one limb
      window-overlap   one instance's real windows are pairwise disjoint
      window-coverage  they cover every B limb exactly once
      idle-unmasked    padded idle steps carry the (0, 0) mask
    """
    tbl = np.asarray(sg.table() if table is None else table)
    out = []
    want = (sg.n_instances, sg.max_steps, 2)
    if tbl.shape != want:
        out.append(Violation(
            _ANALYZER, "window-shape", f"fused[{sg.la}x{sg.lb}]",
            f"window table shape {tbl.shape}, super-geometry "
            f"requires {want}"))
        return out
    for i in range(sg.n_instances):
        real = sg.rows[i].ct_run
        covered = np.zeros(sg.lb, int)
        for j in range(sg.max_steps):
            lo, hi = int(tbl[i, j, 0]), int(tbl[i, j, 1])
            where = f"fused[{sg.la}x{sg.lb}] instance {i} step {j}"
            if j >= real:
                if (lo, hi) != (0, 0):
                    out.append(Violation(
                        _ANALYZER, "idle-unmasked", where,
                        f"padded idle step carries window "
                        f"({lo}, {hi}) instead of the (0, 0) mask"))
                continue
            if not (0 <= lo <= hi <= sg.lb):
                out.append(Violation(
                    _ANALYZER, "window-bounds", where,
                    f"window ({lo}, {hi}) outside [0, {sg.lb}]"))
                continue
            if lo == hi:
                out.append(Violation(
                    _ANALYZER, "window-empty", where,
                    "real fold step consumes no B limbs"))
                continue
            covered[lo:hi] += 1
        if (covered > 1).any():
            dup = int(np.argmax(covered > 1))
            out.append(Violation(
                _ANALYZER, "window-overlap",
                f"fused[{sg.la}x{sg.lb}] instance {i}",
                f"B limb {dup} accumulated by overlapping windows -- "
                f"its partial products would be added twice"))
        elif (covered == 0).any():
            miss = int(np.argmax(covered == 0))
            out.append(Violation(
                _ANALYZER, "window-coverage",
                f"fused[{sg.la}x{sg.lb}] instance {i}",
                f"B limb {miss} not covered by any window"))
    return out


# --------------------------------------------------------- plan-level API

def _instance_params(cfg) -> tuple:
    """(schedule, ct) of the mcim_fold launch realizing one config."""
    if cfg.arch == "star":
        return "fb", 1
    if cfg.arch == "karatsuba":
        return "karatsuba", 3
    return cfg.arch, cfg.ct


def _flat_configs(configs) -> tuple:
    flat = []
    for count, cfg in configs:
        flat.extend([cfg] * count)
    return tuple(flat)


@functools.lru_cache(maxsize=2048)
def _kernel_report(la, lb, schedule, ct, batch=256, budget=None):
    from repro.kernels import mcim_fold
    return analyze_contract(
        mcim_fold.launch_contract(la, lb, ct, schedule, batch=batch),
        budget=budget)


@functools.lru_cache(maxsize=2048)
def _fused_report(la, lb, cts, budget=None):
    from repro.core.mcim import MCIMConfig
    from repro.kernels import bank_fold
    configs = tuple(MCIMConfig(arch="fb", ct=ct) for ct in cts)
    return analyze_contract(bank_fold.launch_contract(configs, la, lb),
                            budget=budget)


def analyze_plan(bits_a: int, bits_b: int, configs,
                 substrate: str = "fused", budget=None) -> tuple:
    """LaunchReports of every distinct launch a plan implies.

    ``substrate="kernel"``: one per-instance ``mcim_fold`` launch per
    distinct (schedule, CT) in the plan.  ``substrate="fused"``: the
    one megakernel launch of the whole bank.  Signed configs analyze
    identically -- the correction pass is pure jnp outside the kernel,
    so the Pallas launch is the unsigned one.
    """
    from repro.core import limbs as L
    from repro.kernels.bank_fold import fused_ct
    la = L.n_limbs_for_bits(bits_a)
    lb = L.n_limbs_for_bits(bits_b)
    flat = _flat_configs(configs)
    if substrate == "fused":
        cts = tuple(fused_ct(cfg) for cfg in flat)
        return (_fused_report(la, lb, cts, budget),)
    if substrate != "kernel":
        raise ValueError(f"substrate must be kernel or fused, "
                         f"got {substrate!r}")
    reports, seen = [], set()
    for cfg in flat:
        schedule, ct = _instance_params(cfg)
        if (schedule, ct) in seen:
            continue
        seen.add((schedule, ct))
        reports.append(_kernel_report(la, lb, schedule, ct,
                                      budget=budget))
    return tuple(reports)


def verify_plan_dataflow(bits_a: int, bits_b: int, configs,
                         budget=None) -> tuple:
    """All dataflow violations of a plan, both substrates."""
    out = []
    for substrate in ("kernel", "fused"):
        for rep in analyze_plan(bits_a, bits_b, configs,
                                substrate=substrate, budget=budget):
            out.extend(rep.violations)
    return tuple(out)


def plan_static_stats(bits_a: int, bits_b: int, configs) -> dict:
    """Fused-launch roofline numbers of a plan (benchmark columns)."""
    rep = analyze_plan(bits_a, bits_b, configs, substrate="fused")[0]
    return {
        "vmem_bytes_step": rep.vmem.get("total_bytes", 0),
        "vmem_model_bytes": rep.vmem_model_bytes,
        "flops_per_launch": rep.flops,
        "hbm_bytes_per_launch": rep.hbm_bytes,
        "arith_intensity": rep.arith_intensity,
    }


def analyze_standalone(budget=None) -> tuple:
    """LaunchReports of the non-bank kernels (full-tree coverage)."""
    from repro.kernels import int8_matmul, karatsuba_ppm, prefix_adder
    contracts = (
        karatsuba_ppm.launch_contract(4),
        prefix_adder.launch_contract(16),
        int8_matmul.launch_contract(),
    )
    return tuple(analyze_contract(c, budget=budget) for c in contracts)


def analyze_tiling(bits: int = 32, batches=RAGGED_BATCHES,
                   budget=None) -> tuple:
    """Bounds/hazard proofs across ragged batch shapes of the tiler."""
    from repro.core import limbs as L
    la = L.n_limbs_for_bits(bits)
    return tuple(_kernel_report(la, la, "fb", 2, batch=b,
                                budget=budget)
                 for b in batches)
