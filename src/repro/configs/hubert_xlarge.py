"""HuBERT-XLarge: encoder-only audio backbone (frontend stubbed).
[arXiv:2106.07447; unverified]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="hubert-xlarge", family="encoder",
    n_layers=48, d_model=1280, n_heads=16, n_kv_heads=16, head_dim=80,
    d_ff=5120, vocab_size=504,
)
