"""Gemma3-1B: 5:1 local:global attention, 128k ctx, qk-norm.
[hf:google/gemma-3-1b-pt; unverified]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="gemma3-1b", family="dense",
    n_layers=26, d_model=1152, n_heads=4, n_kv_heads=1, head_dim=256,
    d_ff=6912, vocab_size=262144,
    qk_norm=True, local_per_global=5, window=512,
    rope_theta=10_000.0, rope_theta_global=1_000_000.0,
    tie_embeddings=True,
)
