"""Architecture configuration schema shared by all 10 assigned archs."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                     # dense | moe | ssm | hybrid | encoder | vlm
    n_layers: int
    d_model: int
    n_heads: int                    # 0 for attention-free
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 128

    # attention variants
    qk_norm: bool = False
    attn_logit_cap: Optional[float] = None
    final_logit_cap: Optional[float] = None
    rope_theta: float = 10_000.0
    rope_theta_global: Optional[float] = None   # gemma3 dual-theta
    # layer pattern: how many local (sliding-window) layers per global
    # layer; None => all layers global full attention.
    local_per_global: Optional[int] = None
    window: int = 4096

    # MoE
    n_experts: int = 0
    top_k: int = 0
    d_ff_expert: int = 0
    n_shared_experts: int = 0       # llama4-style always-on expert
    router_aux_coef: float = 0.01

    # SSM (mamba2 / zamba2)
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv_width: int = 4
    ssm_chunk: int = 128

    # hybrid (zamba2): one shared attention block every N ssm layers
    shared_attn_every: int = 0

    # VLM
    n_vis_tokens: int = 0
    d_vis: int = 0

    tie_embeddings: bool = False
    vocab_round_to: int = 256       # pad vocab for shardability
    norm_eps: float = 1e-6
    max_seq: int = 32768

    # execution knobs (overridable per run; part of the perf surface)
    q_chunk: int = 512
    k_chunk: int = 512
    attn_schedule: str = "masked"   # masked | banded  (§Perf knob)
    remat: bool = True
    scan_layers: bool = True
    ce_chunk: int = 512
    # §Perf knobs (hillclimb iterations; defaults = paper-faithful baseline)
    moe_local_dispatch: bool = False   # expert-choice within data shard
    attn_fallback: str = "hd"          # hd | replicate (heads % model != 0)
    kv_cache_dtype: str = "bf16"       # bf16 | int8 (MCIM int8 KV cache)

    @property
    def padded_vocab(self) -> int:
        r = self.vocab_round_to
        return -(-self.vocab_size // r) * r

    @property
    def d_inner(self) -> int:        # mamba2 inner width
        return self.ssm_expand * self.d_model

    @property
    def n_ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    def reduced(self, **overrides) -> "ArchConfig":
        """Smoke-test scale: same family/wiring, tiny dims.

        Layer counts are chosen to exercise every structural path of the
        full config: at least one full pattern group AND a remainder
        tail where the full config has one.
        """
        if self.local_per_global is not None:
            n_layers = (self.local_per_global + 1) + 2   # 1 group + tail
        elif self.shared_attn_every:
            n_layers = 2 * min(self.shared_attn_every, 2) + 1
        else:
            n_layers = min(self.n_layers, 4)
        shrink = dict(
            n_layers=n_layers,
            d_model=128,
            n_heads=min(self.n_heads, 4) if self.n_heads else 0,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads else 0,
            head_dim=32,
            d_ff=256,
            vocab_size=512,
            n_experts=min(self.n_experts, 4),
            d_ff_expert=128 if self.d_ff_expert else 0,
            ssm_state=min(self.ssm_state, 16),
            ssm_head_dim=32 if self.ssm_state else 64,
            ssm_chunk=32,
            n_vis_tokens=16 if self.n_vis_tokens else 0,
            d_vis=64 if self.d_vis else 0,
            window=64,
            max_seq=256,
            q_chunk=64,
            k_chunk=64,
            ce_chunk=64,
            shared_attn_every=min(self.shared_attn_every, 2)
            if self.shared_attn_every else 0,
        )
        if self.n_kv_heads and shrink["n_heads"] % shrink["n_kv_heads"]:
            shrink["n_kv_heads"] = 1
        shrink.update(overrides)
        return dataclasses.replace(self, **shrink)


# Input shape set shared by all LM-family archs (the assignment's 4 shapes)
@dataclasses.dataclass(frozen=True)
class ShapeCfg:
    name: str
    seq_len: int
    global_batch: int
    kind: str                       # train | prefill | decode


SHAPES = {
    "train_4k": ShapeCfg("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCfg("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCfg("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCfg("long_500k", 524288, 1, "decode"),
}
