"""Gemma2-9B: alternating local/global attention, logit softcaps.
[arXiv:2408.00118; hf]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="gemma2-9b", family="dense",
    n_layers=42, d_model=3584, n_heads=16, n_kv_heads=8, head_dim=256,
    d_ff=14336, vocab_size=256000,
    local_per_global=1, window=4096,
    attn_logit_cap=50.0, final_logit_cap=30.0,
    rope_theta=10_000.0, tie_embeddings=True,
)
