"""Llama4-Scout 17B-active/16E: top-1 MoE + shared expert, early fusion.
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="llama4-scout-17b-a16e", family="moe",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8, head_dim=128,
    d_ff=8192, vocab_size=202048,
    n_experts=16, top_k=1, d_ff_expert=8192, n_shared_experts=1,
    rope_theta=500_000.0,
)
