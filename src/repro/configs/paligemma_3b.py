"""PaliGemma-3B: SigLIP stub + gemma decoder, prefix-LM.
[arXiv:2407.07726; hf]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="paligemma-3b", family="vlm",
    n_layers=18, d_model=2048, n_heads=8, n_kv_heads=1, head_dim=256,
    d_ff=16384, vocab_size=257216,
    n_vis_tokens=256, d_vis=1152,
    rope_theta=10_000.0, tie_embeddings=True,
)
