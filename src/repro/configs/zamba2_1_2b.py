"""Zamba2-1.2B: Mamba2 stack + ONE shared attention block reused over
depth. [arXiv:2411.15242; hf]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-1.2b", family="hybrid",
    n_layers=38, d_model=2048, n_heads=32, n_kv_heads=32, head_dim=64,
    d_ff=8192, vocab_size=32000,
    ssm_state=64, ssm_head_dim=64, ssm_expand=2, ssm_chunk=128,
    shared_attn_every=6,
    rope_theta=10_000.0,
)
