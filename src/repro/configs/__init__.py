"""Config registry for the 10 assigned architectures."""
from .base import ArchConfig, ShapeCfg, SHAPES

from . import (qwen3_32b, minitron_8b, gemma3_1b, gemma2_9b, dbrx_132b,
               llama4_scout_17b_a16e, mamba2_370m, hubert_xlarge,
               paligemma_3b, zamba2_1_2b)

_MODULES = [qwen3_32b, minitron_8b, gemma3_1b, gemma2_9b, dbrx_132b,
            llama4_scout_17b_a16e, mamba2_370m, hubert_xlarge,
            paligemma_3b, zamba2_1_2b]

REGISTRY = {m.CONFIG.name: m.CONFIG for m in _MODULES}
ARCH_NAMES = tuple(REGISTRY)


def get_config(name: str, smoke: bool = False, **overrides) -> ArchConfig:
    cfg = REGISTRY[name]
    if smoke:
        cfg = cfg.reduced()
    if overrides:
        import dataclasses
        cfg = dataclasses.replace(cfg, **overrides)
    return cfg


# Which (arch x shape) cells are runnable, with skip reasons (DESIGN.md
# §Arch-applicability documents these).
SKIPS = {
    ("hubert-xlarge", "decode_32k"): "encoder-only: no decode step",
    ("hubert-xlarge", "long_500k"): "encoder-only: no decode step",
    ("qwen3-32b", "long_500k"): "pure full attention: O(S) KV infeasible",
    ("minitron-8b", "long_500k"): "pure full attention: O(S) KV infeasible",
    ("gemma2-9b", "long_500k"):
        "1:1 global layers: 21-layer full 500k KV infeasible",
    ("dbrx-132b", "long_500k"): "pure full attention: O(S) KV infeasible",
    ("llama4-scout-17b-a16e", "long_500k"):
        "pure full attention: O(S) KV infeasible",
    ("paligemma-3b", "long_500k"): "pure full attention: O(S) KV infeasible",
}


def cell_runnable(arch: str, shape: str) -> bool:
    return (arch, shape) not in SKIPS
