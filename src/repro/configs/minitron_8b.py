"""Minitron-8B: width-pruned Nemotron-4 dense. [arXiv:2407.14679; hf]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="minitron-8b", family="dense",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=16384, vocab_size=256000,
    rope_theta=500_000.0,
)
