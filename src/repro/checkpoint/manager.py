"""Sharded, checksummed, async checkpointing with elastic restore.

Layout (one directory per step, atomically renamed into place):

    <root>/step_000420/
        manifest.json     tree structure, shapes, dtypes, CRCs, step
        arr_000000.npy    one file per leaf (per-host shard at scale)
        ...

Design points for 1000+-node deployments (single-process here, same
code path):
  * each host writes only the shards it owns (``addressable_shards``);
    host 0 writes the manifest after all data files exist;
  * writes go to ``<dir>.tmp`` then ``os.rename`` -- a crash mid-write
    can never yield a directory that looks valid;
  * every array carries a CRC32; restore verifies before device_put;
  * restore re-shards to whatever mesh/sharding the *new* job uses
    (elastic scaling: checkpoint written on 512 chips restores onto 8);
  * ``save_async`` offloads serialization to a worker thread -- the
    train loop only blocks on the previous save (double buffering).
"""
from __future__ import annotations

import dataclasses
import json
import os
import shutil
import threading
import zlib

import numpy as np
import jax
import ml_dtypes

# numpy can't natively (de)serialize ml_dtypes (bfloat16, fp8...);
# store them as same-width unsigned views + the real dtype in the manifest.
_ML_DTYPES = {
    "bfloat16": (ml_dtypes.bfloat16, np.uint16),
    "float8_e4m3fn": (ml_dtypes.float8_e4m3fn, np.uint8),
    "float8_e5m2": (ml_dtypes.float8_e5m2, np.uint8),
}


def _to_savable(arr: np.ndarray):
    name = arr.dtype.name if arr.dtype.names is None else str(arr.dtype)
    for dname, (mdt, view) in _ML_DTYPES.items():
        if name == dname:
            return arr.view(view), dname
    return arr, name


def _from_saved(arr: np.ndarray, dtype_name: str):
    if dtype_name in _ML_DTYPES:
        return arr.view(_ML_DTYPES[dtype_name][0])
    return arr


def _flatten_with_names(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    names = ["/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                      for k in path) for path, _ in flat]
    return names, [v for _, v in flat], treedef


@dataclasses.dataclass
class CheckpointManager:
    root: str
    keep: int = 3

    def __post_init__(self):
        os.makedirs(self.root, exist_ok=True)
        self._thread: threading.Thread | None = None

    # ------------------------------------------------------------- save
    def save(self, step: int, tree) -> str:
        host_tree = jax.tree_util.tree_map(np.asarray, tree)
        return self._write(step, host_tree)

    def save_async(self, step: int, tree) -> None:
        """Device->host copy happens now; disk I/O on a worker thread."""
        self.wait()
        host_tree = jax.tree_util.tree_map(np.asarray, tree)
        self._thread = threading.Thread(
            target=self._write, args=(step, host_tree), daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, host_tree) -> str:
        names, leaves, _ = _flatten_with_names(host_tree)
        final = os.path.join(self.root, f"step_{step:09d}")
        tmp = final + ".tmp"
        shutil.rmtree(tmp, ignore_errors=True)
        os.makedirs(tmp)
        manifest = {"step": step, "arrays": []}
        for i, (name, arr) in enumerate(zip(names, leaves)):
            arr = np.asarray(arr)
            saved, dtype_name = _to_savable(arr)
            fname = f"arr_{i:06d}.npy"
            np.save(os.path.join(tmp, fname), saved)
            manifest["arrays"].append({
                "name": name, "file": fname,
                "shape": list(arr.shape), "dtype": dtype_name,
                "crc32": zlib.crc32(saved.tobytes()) & 0xFFFFFFFF,
            })
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        shutil.rmtree(final, ignore_errors=True)
        os.rename(tmp, final)
        self._gc()
        return final

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.root, f"step_{s:09d}"),
                          ignore_errors=True)

    # ---------------------------------------------------------- restore
    def all_steps(self) -> list:
        out = []
        for d in os.listdir(self.root):
            if d.startswith("step_") and not d.endswith(".tmp") \
                    and os.path.exists(os.path.join(self.root, d,
                                                    "manifest.json")):
                out.append(int(d[5:]))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, like_tree, shardings=None):
        """Restore into the structure of ``like_tree``; re-shard onto the
        current mesh via ``shardings`` (same treedef) if given."""
        d = os.path.join(self.root, f"step_{step:09d}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        names, like_leaves, treedef = _flatten_with_names(like_tree)
        by_name = {a["name"]: a for a in manifest["arrays"]}
        missing = [n for n in names if n not in by_name]
        if missing:
            raise ValueError(f"checkpoint missing arrays: {missing[:5]}")
        spec_leaves = (jax.tree_util.tree_leaves(shardings)
                       if shardings is not None else [None] * len(names))
        out = []
        for name, like, spec in zip(names, like_leaves, spec_leaves):
            meta = by_name[name]
            arr = np.load(os.path.join(d, meta["file"]))
            crc = zlib.crc32(arr.tobytes()) & 0xFFFFFFFF
            if crc != meta["crc32"]:
                raise IOError(f"CRC mismatch for {name} in {d}")
            arr = _from_saved(arr, meta["dtype"])
            if tuple(arr.shape) != tuple(like.shape):
                raise ValueError(
                    f"{name}: shape {arr.shape} != expected {like.shape}")
            out.append(jax.device_put(arr, spec) if spec is not None
                       else jax.device_put(arr))
        return jax.tree_util.tree_unflatten(treedef, out)
