from .fixedpoint import (f32_to_fixed, fixed_to_f32, exact_sum, exact_psum,
                         exact_tree_sum, N_LIMBS, FRAC_BITS)

__all__ = ["f32_to_fixed", "fixed_to_f32", "exact_sum", "exact_psum",
           "exact_tree_sum", "N_LIMBS", "FRAC_BITS"]
