"""Bit-exact deterministic reductions via 128-bit fixed-point limbs.

Floating-point summation is not associative, so data-parallel gradient
all-reduces give run-to-run (and topology-to-topology) different bits --
a real obstacle to reproducible large-scale training.  The MCIM limb
machinery gives us the fix: encode each f32 into 128-bit two's-complement
fixed point (16-bit limbs, 2^-40 resolution), reduce in the *integer*
domain (exact, associative, order-invariant -- the compressor's
carry-free column sums survive any reduction tree), and carry-propagate
once at the end (the final adder).

  f32 -> fixed is exact up to one deterministic rounding (power-of-two
  scaling is exact in FP; only the final round-to-integer quantizes).
  fixed -> f32 rounds once more.  Everything in between is exact.

Used by runtime.trainer's ``exact_accum`` mode for cross-microbatch and
cross-replica gradient accumulation.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ..core import limbs as L

N_LIMBS = 8          # 128 bits
FRAC_BITS = 40       # resolution 2^-40; integer headroom 2^(87)
_TOP_BIT = jnp.uint32(0x8000)


@functools.partial(jax.jit, static_argnames=("frac_bits", "n_limbs"))
def f32_to_fixed(x: jax.Array, frac_bits: int = FRAC_BITS,
                 n_limbs: int = N_LIMBS) -> jax.Array:
    """f32 (...,) -> (..., n_limbs) uint32 two's-complement fixed point."""
    x = jnp.where(jnp.isfinite(x), x, 0.0).astype(jnp.float32)
    sign = x < 0
    ax = jnp.abs(x)
    m, e = jnp.frexp(ax)                       # ax = m * 2^e, m in [0.5, 1)
    mi = jnp.round(m * (1 << 24)).astype(jnp.uint32)      # 24-bit mantissa
    shift = e - 24 + frac_bits                 # value = mi * 2^shift
    # negative shift: truncate low bits of the mantissa
    neg = jnp.maximum(-shift, 0).astype(jnp.uint32)
    mi = jnp.where(neg < 32, mi >> jnp.minimum(neg, 31), 0)
    shift = jnp.maximum(shift, 0)

    k0 = (shift // 16).astype(jnp.int32)       # limb offset
    r = (shift % 16).astype(jnp.uint32)        # intra-limb bit offset
    mi_lo = mi & 0xFFFF
    mi_hi = mi >> 16
    s_lo = mi_lo << r                          # < 2^31
    s_hi = mi_hi << r                          # < 2^24
    p0 = s_lo & 0xFFFF
    p1 = (s_lo >> 16) + (s_hi & 0xFFFF)
    p2 = s_hi >> 16

    k = jnp.arange(n_limbs)
    tgt = k0[..., None]
    kk = jnp.broadcast_to(k, tgt.shape[:-1] + (n_limbs,))
    mag = (jnp.where(kk == tgt, p0[..., None], 0)
           + jnp.where(kk == tgt + 1, p1[..., None], 0)
           + jnp.where(kk == tgt + 2, p2[..., None], 0)).astype(jnp.uint32)

    # two's complement for negatives: NOT + 1, carry-propagated
    comp = (jnp.uint32(0xFFFF) - mag)
    comp = comp.at[..., 0].add(1)
    comp = L.final_adder_1ca(comp, n_limbs)
    return jnp.where(sign[..., None], comp, mag)


@functools.partial(jax.jit, static_argnames=("frac_bits",))
def fixed_to_f32(limbs: jax.Array, frac_bits: int = FRAC_BITS) -> jax.Array:
    """(..., n_limbs) two's-complement column sums -> f32 (deterministic)."""
    n = limbs.shape[-1]
    norm = L.final_adder_1ca(limbs, n)         # canonical mod 2^(16n)
    neg = (norm[..., -1] & _TOP_BIT) != 0
    comp = (jnp.uint32(0xFFFF) - norm).at[..., 0].add(1)
    comp = L.final_adder_1ca(comp, n)
    mag = jnp.where(neg[..., None], comp, norm)
    scale = 2.0 ** (16.0 * jnp.arange(n) - frac_bits)
    val = jnp.sum(mag.astype(jnp.float32) * scale.astype(jnp.float32),
                  axis=-1)
    return jnp.where(neg, -val, val)


def fixed_add(a: jax.Array, b: jax.Array) -> jax.Array:
    """Carry-free accumulation (columns stay < 2^32 for < 2^16 terms)."""
    return a + b


def exact_sum(x: jax.Array, axis: int = 0) -> jax.Array:
    """Order-invariant sum over ``axis``: same bits for any permutation."""
    fixed = f32_to_fixed(x)
    acc = jnp.sum(fixed.astype(jnp.uint32), axis=axis, dtype=jnp.uint32)
    return fixed_to_f32(acc)


def exact_psum(x: jax.Array, axis_name: str) -> jax.Array:
    """Deterministic psum (use inside shard_map): integer-domain reduce."""
    fixed = f32_to_fixed(x)
    acc = jax.lax.psum(fixed.astype(jnp.int32), axis_name)
    return fixed_to_f32(acc.astype(jnp.uint32))


def exact_tree_sum(trees: list):
    """Deterministic elementwise sum of a list of pytrees (microbatches)."""
    def one(*leaves):
        stacked = jnp.stack([l.astype(jnp.float32) for l in leaves])
        return exact_sum(stacked, axis=0)
    return jax.tree_util.tree_map(one, *trees)
