"""AdamW with decoupled weight decay, global-norm clipping, schedules.

Self-contained (no optax).  Moments are f32 regardless of param dtype;
parameters update in their own dtype (bf16 params + f32 moments is the
production configuration -- see DESIGN.md §Numerics).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: Optional[float] = 1.0
    schedule: str = "cosine"        # cosine | constant | linear
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


def schedule_lr(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    if cfg.schedule == "constant":
        decay = 1.0
    elif cfg.schedule == "linear":
        frac = jnp.clip((step - cfg.warmup_steps)
                        / max(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
        decay = 1.0 - (1.0 - cfg.min_lr_ratio) * frac
    else:                            # cosine
        frac = jnp.clip((step - cfg.warmup_steps)
                        / max(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
        decay = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) \
            * 0.5 * (1 + jnp.cos(jnp.pi * frac))
    return cfg.lr * warm * decay


def _decay_mask(path: tuple) -> bool:
    """No weight decay for norms / biases / scalar SSM params."""
    name = "/".join(str(getattr(k, "key", k)) for k in path)
    nodecay = ("norm", "bias", "A_log", "D", "dt_bias", "mask_embed")
    return not any(t in name for t in nodecay)


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree_util.tree_leaves(tree)]
    return jnp.sqrt(sum(leaves))


def init_state(params):
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "step": jnp.zeros((), jnp.int32),
        "m": jax.tree_util.tree_map(zeros, params),
        "v": jax.tree_util.tree_map(zeros, params),
    }


def apply_updates(params, grads, state, cfg: AdamWConfig):
    """Returns (new_params, new_state, stats)."""
    step = state["step"] + 1
    lr = schedule_lr(cfg, step)

    gnorm = global_norm(grads)
    if cfg.clip_norm is not None:
        scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
        grads = jax.tree_util.tree_map(
            lambda g: (g.astype(jnp.float32) * scale), grads)
    else:
        grads = jax.tree_util.tree_map(
            lambda g: g.astype(jnp.float32), grads)

    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    paths = [p for p, _ in jax.tree_util.tree_flatten_with_path(params)[0]]
    flat_p = jax.tree_util.tree_leaves(params)
    flat_m = jax.tree_util.tree_leaves(state["m"])
    flat_v = jax.tree_util.tree_leaves(state["v"])

    new_p, new_m, new_v = [], [], []
    for path, p, g, m, v in zip(paths, flat_p, flat_g, flat_m, flat_v):
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        update = (m / bc1) / (jnp.sqrt(v / bc2) + cfg.eps)
        if cfg.weight_decay and _decay_mask(path):
            update = update + cfg.weight_decay * p.astype(jnp.float32)
        new_p.append((p.astype(jnp.float32) - lr * update).astype(p.dtype))
        new_m.append(m)
        new_v.append(v)

    unflatten = jax.tree_util.tree_unflatten
    new_state = {"step": step, "m": unflatten(treedef, new_m),
                 "v": unflatten(treedef, new_v)}
    return (unflatten(treedef, new_p), new_state,
            {"grad_norm": gnorm, "lr": lr})
