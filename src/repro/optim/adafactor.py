"""Adafactor (Shazeer & Stern 2018): factored second moments.

At 1000+-node scale, Adam's two f32 moments are 8 bytes/param -- often
more HBM than the model itself.  Adafactor keeps row/column factored
second-moment statistics for matrices (O(n+m) instead of O(nm)),
cutting optimizer state by ~2000x for large matrices; vectors fall back
to full second moments.  Standard production choice for memory-tight
training (T5, PaLM).

Implements: factored v via row/col EMAs, update clipping by RMS,
relative step size or fixed lr, decoupled weight decay.  Momentum is
omitted (beta1=0 variant) to keep state minimal, as in T5.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdafactorConfig:
    lr: float = 1e-2
    decay: float = 0.8            # t^-decay second-moment EMA schedule
    eps1: float = 1e-30
    clip_threshold: float = 1.0
    weight_decay: float = 0.0
    min_dim_factored: int = 2     # factor matrices with both dims >= this


def _factored(shape, cfg) -> bool:
    return len(shape) >= 2 and shape[-1] >= cfg.min_dim_factored \
        and shape[-2] >= cfg.min_dim_factored


def init_state(params, cfg: AdafactorConfig = AdafactorConfig()):
    def one(p):
        if _factored(p.shape, cfg):
            return {
                "vr": jnp.zeros(p.shape[:-1], jnp.float32),          # rows
                "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
            }
        return {"v": jnp.zeros(p.shape, jnp.float32)}
    return {"step": jnp.zeros((), jnp.int32),
            "v": jax.tree_util.tree_map(one, params)}


def apply_updates(params, grads, state, cfg: AdafactorConfig):
    step = state["step"] + 1
    t = step.astype(jnp.float32)
    beta2 = 1.0 - t ** (-cfg.decay)

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = jax.tree_util.tree_leaves(grads)
    flat_v = treedef.flatten_up_to(state["v"])

    new_p, new_v = [], []
    for p, g, v in zip(flat_p, flat_g, flat_v):
        g = g.astype(jnp.float32)
        g2 = g * g + cfg.eps1
        if _factored(p.shape, cfg):
            vr = beta2 * v["vr"] + (1 - beta2) * g2.mean(axis=-1)
            vc = beta2 * v["vc"] + (1 - beta2) * g2.mean(axis=-2)
            # rank-1 reconstruction of 1/sqrt(v)
            r = vr / jnp.maximum(vr.mean(axis=-1, keepdims=True), cfg.eps1)
            upd = g / (jnp.sqrt(r)[..., None] * jnp.sqrt(vc)[..., None, :]
                       + cfg.eps1)
            nv = {"vr": vr, "vc": vc}
        else:
            vf = beta2 * v["v"] + (1 - beta2) * g2
            upd = g / (jnp.sqrt(vf) + cfg.eps1)
            nv = {"v": vf}
        # update clipping by RMS (Adafactor eq. 12)
        rms = jnp.sqrt(jnp.mean(upd * upd))
        upd = upd / jnp.maximum(1.0, rms / cfg.clip_threshold)
        pf = p.astype(jnp.float32)
        if cfg.weight_decay:
            pf = pf - cfg.lr * cfg.weight_decay * pf
        new_p.append((pf - cfg.lr * upd).astype(p.dtype))
        new_v.append(nv)

    return (jax.tree_util.tree_unflatten(treedef, new_p),
            {"step": step,
             "v": jax.tree_util.tree_unflatten(treedef, new_v)},
            {"beta2": beta2})


def state_bytes(params) -> tuple:
    """(adam_bytes, adafactor_bytes) for a param tree -- the scale claim."""
    adam = sum(2 * 4 * p.size for p in jax.tree_util.tree_leaves(params))
    cfg = AdafactorConfig()
    af = 0
    for p in jax.tree_util.tree_leaves(params):
        if _factored(p.shape, cfg):
            af += 4 * (int(np.prod(p.shape[:-1]))
                       + int(np.prod(p.shape[:-2] + p.shape[-1:])))
        else:
            af += 4 * p.size
    return adam, af


import numpy as np  # noqa: E402  (used by state_bytes)
