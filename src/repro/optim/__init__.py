from .adamw import (AdamWConfig, init_state, apply_updates, schedule_lr,
                    global_norm)
from . import compress

__all__ = ["AdamWConfig", "init_state", "apply_updates", "schedule_lr",
           "global_norm", "compress"]
