"""Int8 gradient compression with error feedback (distributed-optimization
trick for cross-pod all-reduce traffic).

Gradients are quantized to int8 with per-tensor-row scales *before* the
data-parallel reduction; the quantization residual is carried in an
error-feedback buffer so the compression bias vanishes over steps
(Karimireddy et al. 2019).  Collective bytes drop 4x (f32) / 2x (bf16),
directly shrinking the roofline collective term for DP-dominated steps.

The quantize/dequantize pair reuses the MCIM int8 machinery
(kernels.int8_matmul.quantize_rows).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..kernels.int8_matmul import quantize_rows


def init_error(params):
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)


def _q(x):
    flat = x.reshape(-1, x.shape[-1]) if x.ndim > 1 else x.reshape(1, -1)
    q, s = quantize_rows(flat, axis=1)
    return q.reshape(x.shape), s


def _dq(q, s, shape):
    last = shape[-1] if len(shape) > 1 else int(jnp.size(q))
    flat = q.reshape(-1, last).astype(jnp.float32)
    return (flat * s.reshape(-1, 1)).reshape(shape)


def compress_grads(grads, error):
    """Returns (int8 tree, scales tree, new_error tree)."""
    def one(g, e):
        corrected = g.astype(jnp.float32) + e
        q, s = _q(corrected)
        back = _dq(q, s, corrected.shape)
        return q, s, corrected - back
    flat = jax.tree_util.tree_map(one, grads, error)
    qs = jax.tree_util.tree_map(lambda t: t[0], flat,
                                is_leaf=lambda x: isinstance(x, tuple))
    ss = jax.tree_util.tree_map(lambda t: t[1], flat,
                                is_leaf=lambda x: isinstance(x, tuple))
    es = jax.tree_util.tree_map(lambda t: t[2], flat,
                                is_leaf=lambda x: isinstance(x, tuple))
    return qs, ss, es


def decompress_grads(qs, ss, shapes):
    return jax.tree_util.tree_map(
        lambda q, s, g: _dq(q, s, g.shape), qs, ss, shapes)


def compressed_psum(grads, error, axis_name: str):
    """int8 all-reduce with error feedback, for use inside shard_map.

    All replicas first agree on a SHARED per-row scale (pmax of local
    amax -- int8 values from different replicas are only summable if
    they share a scale), then the int8 grads are summed exactly in
    int32 (the MCIM carry-free compressor idea applied to the
    collective) and dequantized once.
    """
    def one(g, e):
        corrected = g.astype(jnp.float32) + e
        flat = corrected.reshape(-1, corrected.shape[-1]) \
            if corrected.ndim > 1 else corrected.reshape(1, -1)
        amax = jnp.max(jnp.abs(flat), axis=1)
        amax = jax.lax.pmax(amax, axis_name)          # shared scale
        s = jnp.where(amax == 0, 1.0, amax / 127.0)
        q = jnp.clip(jnp.round(flat / s[:, None]), -127, 127
                     ).astype(jnp.int8)
        back = _dq(q, s, corrected.shape)
        new_e = corrected - back
        q_sum = jax.lax.psum(q.astype(jnp.int32), axis_name)
        n = jax.lax.psum(jnp.ones((), jnp.float32), axis_name)
        approx = _dq(q_sum, s, corrected.shape) / n
        return approx, new_e
    pairs = jax.tree_util.tree_map(one, grads, error)
    out = jax.tree_util.tree_map(lambda t: t[0], pairs,
                                 is_leaf=lambda x: isinstance(x, tuple))
    new_e = jax.tree_util.tree_map(lambda t: t[1], pairs,
                                   is_leaf=lambda x: isinstance(x, tuple))
    return out, new_e
