from .philox import philox4x32, random_u32, random_uniform, random_tokens

__all__ = ["philox4x32", "random_u32", "random_uniform", "random_tokens"]
