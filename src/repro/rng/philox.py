"""Philox-4x32-10 counter-based RNG built on the MCIM 32x32->64 multiply.

TPUs have no native 64-bit integer multiply; the Philox round function
needs mulhi/mullo of 32-bit lanes, which we synthesize from the paper's
folded 16-bit-limb machinery (core.mul32x32_64).  Counter-based RNG is
what makes the data pipeline *order-independent and resumable*: sample i
of epoch e is a pure function of (seed, e, i), so restarts and elastic
re-sharding never replay or skip data.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ..core import mul32x32_64

PHILOX_M0 = jnp.uint32(0xD2511F53)
PHILOX_M1 = jnp.uint32(0xCD9E8D57)
W32_0 = jnp.uint32(0x9E3779B9)
W32_1 = jnp.uint32(0xBB67AE85)


@functools.partial(jax.jit, static_argnames=("rounds",))
def philox4x32(counter: jax.Array, key: jax.Array, rounds: int = 10):
    """counter: (..., 4) uint32, key: (..., 2) uint32 -> (..., 4) uint32."""
    c0, c1, c2, c3 = [counter[..., i] for i in range(4)]
    k0, k1 = key[..., 0], key[..., 1]
    for _ in range(rounds):
        lo0, hi0 = mul32x32_64(PHILOX_M0, c0)
        lo1, hi1 = mul32x32_64(PHILOX_M1, c2)
        c0, c1, c2, c3 = (hi1 ^ c1 ^ k0, lo1, hi0 ^ c3 ^ k1, lo0)
        k0 = k0 + W32_0
        k1 = k1 + W32_1
    return jnp.stack([c0, c1, c2, c3], axis=-1)


def random_u32(seed: int, stream: int, offsets: jax.Array) -> jax.Array:
    """Deterministic uint32 per offset: (N,) int -> (N, 4) uint32 lanes."""
    offsets = offsets.astype(jnp.uint32)
    counter = jnp.stack(
        [offsets, jnp.zeros_like(offsets),
         jnp.full_like(offsets, stream & 0xFFFFFFFF),
         jnp.zeros_like(offsets)], axis=-1)
    key = jnp.broadcast_to(
        jnp.asarray([seed & 0xFFFFFFFF, (seed >> 32) & 0xFFFFFFFF],
                    jnp.uint32), offsets.shape + (2,))
    return philox4x32(counter, key)


def random_uniform(seed: int, stream: int, offsets: jax.Array) -> jax.Array:
    """(N,) offsets -> (N,) float32 in [0, 1)."""
    bits = random_u32(seed, stream, offsets)[..., 0]
    return bits.astype(jnp.float32) * (1.0 / 4294967296.0)


def random_tokens(seed: int, stream: int, offsets: jax.Array,
                  vocab: int) -> jax.Array:
    """Deterministic synthetic token ids (for the synthetic pipeline)."""
    bits = random_u32(seed, stream, offsets)[..., 0]
    return (bits % jnp.uint32(vocab)).astype(jnp.int32)
