"""Backend registry: how one bank instance actually multiplies.

PR 2 hard-coded two string branches ("core" / "kernel") inside the bank
monolith, with a silent core fallback for Karatsuba instances.  This
module replaces the branches with registered ``InstanceBackend`` objects
keyed by ``(arch, capability)``:

  * ``arch``        -- the planner architecture: star | fb | ff | karatsuba
  * ``capability``  -- the execution substrate: "core" (pure jnp
                       ``mcim_mul``), "kernel" (one Pallas launch per
                       instance) or "fused" (the whole bank round as ONE
                       ``kernels.bank_fold`` megakernel launch).

Every planner arch now has a real Pallas path -- Star/FB/FF through the
``kernels.mcim_fold`` FB/FF schedules, Karatsuba through the new folded
CT=3 Karatsuba schedule in the same kernel family -- so the "kernel"
capability needs no core fallback.  New substrates (e.g. a non-interpret
TPU build, a GPU port) register additional capabilities without touching
the engine.

The "fused" capability is bank-level: dispatch is built by
``kernels.bank_fold.make_fused_dispatch`` over the *whole* instance
list, so its ``make_mul`` only serves as the per-instance fallback (the
sharded path, direct ``be.make_mul`` callers) and its ``working_set`` is
the time-shared datapath footprint -- identical for every instance and
NOT summed across the bank (see ``Bank.report``).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable

from ..mcim import MCIMConfig, mcim_mul

CAPABILITIES = ("core", "kernel", "fused")
#: Back-compat alias: the PR-2 bank exposed the capability names as BACKENDS.
BACKENDS = CAPABILITIES


@dataclasses.dataclass(frozen=True)
class InstanceBackend:
    """One (arch, capability) execution strategy for a bank instance.

    ``make_mul(cfg, la, lb)`` returns the batched multiplier
    ``(B, LA) x (B, LB) -> (B, LA+LB)`` for that instance;
    ``working_set(cfg, la, lb, tile_b)`` its per-step VMEM footprint in
    bytes (the TPU analogue of the paper's silicon area).
    """
    arch: str
    capability: str
    make_mul: Callable        # (MCIMConfig, la, lb) -> batched mul fn
    working_set: Callable     # (MCIMConfig, la, lb, tile_b) -> bytes


_REGISTRY: dict = {}


def register_backend(backend: InstanceBackend) -> InstanceBackend:
    _REGISTRY[(backend.arch, backend.capability)] = backend
    return backend


def get_backend(arch: str, capability: str) -> InstanceBackend:
    try:
        return _REGISTRY[(arch, capability)]
    except KeyError:
        raise ValueError(
            f"no backend registered for arch={arch!r} "
            f"capability={capability!r}; "
            f"registered: {sorted(_REGISTRY)}") from None


def registered_backends() -> tuple:
    """Snapshot of the registry keys (arch, capability)."""
    return tuple(sorted(_REGISTRY))


# ------------------------------------------------------------- core backends

def _core_mul(cfg: MCIMConfig, la: int, lb: int):
    return functools.partial(mcim_mul, config=cfg)


def _vmem(cfg: MCIMConfig, la: int, lb: int, tile_b: int) -> int:
    """Working set via the kernel-family area model; the core capability
    reports the same figure (it models the *design*, not the substrate)."""
    from repro.kernels.mcim_fold import vmem_bytes_per_step
    if cfg.arch == "star":
        return vmem_bytes_per_step(la, lb, 1, tile_b)
    if cfg.arch == "ff":
        return vmem_bytes_per_step(la, lb, cfg.ct, tile_b, schedule="ff")
    if cfg.arch == "karatsuba":
        return vmem_bytes_per_step(la, lb, cfg.ct, tile_b,
                                   schedule="karatsuba")
    return vmem_bytes_per_step(la, lb, cfg.ct, tile_b)


for _arch in ("star", "fb", "ff", "karatsuba"):
    register_backend(InstanceBackend(
        arch=_arch, capability="core",
        make_mul=_core_mul, working_set=_vmem))


# ----------------------------------------------------------- kernel backends

def _kernel_fold_mul(cfg: MCIMConfig, la: int, lb: int):
    from repro.kernels.mcim_fold import big_mul
    if cfg.arch == "star":
        return functools.partial(big_mul, ct=1, schedule="fb")
    if cfg.arch == "karatsuba":
        return functools.partial(big_mul, ct=3, schedule="karatsuba")
    return functools.partial(big_mul, ct=cfg.ct, schedule=cfg.arch)


for _arch in ("star", "fb", "ff", "karatsuba"):
    register_backend(InstanceBackend(
        arch=_arch, capability="kernel",
        make_mul=_kernel_fold_mul, working_set=_vmem))


# ------------------------------------------------------------ fused backends

def _fused_vmem(cfg: MCIMConfig, la: int, lb: int, tile_b: int) -> int:
    """Per-step footprint of the fused datapath ALL instances time-share.

    Independent of ``cfg``: the megakernel runs every arch through the
    same windowed-schoolbook datapath, so one figure covers the bank.
    """
    from repro.kernels.bank_fold import vmem_bytes_per_step
    return vmem_bytes_per_step(la, lb, tile_b)


for _arch in ("star", "fb", "ff", "karatsuba"):
    register_backend(InstanceBackend(
        arch=_arch, capability="fused",
        make_mul=_kernel_fold_mul,        # per-instance fallback path
        working_set=_fused_vmem))

del _arch


# --------------------------------------------------------------- mul caching

@functools.lru_cache(maxsize=256)
def cached_mul(arch: str, capability: str, cfg: MCIMConfig,
               la: int, lb: int) -> Callable:
    """Backend multiplier shared across ``Bank`` instantiations.

    Repeated ``generate()`` of the same registry point used to rebuild
    (and re-trace) identical instance kernels per ``Bank``; keying on
    the frozen ``(arch, capability, cfg, la, lb)`` tuple lets every bank
    with the same instance shape reuse one jitted multiplier -- jax's
    own jit cache is keyed on function identity, so returning the *same*
    callable is what makes the traces shareable.
    """
    return get_backend(arch, capability).make_mul(cfg, la, lb)
