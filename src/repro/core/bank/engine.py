"""Bank execution engine: run ``planner.Plan`` objects as real multipliers.

``planner.plan_throughput`` picks a *bank* of multiplier instances (e.g.
TP=3.5 -> three Star + one CT=2 MCIM).  This module makes plans
executable: a batch of multiplications is dispatched across the plan's
instances by a pluggable :mod:`.schedule` policy exactly the way the
paper's Sec. V-E use case issues work to the silicon bank.

The resulting engine is

  * bit-exact: every instance runs its registered :mod:`.backends`
    multiplier (pure-jnp ``mcim_mul`` or a Pallas kernel), so the
    reassembled batch equals the Python-int oracle regardless of policy;
  * cycle-accounted: the dispatch schedule is simulated once per batch
    size (and cached), giving per-instance busy cycles and the bank
    makespan, so measured throughput can be checked against
    ``Plan.throughput``;
  * jit/pjit-compatible: the schedule is static for a given batch size,
    so ``execute`` lowers to gathers + batched multiplies + scatters
    (and :mod:`.sharded` can replicate it across a mesh axis).
"""
from __future__ import annotations

import dataclasses
import functools
from fractions import Fraction

import numpy as np
import jax
import jax.numpy as jnp

from .. import limbs as L
from ..mcim import MCIMConfig
from ..planner import Plan
from .backends import BACKENDS, cached_mul, get_backend
from .schedule import (completion_cycles, get_scheduler,
                       histogram_percentile, latency_histogram)


# ------------------------------------------------------------------ reports

@dataclasses.dataclass(frozen=True)
class InstanceReport:
    """Per-instance cycle accounting for one executed batch."""
    config: MCIMConfig
    n_ops: int
    busy_cycles: int          # n_ops * ct: cycles the datapath is occupied

    @property
    def ct(self) -> int:
        return self.config.ct


@dataclasses.dataclass(frozen=True)
class BankReport:
    """Throughput accounting for one executed batch."""
    batch: int
    cycles: int                       # bank makespan
    instances: tuple                  # tuple[InstanceReport]
    plan_throughput: Fraction
    working_set_bytes: int            # sum of per-instance VMEM footprints
    scheduler: str = "round_robin"    # policy that produced the makespan
    #: per-request latency histogram, sorted ((cycles, count), ...):
    #: admission (the policy's arrival trace, cycle 0 for batch
    #: policies) to completion -- the same accounting path the online
    #: serving layer reports p50/p99 from
    latency_hist: tuple = ()
    # filled in by CompiledDesign.report() (the bank itself has no spec,
    # so no clock/stress context to model power with)
    energy_per_op_pj: float | None = None
    peak_power_mw: float | None = None

    @property
    def measured_throughput(self) -> Fraction:
        return Fraction(self.batch, self.cycles) if self.cycles else Fraction(0)

    @property
    def utilization(self) -> float:
        if not self.cycles:
            return 0.0
        return float(self.measured_throughput / self.plan_throughput)

    @property
    def energy_pj(self) -> float | None:
        """Total modeled switching energy of the batch."""
        if self.energy_per_op_pj is None:
            return None
        return self.batch * self.energy_per_op_pj

    def latency_percentile(self, q: float):
        """Latency (cycles) at quantile ``q`` of the per-request
        histogram; None for an empty batch."""
        return histogram_percentile(self.latency_hist, q)

    @property
    def latency_p50(self):
        return self.latency_percentile(0.50)

    @property
    def latency_p99(self):
        return self.latency_percentile(0.99)


# ------------------------------------------------------------------ the bank

class Bank:
    """Executable multiplier bank for one ``planner.Plan``.

    ``execute(a, b)`` multiplies a batch of limb vectors
    (B, LA) x (B, LB) -> (B, LA+LB) bit-exactly; ``last_report`` /
    ``report(batch)`` exposes the cycle accounting.  ``backend`` picks
    the instance substrate ("core" | "kernel" | "fused"), ``scheduler``
    the dispatch policy ("round_robin" | "greedy" | "streaming" or any
    registered :class:`~.schedule.Scheduler`).

    The "fused" backend collapses the whole bank round into ONE
    ``kernels.bank_fold`` megakernel launch (vs one launch per busy
    instance on "kernel"); :meth:`launch_count` reports the difference
    from the traced jaxpr.
    """

    # each distinct batch size compiles its own dispatch; bound the set
    # (FIFO eviction) so ragged serving batches cannot grow it unboundedly
    MAX_COMPILED = 32

    def __init__(self, plan: Plan, bits_a: int, bits_b: int, *,
                 backend: str = "core", scheduler="round_robin",
                 tile_b: int = 256):
        if backend not in BACKENDS:
            raise ValueError(f"backend must be one of {BACKENDS}")
        self.plan = plan
        self.bits_a, self.bits_b = bits_a, bits_b
        self.la = L.n_limbs_for_bits(bits_a)
        self.lb = L.n_limbs_for_bits(bits_b)
        self.backend = backend
        self.scheduler = get_scheduler(scheduler)
        self.tile_b = tile_b
        # expand [(count, cfg)] -> flat instance list, Stars first so the
        # fast units drain the head of the queue like the paper's bank
        self.instances = tuple(
            cfg for count, cfg in plan.configs for _ in range(count))
        if not self.instances:
            raise ValueError("plan has no instances")
        self._cts = tuple(cfg.ct for cfg in self.instances)
        self._backends = tuple(get_backend(cfg.arch, backend)
                               for cfg in self.instances)
        # cached across Bank instantiations: same instance shape -> same
        # callable -> shared jit trace (see backends.cached_mul)
        self._muls = tuple(cached_mul(cfg.arch, backend, cfg,
                                      self.la, self.lb)
                           for cfg in self.instances)
        signedness = {cfg.signed for cfg in self.instances}
        if backend == "fused" and len(signedness) > 1:
            raise ValueError(
                "fused backend needs uniform signedness across instances "
                "(the correction pass is applied bank-wide)")
        self._signed = self.instances[0].signed
        self._compiled = {}           # batch size -> jitted execute
        self.last_report = None

    # -------------------------------------------------------------- reports
    def report(self, batch: int, scheduler=None) -> BankReport:
        """Cycle accounting for one batch.  ``scheduler`` overrides the
        bank's policy for this report only (e.g. a StreamingScheduler
        carrying a recorded arrival trace) without recompiling dispatch."""
        sched = self.scheduler if scheduler is None else \
            get_scheduler(scheduler)
        assign, cycles = sched.schedule(self._cts, batch)
        insts = tuple(
            InstanceReport(cfg, len(ops), len(ops) * cfg.ct)
            for cfg, ops in zip(self.instances, assign))
        # per-request latency: completion minus admission, where
        # admission is the policy's own arrival trace (cycle 0 for the
        # batch policies).  Arrival-aware policies expose arrivals_for.
        arrivals = sched.arrivals_for(batch) \
            if hasattr(sched, "arrivals_for") else (0,) * batch
        finish = completion_cycles(self._cts, assign, arrivals)
        hist = latency_histogram(f - a for f, a in zip(finish, arrivals))
        footprints = tuple(
            be.working_set(cfg, self.la, self.lb, self.tile_b)
            for cfg, be in zip(self.instances, self._backends))
        # fused instances time-share ONE datapath, so the bank's working
        # set is the largest instance footprint, not the sum
        ws = max(footprints) if self.backend == "fused" else sum(footprints)
        return BankReport(batch=batch, cycles=cycles, instances=insts,
                          plan_throughput=self.plan.throughput,
                          working_set_bytes=ws,
                          scheduler=sched.name,
                          latency_hist=hist)

    # -------------------------------------------------------------- execute
    def dispatch_fn(self, batch: int):
        """The pure (un-jitted) dispatch closure for one batch size.

        Exposed so :mod:`.sharded` can wrap it in shard_map; ``execute``
        wraps it in ``jax.jit``.
        """
        assign, _ = self.scheduler.schedule(self._cts, batch)
        if self.backend == "fused":
            from repro.kernels.bank_fold import make_fused_dispatch
            return make_fused_dispatch(assign, self.instances,
                                       self.la, self.lb, batch,
                                       signed=self._signed)
        idx = [np.asarray(ops, np.int32) for ops in assign]
        muls = self._muls
        la, lb = self.la, self.lb

        def run(a, b):
            out = jnp.zeros((batch, la + lb), L.LIMB_DTYPE)
            for ops, mul in zip(idx, muls):
                if ops.size == 0:
                    continue
                part = mul(a[ops], b[ops])
                out = out.at[ops].set(part)
            return out

        return run

    def _build(self, batch: int):
        return jax.jit(self.dispatch_fn(batch))

    def launch_count(self, batch: int) -> int:
        """Pallas launches one bank round issues for this batch size.

        Traced from the dispatch jaxpr (no execution): exactly 1 on the
        fused path, one per busy instance on the per-instance kernel
        path, 0 on the pure-jnp core path.
        """
        from repro.launch.roofline import count_pallas_launches
        a = jnp.zeros((batch, self.la), L.LIMB_DTYPE)
        b = jnp.zeros((batch, self.lb), L.LIMB_DTYPE)
        return count_pallas_launches(self.dispatch_fn(batch), a, b)

    def execute(self, a: jax.Array, b: jax.Array) -> jax.Array:
        """(B, LA) x (B, LB) -> (B, LA+LB) limbs, bit-exact."""
        if a.ndim == 1:
            return self.execute(a[None], b[None])[0]
        batch = a.shape[0]
        if b.shape[0] != batch:
            # without this, the gather in dispatch_fn clamps out-of-range
            # op indices and silently returns wrong products
            raise ValueError(
                f"batch mismatch: a has {batch} ops, b has {b.shape[0]}")
        if a.shape[-1] != self.la or b.shape[-1] != self.lb:
            raise ValueError(
                f"operand limbs {a.shape[-1]}x{b.shape[-1]} do not match "
                f"bank widths {self.la}x{self.lb}")
        fn = self._compiled.get(batch)
        if fn is None:
            if len(self._compiled) >= self.MAX_COMPILED:
                self._compiled.pop(next(iter(self._compiled)))
            fn = self._compiled[batch] = self._build(batch)
        self.last_report = self.report(batch)
        return fn(a, b)

    def describe(self) -> str:
        return (f"Bank[{self.plan.describe()}  backend={self.backend}  "
                f"scheduler={self.scheduler.name}  "
                f"{len(self.instances)} instances]")


# ------------------------------------------------------------------ module API

@functools.lru_cache(maxsize=64)
def _bank_for(plan: Plan, bits_a: int, bits_b: int, backend: str,
              scheduler: str = "round_robin") -> Bank:
    return Bank(plan, bits_a, bits_b, backend=backend, scheduler=scheduler)


def execute(plan: Plan, a: jax.Array, b: jax.Array, *,
            backend: str = "core",
            scheduler: str = "round_robin") -> jax.Array:
    """One-shot bank execution: dispatch a batch across ``plan``'s
    instances and return the (B, LA+LB) limb products.

    Operand bit widths are taken from the limb counts.  Banks are cached
    per (plan, widths, backend, scheduler), so repeated calls re-use the
    compiled dispatch.  Use ``last_report(plan, a, b)`` -- or a ``Bank``
    object directly -- for the cycle accounting.
    """
    la = a.shape[-1] if a.ndim > 1 else a.shape[0]
    lb = b.shape[-1] if b.ndim > 1 else b.shape[0]
    bank = _bank_for(plan, la * L.RADIX_BITS, lb * L.RADIX_BITS, backend,
                     scheduler)
    return bank.execute(a, b)


def last_report(plan: Plan, a: jax.Array, b: jax.Array, *,
                backend: str = "core",
                scheduler: str = "round_robin") -> BankReport:
    """Cycle-accounting report for the batch shape of (a, b)."""
    la = a.shape[-1] if a.ndim > 1 else a.shape[0]
    lb = b.shape[-1] if b.ndim > 1 else b.shape[0]
    bank = _bank_for(plan, la * L.RADIX_BITS, lb * L.RADIX_BITS, backend,
                     scheduler)
    batch = a.shape[0] if a.ndim > 1 else 1
    return bank.report(batch)
