"""Scheduler layer: dispatch policies for multiplier banks.

The folding literature (Möller et al., "Model-based Hardware Design for
FPGAs using Folding Transformations"; "Operand Folding Hardware
Multipliers") treats the *schedule* -- which operation runs on which
shared instance on which cycle -- as a first-class, swappable design
object.  This module does the same for the bank engine: a ``Scheduler``
maps ``(cts, n_ops)`` to a static ``(assignment, makespan)`` pair, where

  * ``cts[i]`` is instance i's cycle time (issue interval, = 1/TP_i),
  * ``assignment[i]`` is the tuple of op indices instance i executes,
  * ``makespan`` is the cycle on which the last result retires.

Because the contract is *static* for a given batch size, every policy
keeps ``Bank.execute`` jit-compatible: the schedule lowers to constant
gather/scatter indices, never to data-dependent control flow.

Policies
--------
``round_robin``   Cycle-accurate polling in instance order: each cycle,
                  every free instance accepts the next pending op.  This
                  is the paper's Sec. V-E silicon bank behaviour and the
                  PR-2 default.
``greedy``        Earliest-completion-time list scheduling.  Ops are
                  placed on the instance that would *finish* them first.
                  For identical ops on instances of speeds 1/ct this is
                  provably makespan-optimal (the k-th op on instance i
                  can finish no earlier than k*ct_i; greedy picks the
                  n smallest such slots), so its makespan is always
                  <= round_robin's -- strictly better on heterogeneous
                  CT banks whose slow units would otherwise catch the
                  tail of the queue.
``streaming``     Ops are *not* all available at cycle 0: an arrival
                  trace assigns each op an arrival cycle, and free
                  instances poll the queue of arrived ops each cycle
                  (async dispatch, the serving use case).  With an
                  all-zero trace it reduces exactly to round_robin.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Protocol, runtime_checkable


@runtime_checkable
class Scheduler(Protocol):
    """Dispatch policy: (cts, n_ops) -> static (assignment, makespan)."""

    name: str

    def schedule(self, cts: tuple, n_ops: int) -> tuple:
        """Return ``(assignment, makespan)``.

        ``assignment`` is a tuple (one entry per instance) of tuples of
        op indices; every op index in ``range(n_ops)`` appears exactly
        once.  ``makespan`` is the retire cycle of the last op.
        """
        ...


# ---------------------------------------------------------------- policies

@functools.lru_cache(maxsize=1024)
def round_robin_schedule(cts: tuple, n_ops: int) -> tuple:
    """Cycle-accurate round-robin issue of ``n_ops`` over instances.

    Each cycle, instances are polled in order; a free instance accepts
    the next pending op and stays busy for its CT.
    """
    n_inst = len(cts)
    free_at = [0] * n_inst
    assign = [[] for _ in range(n_inst)]
    issued = 0
    cycle = 0
    while issued < n_ops:
        for i in range(n_inst):
            if issued >= n_ops:
                break
            if free_at[i] <= cycle:
                assign[i].append(issued)
                free_at[i] = cycle + cts[i]
                issued += 1
        cycle += 1
    makespan = max((free_at[i] for i in range(n_inst) if assign[i]),
                   default=0)
    return tuple(tuple(ops) for ops in assign), makespan


@functools.lru_cache(maxsize=1024)
def greedy_schedule(cts: tuple, n_ops: int) -> tuple:
    """Earliest-completion-time list scheduling (optimal for equal ops).

    Op k goes to the instance minimising ``free_at[i] + cts[i]`` (ties
    broken by instance order, so Stars placed first by the planner win
    them).  Completion slots on instance i form the chain ct_i, 2*ct_i,
    ...; greedy consumes the globally smallest n slots, hence the
    makespan is the n-th smallest slot value -- a lower bound for *any*
    schedule -- so ``greedy <= round_robin`` always holds.
    """
    import heapq
    n_inst = len(cts)
    assign = [[] for _ in range(n_inst)]
    heap = [(cts[i], i) for i in range(n_inst)]
    heapq.heapify(heap)
    makespan = 0
    for op in range(n_ops):
        done, i = heapq.heappop(heap)
        assign[i].append(op)
        makespan = max(makespan, done)
        heapq.heappush(heap, (done + cts[i], i))
    return tuple(tuple(ops) for ops in assign), makespan


@functools.lru_cache(maxsize=1024)
def streaming_schedule(cts: tuple, n_ops: int, arrivals: tuple) -> tuple:
    """Async dispatch against a per-op arrival trace.

    ``arrivals[k]`` is the cycle op k becomes available (nondecreasing).
    Each cycle, free instances poll the queue of *arrived* ops in
    round-robin order; an instance never idles while an arrived op is
    pending (work-conserving), but an op can never issue before it
    arrives.  An all-zero trace therefore reproduces round_robin
    exactly.
    """
    if len(arrivals) != n_ops:
        raise ValueError(
            f"arrival trace has {len(arrivals)} entries for {n_ops} ops")
    if any(b < a for a, b in zip(arrivals, arrivals[1:])):
        raise ValueError("arrival trace must be nondecreasing")
    n_inst = len(cts)
    free_at = [0] * n_inst
    assign = [[] for _ in range(n_inst)]
    issued = 0
    cycle = 0
    while issued < n_ops:
        if arrivals[issued] > cycle:
            cycle = arrivals[issued]        # fast-forward an idle bank
        for i in range(n_inst):
            if issued >= n_ops or arrivals[issued] > cycle:
                break
            if free_at[i] <= cycle:
                assign[i].append(issued)
                free_at[i] = cycle + cts[i]
                issued += 1
        cycle += 1
    makespan = max((free_at[i] for i in range(n_inst) if assign[i]),
                   default=0)
    return tuple(tuple(ops) for ops in assign), makespan


def uniform_arrivals(n_ops: int, per_cycle: int) -> tuple:
    """Deterministic arrival trace: ``per_cycle`` ops arrive each cycle."""
    if per_cycle < 1:
        raise ValueError("per_cycle >= 1")
    return tuple(k // per_cycle for k in range(n_ops))


# ------------------------------------------------------------- registry

@dataclasses.dataclass(frozen=True)
class RoundRobinScheduler:
    name: str = "round_robin"

    def schedule(self, cts: tuple, n_ops: int) -> tuple:
        return round_robin_schedule(tuple(cts), n_ops)


@dataclasses.dataclass(frozen=True)
class GreedyScheduler:
    name: str = "greedy"

    def schedule(self, cts: tuple, n_ops: int) -> tuple:
        return greedy_schedule(tuple(cts), n_ops)


@dataclasses.dataclass(frozen=True)
class StreamingScheduler:
    """Arrival-driven dispatch.  ``arrivals`` fixes a trace for every
    batch; ``arrival_rate`` derives a uniform trace per batch size
    (``arrival_rate`` ops arrive per cycle).  With neither set, all ops
    arrive at cycle 0 (== round_robin)."""
    arrivals: tuple | None = None
    arrival_rate: int | None = None
    name: str = "streaming"

    def schedule(self, cts: tuple, n_ops: int) -> tuple:
        if self.arrivals is not None:
            trace = tuple(self.arrivals)[:n_ops]
            if len(trace) < n_ops:
                raise ValueError(
                    f"arrival trace has {len(trace)} entries, need {n_ops}")
        elif self.arrival_rate is not None:
            trace = uniform_arrivals(n_ops, self.arrival_rate)
        else:
            trace = (0,) * n_ops
        return streaming_schedule(tuple(cts), n_ops, trace)


SCHEDULERS = {
    "round_robin": RoundRobinScheduler(),
    "greedy": GreedyScheduler(),
    "streaming": StreamingScheduler(),
}


def register_scheduler(sched: Scheduler) -> Scheduler:
    """Add a policy to the registry (later scaling PRs plug in here)."""
    SCHEDULERS[sched.name] = sched
    return sched


def get_scheduler(which) -> Scheduler:
    """Resolve a scheduler by name or pass a Scheduler object through."""
    if isinstance(which, str):
        try:
            return SCHEDULERS[which]
        except KeyError:
            raise ValueError(
                f"unknown scheduler {which!r}; "
                f"registered: {tuple(SCHEDULERS)}") from None
    if isinstance(which, Scheduler):
        return which
    raise TypeError(f"scheduler must be a name or Scheduler, got {which!r}")
