"""Scheduler layer: dispatch policies for multiplier banks.

The folding literature (Möller et al., "Model-based Hardware Design for
FPGAs using Folding Transformations"; "Operand Folding Hardware
Multipliers") treats the *schedule* -- which operation runs on which
shared instance on which cycle -- as a first-class, swappable design
object.  This module does the same for the bank engine: a ``Scheduler``
maps ``(cts, n_ops)`` to a static ``(assignment, makespan)`` pair, where

  * ``cts[i]`` is instance i's cycle time (issue interval, = 1/TP_i),
  * ``assignment[i]`` is the tuple of op indices instance i executes,
  * ``makespan`` is the cycle on which the last result retires.

Because the contract is *static* for a given batch size, every policy
keeps ``Bank.execute`` jit-compatible: the schedule lowers to constant
gather/scatter indices, never to data-dependent control flow.

Policies
--------
``round_robin``   Cycle-accurate polling in instance order: each cycle,
                  every free instance accepts the next pending op.  This
                  is the paper's Sec. V-E silicon bank behaviour and the
                  PR-2 default.
``greedy``        Earliest-completion-time list scheduling.  Ops are
                  placed on the instance that would *finish* them first.
                  For identical ops on instances of speeds 1/ct this is
                  provably makespan-optimal (the k-th op on instance i
                  can finish no earlier than k*ct_i; greedy picks the
                  n smallest such slots), so its makespan is always
                  <= round_robin's -- strictly better on heterogeneous
                  CT banks whose slow units would otherwise catch the
                  tail of the queue.
``streaming``     Ops are *not* all available at cycle 0: an arrival
                  trace assigns each op an arrival cycle, and free
                  instances poll the queue of arrived ops each cycle
                  (async dispatch, the serving use case).  With an
                  all-zero trace it reduces exactly to round_robin.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Protocol, runtime_checkable


@runtime_checkable
class Scheduler(Protocol):
    """Dispatch policy: (cts, n_ops) -> static (assignment, makespan)."""

    name: str

    def schedule(self, cts: tuple, n_ops: int) -> tuple:
        """Return ``(assignment, makespan)``.

        ``assignment`` is a tuple (one entry per instance) of tuples of
        op indices; every op index in ``range(n_ops)`` appears exactly
        once.  ``makespan`` is the retire cycle of the last op.
        """
        ...


# ---------------------------------------------------------------- policies

@functools.lru_cache(maxsize=1024)
def round_robin_schedule(cts: tuple, n_ops: int) -> tuple:
    """Cycle-accurate round-robin issue of ``n_ops`` over instances.

    Each cycle, instances are polled in order; a free instance accepts
    the next pending op and stays busy for its CT.
    """
    n_inst = len(cts)
    free_at = [0] * n_inst
    assign = [[] for _ in range(n_inst)]
    issued = 0
    cycle = 0
    while issued < n_ops:
        for i in range(n_inst):
            if issued >= n_ops:
                break
            if free_at[i] <= cycle:
                assign[i].append(issued)
                free_at[i] = cycle + cts[i]
                issued += 1
        cycle += 1
    makespan = max((free_at[i] for i in range(n_inst) if assign[i]),
                   default=0)
    return tuple(tuple(ops) for ops in assign), makespan


@functools.lru_cache(maxsize=1024)
def greedy_schedule(cts: tuple, n_ops: int) -> tuple:
    """Earliest-completion-time list scheduling (optimal for equal ops).

    Op k goes to the instance minimising ``free_at[i] + cts[i]`` (ties
    broken by instance order, so Stars placed first by the planner win
    them).  Completion slots on instance i form the chain ct_i, 2*ct_i,
    ...; greedy consumes the globally smallest n slots, hence the
    makespan is the n-th smallest slot value -- a lower bound for *any*
    schedule -- so ``greedy <= round_robin`` always holds.
    """
    import heapq
    n_inst = len(cts)
    assign = [[] for _ in range(n_inst)]
    heap = [(cts[i], i) for i in range(n_inst)]
    heapq.heapify(heap)
    makespan = 0
    for op in range(n_ops):
        done, i = heapq.heappop(heap)
        assign[i].append(op)
        makespan = max(makespan, done)
        heapq.heappush(heap, (done + cts[i], i))
    return tuple(tuple(ops) for ops in assign), makespan


@functools.lru_cache(maxsize=1024)
def streaming_schedule(cts: tuple, n_ops: int, arrivals: tuple) -> tuple:
    """Async dispatch against a per-op arrival trace.

    ``arrivals[k]`` is the cycle op k becomes available (nondecreasing).
    Each cycle, free instances poll the queue of *arrived* ops in
    round-robin order; an instance never idles while an arrived op is
    pending (work-conserving), but an op can never issue before it
    arrives.  An all-zero trace therefore reproduces round_robin
    exactly.
    """
    if len(arrivals) != n_ops:
        raise ValueError(
            f"arrival trace has {len(arrivals)} entries for {n_ops} ops")
    if any(b < a for a, b in zip(arrivals, arrivals[1:])):
        raise ValueError("arrival trace must be nondecreasing")
    n_inst = len(cts)
    free_at = [0] * n_inst
    assign = [[] for _ in range(n_inst)]
    issued = 0
    cycle = 0
    while issued < n_ops:
        if arrivals[issued] > cycle:
            cycle = arrivals[issued]        # fast-forward an idle bank
        for i in range(n_inst):
            if issued >= n_ops or arrivals[issued] > cycle:
                break
            if free_at[i] <= cycle:
                assign[i].append(issued)
                free_at[i] = cycle + cts[i]
                issued += 1
        cycle += 1
    makespan = max((free_at[i] for i in range(n_inst) if assign[i]),
                   default=0)
    return tuple(tuple(ops) for ops in assign), makespan


def uniform_arrivals(n_ops: int, per_cycle: int) -> tuple:
    """Deterministic arrival trace: ``per_cycle`` ops arrive each cycle."""
    if per_cycle < 1:
        raise ValueError("per_cycle >= 1")
    return tuple(k // per_cycle for k in range(n_ops))


# --------------------------------------------------- completion accounting

def completion_cycles(cts: tuple, assignment: tuple,
                      arrivals: tuple | None = None) -> tuple:
    """Per-op completion cycle reconstructed from an assignment.

    Every registered policy is work-conserving and issues each
    instance's ops in the order its assignment tuple lists them, so the
    per-instance chain ``issue_k = max(prev_finish, arrival_k)``,
    ``finish_k = issue_k + ct`` reproduces the simulation exactly: an
    instance whose next assigned op has arrived never idles (if it
    could idle, the polling loop would have handed the op to it -- or
    to an earlier-polled free instance, contradicting the assignment).
    This is the single accounting path both ``Bank.report``'s latency
    histogram and the serving layer's online metrics derive from.
    """
    n_ops = sum(len(ops) for ops in assignment)
    arr = (0,) * n_ops if arrivals is None else tuple(arrivals)
    if len(arr) != n_ops:
        raise ValueError(
            f"arrival trace has {len(arr)} entries for {n_ops} ops")
    finish = [0] * n_ops
    for ops, ct in zip(assignment, cts):
        free = 0
        for k in ops:
            free = max(free, arr[k]) + ct
            finish[k] = free
    return tuple(finish)


def latency_histogram(latencies) -> tuple:
    """Collapse per-request latencies into sorted ((latency, count), ...).

    The compact exchange format between the bank's offline reports and
    the serving layer's online metrics (identical bucketing: exact
    integer cycles, no binning)."""
    counts = {}
    for lat in latencies:
        counts[lat] = counts.get(lat, 0) + 1
    return tuple(sorted(counts.items()))


def histogram_percentile(hist: tuple, q: float):
    """Smallest latency whose cumulative count covers quantile ``q``.

    ``hist`` is ``latency_histogram`` output; returns None when empty.
    """
    if not 0.0 <= q <= 1.0:
        raise ValueError(f"quantile must be in [0, 1], got {q}")
    total = sum(c for _, c in hist)
    if not total:
        return None
    need = max(1, math.ceil(q * total))
    seen = 0
    for lat, c in hist:
        seen += c
        if seen >= need:
            return lat
    return hist[-1][0]


# ------------------------------------------------------------- registry

@dataclasses.dataclass(frozen=True)
class RoundRobinScheduler:
    name: str = "round_robin"

    def schedule(self, cts: tuple, n_ops: int) -> tuple:
        return round_robin_schedule(tuple(cts), n_ops)


@dataclasses.dataclass(frozen=True)
class GreedyScheduler:
    name: str = "greedy"

    def schedule(self, cts: tuple, n_ops: int) -> tuple:
        return greedy_schedule(tuple(cts), n_ops)


@dataclasses.dataclass(frozen=True)
class StreamingScheduler:
    """Arrival-driven dispatch.  ``arrivals`` fixes a trace for every
    batch; ``arrival_rate`` derives a uniform trace per batch size
    (``arrival_rate`` ops arrive per cycle).  With neither set, all ops
    arrive at cycle 0 (== round_robin)."""
    arrivals: tuple | None = None
    arrival_rate: int | None = None
    name: str = "streaming"

    def arrivals_for(self, n_ops: int) -> tuple:
        """The arrival trace this policy dispatches ``n_ops`` against
        (``Bank.report`` asks for it to turn completions into
        admission-to-completion latencies)."""
        if self.arrivals is not None:
            trace = tuple(self.arrivals)[:n_ops]
            if len(trace) < n_ops:
                raise ValueError(
                    f"arrival trace has {len(trace)} entries, need {n_ops}")
            return trace
        if self.arrival_rate is not None:
            return uniform_arrivals(n_ops, self.arrival_rate)
        return (0,) * n_ops

    def schedule(self, cts: tuple, n_ops: int) -> tuple:
        return streaming_schedule(tuple(cts), n_ops,
                                  self.arrivals_for(n_ops))


SCHEDULERS = {
    "round_robin": RoundRobinScheduler(),
    "greedy": GreedyScheduler(),
    "streaming": StreamingScheduler(),
}


def register_scheduler(sched: Scheduler) -> Scheduler:
    """Add a policy to the registry (later scaling PRs plug in here)."""
    SCHEDULERS[sched.name] = sched
    return sched


def get_scheduler(which) -> Scheduler:
    """Resolve a scheduler by name or pass a Scheduler object through."""
    if isinstance(which, str):
        try:
            return SCHEDULERS[which]
        except KeyError:
            raise ValueError(
                f"unknown scheduler {which!r}; "
                f"registered: {tuple(SCHEDULERS)}") from None
    if isinstance(which, Scheduler):
        return which
    raise TypeError(f"scheduler must be a name or Scheduler, got {which!r}")
