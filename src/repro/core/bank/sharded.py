"""Sharded multi-bank execution: N replicated banks over a mesh axis.

The paper's Sec. V-E bank sustains a fractional throughput on one chip;
production serving replicates that bank across devices.  This module
runs one bank *per device slice* along a named mesh axis via the
``repro.compat`` shard_map shim: the global batch is split evenly, every
device executes its shard through the same static dispatch (scheduler +
backend resolved exactly as in :mod:`.engine`), and the results
concatenate back bit-exactly -- each multiplication is computed by
exactly one instance of one bank replica, so ``sharded_execute`` equals
the single-bank oracle product-for-product.

Partition specs come from :func:`repro.launch.sharding.bank_batch_spec`
(the same divisibility-checked spec machinery the model runtime uses),
so the bank composes with the launch layer's meshes instead of invented
ad-hoc shardings.
"""
from __future__ import annotations

import functools

import jax

from .. import limbs as L
from ..planner import Plan
from .engine import Bank, BankReport


def _local_batch(batch: int, mesh, axis: str) -> int:
    # bank_batch_spec is the single owner of the axis-membership and
    # divisibility validation; this just derives the shard size from it
    from repro.launch.sharding import bank_batch_spec
    bank_batch_spec(mesh, axis, 2, batch)
    return batch // mesh.shape[axis]


@functools.lru_cache(maxsize=64)
def _sharded_fn(plan: Plan, bits_a: int, bits_b: int, backend: str,
                scheduler: str, mesh, axis: str, local: int):
    # Lazy imports: core must stay importable without touching the
    # launch layer (and jax device state) at module-import time.
    from repro.compat import shard_map
    from repro.launch.sharding import bank_batch_spec

    bank = Bank(plan, bits_a, bits_b, backend=backend, scheduler=scheduler)
    run = bank.dispatch_fn(local)
    spec = bank_batch_spec(mesh, axis, 2, local * mesh.shape[axis])
    fn = shard_map(run, mesh=mesh, in_specs=(spec, spec), out_specs=spec,
                   check_vma=False)
    return jax.jit(fn)


def sharded_execute(plan: Plan, a: jax.Array, b: jax.Array, mesh,
                    axis: str, *, backend: str = "core",
                    scheduler: str = "round_robin") -> jax.Array:
    """Replicated-bank execution of (B, LA) x (B, LB) over ``mesh[axis]``.

    Each of the ``mesh.shape[axis]`` device slices runs one full bank
    replica on its B/N shard; the returned (B, LA+LB) limb products are
    bit-exact vs the single-bank (and Python-bigint) oracle.  The global
    batch must divide evenly; compiled sharded dispatches are cached per
    (plan, widths, backend, scheduler, mesh, axis, shard size).
    """
    if a.ndim != 2 or b.ndim != 2:
        raise ValueError("sharded_execute expects batched (B, L) operands")
    if a.shape[0] != b.shape[0]:
        raise ValueError(
            f"batch mismatch: a has {a.shape[0]} ops, b has {b.shape[0]}")
    local = _local_batch(a.shape[0], mesh, axis)
    fn = _sharded_fn(plan, a.shape[-1] * L.RADIX_BITS,
                     b.shape[-1] * L.RADIX_BITS, backend,
                     scheduler, mesh, axis, local)
    return fn(a, b)


def sharded_report(plan: Plan, batch: int, bits_a: int, bits_b: int,
                   mesh, axis: str, *, backend: str = "core",
                   scheduler: str = "round_robin") -> BankReport:
    """Per-replica cycle accounting: the report of one bank running its
    B/N shard (all replicas are identical, so one report describes the
    whole sharded execution; aggregate throughput is N x measured)."""
    local = _local_batch(batch, mesh, axis)
    bank = Bank(plan, bits_a, bits_b, backend=backend, scheduler=scheduler)
    return bank.report(local)
