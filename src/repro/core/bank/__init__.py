"""Bank subsystem: executable multiplier banks for ``planner.Plan``s.

The PR-2 ``core/bank.py`` monolith is now three decoupled layers:

  :mod:`.schedule`  -- pluggable dispatch policies (``Scheduler``
                       protocol; round_robin / greedy / streaming), all
                       returning the same static (assignment, makespan)
                       contract so execution stays jit-compatible.
  :mod:`.backends`  -- ``InstanceBackend`` registry keyed by
                       (arch, capability): how one instance multiplies
                       (pure-jnp core or Pallas kernels, incl. the
                       folded Karatsuba CT=3 kernel schedule).
  :mod:`.engine`    -- the ``Bank`` class wiring a Plan, a scheduler and
                       backends into bit-exact, cycle-accounted
                       execution.
  :mod:`.sharded`   -- N replicated banks over a mesh axis
                       (``sharded_execute``) via the compat shard_map
                       shim + launch-layer partition specs.

This package is a drop-in replacement for the old module:
``from repro.core import bank`` and every public PR-2 name
(``Bank``, ``BankReport``, ``execute``, ``last_report``,
``round_robin_schedule``, ``BACKENDS``) keep working.  New code should
usually not construct ``Bank`` objects directly: :mod:`repro.designs`
compiles a declarative ``DesignSpec`` into a ``CompiledDesign`` that
owns the bank plus timing/area/provenance.
"""
from .schedule import (Scheduler, RoundRobinScheduler, GreedyScheduler,
                       StreamingScheduler, SCHEDULERS, register_scheduler,
                       get_scheduler, round_robin_schedule, greedy_schedule,
                       streaming_schedule, uniform_arrivals,
                       completion_cycles, latency_histogram,
                       histogram_percentile)
from .backends import (InstanceBackend, BACKENDS, CAPABILITIES,
                       register_backend, get_backend, registered_backends)
from .engine import (Bank, BankReport, InstanceReport, execute, last_report)
from .sharded import sharded_execute, sharded_report

__all__ = [
    # schedule layer
    "Scheduler", "RoundRobinScheduler", "GreedyScheduler",
    "StreamingScheduler", "SCHEDULERS", "register_scheduler",
    "get_scheduler", "round_robin_schedule", "greedy_schedule",
    "streaming_schedule", "uniform_arrivals",
    "completion_cycles", "latency_histogram", "histogram_percentile",
    # backend layer
    "InstanceBackend", "BACKENDS", "CAPABILITIES", "register_backend",
    "get_backend", "registered_backends",
    # engine
    "Bank", "BankReport", "InstanceReport", "execute", "last_report",
    # distribution layer
    "sharded_execute", "sharded_report",
]
