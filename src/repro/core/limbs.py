"""Limb representation of wide unsigned integers for TPU-native arithmetic.

The paper (Houraniah et al., "Efficient Multi-Cycle Folded Integer
Multipliers") builds multipliers out of three hardware stages:

    PPM (partial-product multiplier, no final addition)
      -> compressor (carry-save tree, no carry propagation)
        -> final adder (single carry-propagating addition)

The TPU-native analogue implemented here represents an N-bit unsigned
integer as a little-endian vector of 16-bit *limbs* stored in uint32
lanes.  A "carry-save" value is a vector of uint32 *column sums* in
radix 2**16: the represented value is sum(cols[k] * 2**(16*k)) where the
individual columns may exceed 16 bits.  This redundant form is the
direct analogue of the paper's carry-save rows:

  * PPM        == limb-wise 16x16->32 products split into lo/hi halves,
                  scattered into columns *without* carry propagation.
  * compressor == integer addition of column-sum vectors (deferred
                  carries; exact because columns stay below 2**32).
  * final adder== one carry-propagation pass turning column sums back
                  into canonical 16-bit limbs.

All ops are batched over arbitrary leading axes; the limb axis is the
last axis, index 0 = least significant limb.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

RADIX_BITS = 16
RADIX = 1 << RADIX_BITS
MASK = RADIX - 1
LIMB_DTYPE = jnp.uint32

#: Largest value a carry-save column may reach: columns live in uint32.
U32_MAX = (1 << 32) - 1


def n_limbs_for_bits(bits: int) -> int:
    """Number of 16-bit limbs needed to hold ``bits`` bits."""
    return -(-bits // RADIX_BITS)


def max_limb_value(bits: int) -> int:
    """Worst-case value of any single limb of a ``bits``-bit operand.

    Full limbs reach MASK; a lone partial top limb reaches 2**rem - 1.
    """
    if bits >= RADIX_BITS:
        return MASK
    return (1 << bits) - 1


def MAX_SAFE_COLUMN_TERMS(bits_a: int, bits_b: int) -> int:
    """Carry-save terms one uint32 column can absorb for a bits_a x bits_b
    design before overflow becomes possible.

    Every term the limb pipeline scatters into a column is the lo or hi
    half of one limb product (or a complement limb / +1 correction), so
    it is bounded by ``min(amax * bmax, MASK)`` where amax/bmax are the
    widest limb values the operands can hold.  The budget is the largest
    term count whose sum still fits in uint32.

    This is the coarse always-true bound asserted at the carry-save
    construction sites below; :mod:`repro.verify.intervals` proves the
    sharp per-column magnitude bound for every design the repo can
    generate (and ``python -m repro.verify`` sweeps them all).
    """
    prod = max_limb_value(bits_a) * max_limb_value(bits_b)
    term_max = max(min(prod, MASK), prod >> RADIX_BITS, 1)
    return U32_MAX // term_max


def to_limbs(value: int, n_limbs: int) -> np.ndarray:
    """Convert a Python int to a little-endian uint32 limb vector."""
    if value < 0:
        raise ValueError("unsigned only")
    if value >> (RADIX_BITS * n_limbs):
        raise ValueError(f"{value} does not fit in {n_limbs} limbs")
    out = np.zeros((n_limbs,), dtype=np.uint32)
    for k in range(n_limbs):
        out[k] = (value >> (RADIX_BITS * k)) & MASK
    return out


def from_limbs(limbs) -> int:
    """Convert a 1-D limb vector (canonical or carry-save) to a Python int."""
    limbs = np.asarray(limbs)
    total = 0
    for k in range(limbs.shape[-1]):
        total += int(limbs[k]) << (RADIX_BITS * k)
    return total


def batch_to_limbs(values, n_limbs: int) -> np.ndarray:
    """Convert an iterable of Python ints to a (B, n_limbs) uint32 array."""
    return np.stack([to_limbs(int(v), n_limbs) for v in values])


def batch_from_limbs(limbs) -> list:
    limbs = np.asarray(limbs)
    flat = limbs.reshape(-1, limbs.shape[-1])
    return [from_limbs(row) for row in flat]


def random_limbs(rng: np.random.Generator, shape, bits: int) -> np.ndarray:
    """Uniform random ``bits``-bit integers as limb arrays of matching width."""
    n = n_limbs_for_bits(bits)
    out = rng.integers(0, RADIX, size=tuple(shape) + (n,), dtype=np.uint32)
    rem = bits - (n - 1) * RADIX_BITS
    out[..., -1] &= (1 << rem) - 1
    return out


# ---------------------------------------------------------------------------
# PPM: partial-product "multiplier" producing carry-save column sums.
# ---------------------------------------------------------------------------

def _ppm_scatter_indices(la: int, lb: int):
    """Column indices for lo/hi halves of every limb product (static)."""
    i = np.arange(la)[:, None]
    j = np.arange(lb)[None, :]
    lo_idx = (i + j).reshape(-1)          # lo half of a[i]*b[j] lands in col i+j
    hi_idx = lo_idx + 1                   # hi half lands in col i+j+1
    return jnp.asarray(lo_idx), jnp.asarray(hi_idx)


def ppm(a: jax.Array, b: jax.Array) -> jax.Array:
    """Partial-product multiplier: carry-save column sums of a*b.

    a: (..., LA) uint32 canonical 16-bit limbs
    b: (..., LB) uint32 canonical 16-bit limbs
    returns (..., LA+LB) uint32 column sums (redundant / carry-save form).

    This is the analogue of DW02_multp / the RoCoCo PPM: it produces the
    product *without* the final carry-propagating addition.
    """
    la, lb = a.shape[-1], b.shape[-1]
    # every output column receives at most min(la, lb) lo halves plus
    # min(la, lb) hi halves; the budget is checked at trace time (static)
    assert 2 * min(la, lb) <= MAX_SAFE_COLUMN_TERMS(la * RADIX_BITS,
                                                    lb * RADIX_BITS), \
        f"{la}x{lb}-limb PPM exceeds the uint32 carry-save term budget"
    prod = a[..., :, None] * b[..., None, :]        # exact: <2**32
    lo = (prod & MASK).reshape(*prod.shape[:-2], la * lb)
    hi = (prod >> RADIX_BITS).reshape(*prod.shape[:-2], la * lb)
    lo_idx, hi_idx = _ppm_scatter_indices(la, lb)
    cols = jnp.zeros(prod.shape[:-2] + (la + lb,), dtype=LIMB_DTYPE)
    cols = cols.at[..., lo_idx].add(lo)
    cols = cols.at[..., hi_idx].add(hi)
    return cols


def ppm_op_count(la: int, lb: int) -> int:
    """Number of 16x16 limb products a PPM of this size instantiates.

    This is the area proxy for the PPM stage (see core.area_model)."""
    return la * lb


# ---------------------------------------------------------------------------
# Compressor: carry-save addition of column-sum vectors.
# ---------------------------------------------------------------------------

def compress(terms, width: int) -> jax.Array:
    """Sum carry-save vectors (optionally shifted) into ``width`` columns.

    ``terms`` is a list of (cols, shift_limbs) pairs.  This is the 3:2 /
    4:2 / 5:2 compressor analogue: pure column addition, no carry
    propagation.  Shifts are static.
    """
    # each summand vector may itself hold column sums, so the coarse
    # budget only bounds the vector count here; repro.verify.intervals
    # proves the sharp per-column magnitude bound per design
    assert len(terms) <= MAX_SAFE_COLUMN_TERMS(RADIX_BITS, RADIX_BITS), \
        f"compress of {len(terms)} terms exceeds the uint32 term budget"
    batch = jnp.broadcast_shapes(*[t[0].shape[:-1] for t in terms])
    acc = jnp.zeros(batch + (width,), dtype=LIMB_DTYPE)
    for cols, shift in terms:
        n = cols.shape[-1]
        take = min(n, width - shift)
        if take <= 0:
            continue
        acc = acc.at[..., shift:shift + take].add(cols[..., :take])
    return acc


def shift_cols(cols: jax.Array, shift: int, width: int) -> jax.Array:
    """Place ``cols`` at limb offset ``shift`` inside a ``width``-wide vector."""
    return compress([(cols, shift)], width)


def negate_cols(limbs: jax.Array, shift: int, width: int):
    """Two's-complement encoding of -(limbs << 16*shift) mod 2**(16*width).

    ``limbs`` must be canonical (16-bit) limbs.  Mirrors the paper's
    handling of Karatsuba subtraction: NOT every bit, then add 1 -- both
    folded into the compressor.  Returns (cols, +1 correction column sum)
    to be added into an accumulator; the wrap-around 2**(16*width) term
    vanishes in the final adder's modular truncation.
    """
    n = limbs.shape[-1]
    full = jnp.full(limbs.shape[:-1] + (width,), MASK, dtype=LIMB_DTYPE)
    placed = shift_cols(limbs, shift, width)
    inverted = full - placed            # NOT of the shifted value, columnwise
    one = jnp.zeros(limbs.shape[:-1] + (width,), dtype=LIMB_DTYPE).at[..., 0].add(1)
    return inverted, one


# ---------------------------------------------------------------------------
# Final adders.
# ---------------------------------------------------------------------------

def final_adder_1ca(cols: jax.Array, out_limbs: int | None = None) -> jax.Array:
    """Single-pass carry-propagating final adder ("1CA" in the paper).

    Sequential carry propagation over the limb axis via lax.scan; result
    is truncated (mod 2**(16*out_limbs)) like fixed-width hardware.
    """
    width = cols.shape[-1]
    out_limbs = width if out_limbs is None else out_limbs
    cols_t = jnp.moveaxis(cols, -1, 0)               # (width, ...)
    carry0 = jnp.zeros(cols.shape[:-1], dtype=LIMB_DTYPE)

    def step(carry, col):
        tot = col + carry
        return tot >> RADIX_BITS, tot & MASK

    _, limbs_t = jax.lax.scan(step, carry0, cols_t)
    limbs = jnp.moveaxis(limbs_t, 0, -1)
    if out_limbs <= width:
        return limbs[..., :out_limbs]
    pad = jnp.zeros(limbs.shape[:-1] + (out_limbs - width,), dtype=LIMB_DTYPE)
    return jnp.concatenate([limbs, pad], axis=-1)


def final_adder_3ca(cols: jax.Array, out_limbs: int | None = None) -> jax.Array:
    """3-cycle resource-shared final adder ("3CA").

    The paper folds the final adder over 3 cycles using a feedback loop
    around 1/3rd of the full-adder cells.  Analogue: propagate carries
    over one third of the limb axis per cycle, carrying the running
    carry across cycles.  Functionally identical to 1CA; it exists so
    the area model and the folded kernels can represent the 1/3-width
    adder design point.
    """
    width = cols.shape[-1]
    out_limbs = width if out_limbs is None else out_limbs
    third = -(-width // 3)
    padded = width if width % third == 0 else (width // third + 1) * third
    if padded != width:
        cols = jnp.concatenate(
            [cols, jnp.zeros(cols.shape[:-1] + (padded - width,), LIMB_DTYPE)],
            axis=-1)
    carry = jnp.zeros(cols.shape[:-1], dtype=LIMB_DTYPE)
    pieces = []
    for c in range(padded // third):                # the multi-cycle feedback loop
        seg = cols[..., c * third:(c + 1) * third]
        seg_t = jnp.moveaxis(seg, -1, 0)

        def step(cin, col):
            tot = col + cin
            return tot >> RADIX_BITS, tot & MASK

        carry, seg_out_t = jax.lax.scan(step, carry, seg_t)
        pieces.append(jnp.moveaxis(seg_out_t, 0, -1))
    limbs = jnp.concatenate(pieces, axis=-1)[..., :width]
    if out_limbs <= width:
        return limbs[..., :out_limbs]
    pad = jnp.zeros(limbs.shape[:-1] + (out_limbs - width,), dtype=LIMB_DTYPE)
    return jnp.concatenate([limbs, pad], axis=-1)


FINAL_ADDERS = {"1ca": final_adder_1ca, "3ca": final_adder_3ca}


# ---------------------------------------------------------------------------
# Canonical-form helpers.
# ---------------------------------------------------------------------------

def add_canonical(a: jax.Array, b: jax.Array, out_limbs: int) -> jax.Array:
    """Exact addition of canonical limb vectors (via compressor + 1CA)."""
    width = max(a.shape[-1], b.shape[-1]) + 1
    acc = compress([(a, 0), (b, 0)], width)
    return final_adder_1ca(acc, out_limbs)


def pad_limbs(a: jax.Array, n: int) -> jax.Array:
    """Zero-pad the limb axis up to n limbs."""
    cur = a.shape[-1]
    if cur == n:
        return a
    if cur > n:
        raise ValueError(f"cannot shrink {cur} -> {n}")
    pad = jnp.zeros(a.shape[:-1] + (n - cur,), dtype=LIMB_DTYPE)
    return jnp.concatenate([a, pad], axis=-1)
