"""Core MCIM library: multi-cycle folded integer multipliers in JAX.

Most callers should start one level up, at :mod:`repro.designs`: a
declarative ``DesignSpec`` compiled by ``generate()`` wires planner,
timing model, bank and sharding together.  The layers below stay public
for direct use:

  limbs            -- limb representation + PPM / compressor / final adders
  mcim_mul         -- configurable folded multiply (fb/ff/karatsuba/star)
  MCIMConfig       -- generator parameters (arch, ct, levels, adder, signed)
  make_multiplier  -- jitted fixed-width multiplier factory
  mul32x32_64      -- 32x32->64 multiply on uint32 lanes (for RNG / exact)
  planner          -- design-point selection (paper Table VIII policy)
  timing_model     -- clock/latency model filtering that selection
  bank             -- executable multiplier banks for planner Plans
                      (pluggable schedulers/backends + sharded execution)
  area_model       -- ASIC-area cost model used by benchmarks/
  power_model      -- switching-energy / peak-power cost model
                      (the paper's 33%-energy / 65%-peak-power claims)
"""
from . import limbs
from . import area_model
from . import power_model
from . import planner
from . import bank
from .bank import Bank, BankReport, sharded_execute
from .mcim import MCIMConfig, mcim_mul, make_multiplier, mul32x32_64
from .schoolbook import star_mul, feedback_mul, feedforward_mul
from .karatsuba import karatsuba_mul, karatsuba_ppm

__all__ = [
    "limbs", "area_model", "power_model", "planner", "bank",
    "Bank", "BankReport", "sharded_execute",
    "MCIMConfig", "mcim_mul", "make_multiplier", "mul32x32_64",
    "star_mul", "feedback_mul", "feedforward_mul",
    "karatsuba_mul", "karatsuba_ppm",
]
