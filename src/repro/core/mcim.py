"""Top-level MCIM API: configurable multi-cycle folded integer multiply.

``mcim_mul`` is the user-facing entry point mirroring the paper's
generator parameters: architecture (fb / ff / karatsuba), CT (cycle
time, = 1/throughput), Karatsuba recursion levels, and final adder.

All functions operate on batched little-endian 16-bit-limb uint32
arrays (see core.limbs) and are jit/vmap/pjit-compatible.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from . import limbs as L
from .schoolbook import star_mul, feedback_mul, feedforward_mul
from .karatsuba import karatsuba_mul

ARCHS = ("star", "fb", "ff", "karatsuba")


@dataclasses.dataclass(frozen=True)
class MCIMConfig:
    """Generator parameters (paper Sec. IV)."""
    arch: str = "fb"          # star | fb | ff | karatsuba
    ct: int = 2               # cycle time == 1/throughput
    levels: int = 1           # Karatsuba recursion levels (Karat-K)
    adder: str = "1ca"        # 1ca | 3ca
    signed: bool = False      # two's-complement operands

    def __post_init__(self):
        if self.arch not in ARCHS:
            raise ValueError(f"arch must be one of {ARCHS}")
        if self.arch == "star" and self.ct != 1:
            raise ValueError("star is single-cycle")
        if self.arch == "karatsuba" and self.ct != 3:
            raise ValueError("Karatsuba MCIM uses CT=3")
        if self.adder not in L.FINAL_ADDERS:
            raise ValueError(f"adder must be one of {tuple(L.FINAL_ADDERS)}")
        if self.adder == "3ca" and self.ct < 3:
            raise ValueError("3CA usable only by designs with TP <= 1/3")


def mcim_mul(a: jax.Array, b: jax.Array,
             config: MCIMConfig | None = None, **kw) -> jax.Array:
    """Multiply limb vectors a (..., LA) x b (..., LB) -> (..., LA+LB).

    Unsigned by default; ``config.signed`` interprets operands as
    two's-complement of their limb width and returns the low LA+LB limbs
    of the signed product (standard wrapping semantics).
    """
    cfg = config or MCIMConfig(**kw)
    if cfg.signed:
        return _signed_mul(a, b, dataclasses.replace(cfg, signed=False))
    if cfg.arch == "star":
        return star_mul(a, b, adder=cfg.adder)
    if cfg.arch == "fb":
        return feedback_mul(a, b, ct=cfg.ct, adder=cfg.adder)
    if cfg.arch == "ff":
        return feedforward_mul(a, b, ct=cfg.ct, adder=cfg.adder)
    return karatsuba_mul(a, b, levels=cfg.levels, ct=cfg.ct, adder=cfg.adder)


def signed_correction(a: jax.Array, b: jax.Array,
                      prod: jax.Array) -> jax.Array:
    """Turn an *unsigned* product into the two's-complement one.

    For P-limb operands interpreted mod 2**(16P):
      signed(a)*signed(b) == a*b - (a<0)*b*2**(16LA) - (b<0)*a*2**(16LB)
    (mod 2**(16(LA+LB))), i.e. subtract the sign corrections from the
    unsigned product -- implemented with the same compressor/complement
    machinery as Karatsuba's subtractions.  Exposed separately from
    :func:`_signed_mul` so substrates that produce the unsigned product
    elsewhere (the fused bank megakernel) can retire signed designs with
    the identical correction pass.
    """
    la, lb = a.shape[-1], b.shape[-1]
    width = la + lb
    a_neg = (a[..., -1] >> (L.RADIX_BITS - 1)) & 1       # sign bits
    b_neg = (b[..., -1] >> (L.RADIX_BITS - 1)) & 1
    corr_b = jnp.where(a_neg[..., None].astype(jnp.bool_), b, 0)
    corr_a = jnp.where(b_neg[..., None].astype(jnp.bool_), a, 0)
    nb, ob = L.negate_cols(corr_b, la, width)
    na, oa = L.negate_cols(corr_a, lb, width)
    acc = L.compress([(prod, 0), (nb, 0), (ob, 0), (na, 0), (oa, 0)], width)
    return L.final_adder_1ca(acc, width)


def _signed_mul(a: jax.Array, b: jax.Array, cfg: MCIMConfig) -> jax.Array:
    """Signed (two's-complement) extension, paper Sec. I."""
    return signed_correction(a, b, mcim_mul(a, b, cfg))


# Convenience fixed-width wrappers -------------------------------------------

def make_multiplier(bits_a: int, bits_b: int,
                    config: MCIMConfig | None = None, **kw):
    """Return a jitted multiplier for fixed operand widths (bits)."""
    cfg = config or MCIMConfig(**kw)
    la, lb = L.n_limbs_for_bits(bits_a), L.n_limbs_for_bits(bits_b)

    @jax.jit
    def mul(a, b):
        assert a.shape[-1] == la and b.shape[-1] == lb
        return mcim_mul(a, b, cfg)

    return mul


@functools.partial(jax.jit, static_argnames=("arch", "ct"))
def mul32x32_64(a32: jax.Array, b32: jax.Array, arch: str = "ff",
                ct: int = 2) -> tuple[jax.Array, jax.Array]:
    """32x32 -> 64-bit multiply on uint32 lanes (lo, hi) via 16-bit limbs.

    TPUs have no native 64-bit integer multiply; this builds one from the
    MCIM machinery.  Used by repro.rng (Philox) and repro.exact.
    """
    a = jnp.stack([a32 & L.MASK, a32 >> 16], axis=-1).astype(jnp.uint32)
    b = jnp.stack([b32 & L.MASK, b32 >> 16], axis=-1).astype(jnp.uint32)
    p = mcim_mul(a, b, MCIMConfig(arch=arch, ct=ct) if arch != "star"
                 else MCIMConfig(arch="star", ct=1))
    lo = p[..., 0] | (p[..., 1] << 16)
    hi = p[..., 2] | (p[..., 3] << 16)
    return lo, hi
