"""Karatsuba folded multiplier (paper Sec. III-D, Figs. 3 and 4).

Structure mirrors the paper:

  * The *top* level is folded over CT=3 cycles: one shared PPM computes
    T0 = A0*B0, T1 = A1*B1, T2 = (A0+A1)*(B0+B1) on consecutive cycles
    (expressed as a ``lax.scan`` over the 3 stacked operand pairs, i.e.
    one PPM instance in the HLO re-used three times).
  * The shared PPM may itself be a *combinational* Karatsuba PPM
    (paper Fig. 4): 3 recursively smaller PPMs + a 10:2 compressor,
    fully unrolled inside the scan body.  ``levels`` counts total
    Karatsuba levels including the folded top level, matching the
    paper's Karat-K naming.
  * Subtractions are two's-complement: NOT the limbs and add 1 through
    the compressor; the 2**(16*W) wrap vanishes in the final adder's
    fixed-width truncation (paper Sec. III-D).

Deviation from the paper, recorded in DESIGN.md: the hardware keeps the
T_i in 2-row carry-save form through a 5:2 compressor; complementing a
redundant *column-sum* vector is not closed over uint32, so each T_i is
normalized (a final-adder pass) before entering the combiner.  The
function computed and the folding schedule are identical.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import limbs as L


def _split_pad(x: jax.Array, half: int, total: int):
    """Split (..., total) limbs into low/high halves of ``half`` limbs."""
    x = L.pad_limbs(x, total)
    return x[..., :half], x[..., half:]


def _half_sum(x0: jax.Array, x1: jax.Array, out: int) -> jax.Array:
    """(A0 + A1) normalized to ``out`` canonical limbs (out = half+1)."""
    return L.add_canonical(x0, x1, out)


def karatsuba_ppm(a: jax.Array, b: jax.Array, levels: int) -> jax.Array:
    """Combinational Karatsuba PPM (paper Fig. 4): carry-save columns of a*b.

    levels == 0 -> plain schoolbook PPM.
    levels >= 1 -> 3 sub-PPMs at levels-1 + compressor combine.
    """
    la, lb = a.shape[-1], b.shape[-1]
    if levels == 0 or la <= 1 or lb <= 1:
        return L.ppm(a, b)
    n = max(la, lb)
    n += n % 2
    half = n // 2
    a0, a1 = _split_pad(a, half, n)
    b0, b1 = _split_pad(b, half, n)
    sa = _half_sum(a0, a1, half + 1)
    sb = _half_sum(b0, b1, half + 1)

    width = la + lb
    t0 = L.final_adder_1ca(karatsuba_ppm(a0, b0, levels - 1), 2 * half)
    t1 = L.final_adder_1ca(karatsuba_ppm(a1, b1, levels - 1), 2 * half)
    t2 = L.final_adder_1ca(karatsuba_ppm(sa, sb, levels - 1), 2 * half + 2)

    neg_t0, one0 = L.negate_cols(t0, half, width)
    neg_t1, one1 = L.negate_cols(t1, half, width)
    return L.compress(
        [(t0, 0), (t1, 2 * half), (t2, half),
         (neg_t0, 0), (one0, 0), (neg_t1, 0), (one1, 0)],
        width)


def karatsuba_mul(a: jax.Array, b: jax.Array, levels: int = 1,
                  ct: int = 3, adder: str = "1ca") -> jax.Array:
    """CT=3 folded Karatsuba multiplier (paper Fig. 3), Karat-``levels``.

    The three half-size multiplications run on ONE shared PPM over three
    cycles (lax.scan); a small feedback loop around the compressor
    accumulates the placed/complemented terms; the final adder runs once.
    """
    if ct != 3:
        raise ValueError("the Karatsuba MCIM is optimal for (and fixed to) CT=3")
    if levels < 1:
        raise ValueError("levels >= 1")
    la, lb = a.shape[-1], b.shape[-1]
    n = max(la, lb)
    n += n % 2
    half = n // 2
    a0, a1 = _split_pad(a, half, n)
    b0, b1 = _split_pad(b, half, n)
    sa = _half_sum(a0, a1, half + 1)
    sb = _half_sum(b0, b1, half + 1)

    # Stack the three operand pairs on the scan axis, padded to the shared
    # PPM's (half+1)-limb port width -- one PPM, three cycles.
    ops_a = jnp.stack([L.pad_limbs(a0, half + 1),
                       L.pad_limbs(a1, half + 1), sa])
    ops_b = jnp.stack([L.pad_limbs(b0, half + 1),
                       L.pad_limbs(b1, half + 1), sb])

    width = la + lb
    batch = jnp.broadcast_shapes(a.shape[:-1], b.shape[:-1])
    acc0 = jnp.zeros(batch + (width,), dtype=L.LIMB_DTYPE)

    def place_t0(t):      # + T0<<0  - T0<<half
        neg, one = L.negate_cols(t, half, width)
        return L.compress([(t, 0), (neg, 0), (one, 0)], width)

    def place_t1(t):      # + T1<<2h - T1<<half
        neg, one = L.negate_cols(t, half, width)
        return L.compress([(t, 2 * half), (neg, 0), (one, 0)], width)

    def place_t2(t):      # + T2<<half
        return L.compress([(t, half)], width)

    def cycle(acc, xs):
        idx, av, bv = xs
        cols = karatsuba_ppm(av, bv, levels - 1)       # shared PPM
        t = L.final_adder_1ca(cols, 2 * half + 2)
        contrib = jax.lax.switch(idx, [place_t0, place_t1, place_t2], t)
        return acc + contrib, None                     # compressor feedback

    acc, _ = jax.lax.scan(cycle, acc0, (jnp.arange(3), ops_a, ops_b))
    return L.FINAL_ADDERS[adder](acc, la + lb)
