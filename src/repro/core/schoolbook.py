"""Schoolbook multipliers: Star baseline, Feedback (FB) and Feed-Forward (FF).

These are the JAX analogues of the paper's Section III architectures.

Folding ("multi-cycle") is expressed with ``lax.fori_loop`` / ``lax.scan``
over chunks of the second operand B: every iteration re-uses the *same*
PPM + compressor + final-adder computation, exactly as the hardware
re-uses the same silicon over CT clock cycles.  On TPU the win is the
same trade the paper makes: the per-step working set (VMEM footprint,
live registers, HLO size) shrinks by ~1/CT in exchange for a throughput
of 1/CT results per step.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import limbs as L


def _chunk_limbs(lb: int, ct: int) -> int:
    """Limbs per B-chunk for a CT-cycle folded design (ceil(LB/CT))."""
    return -(-lb // ct)


def star_mul(a: jax.Array, b: jax.Array, adder: str = "1ca") -> jax.Array:
    """Single-cycle multiplier (the '*' operator / "Star" baseline).

    Full-width PPM -> compressor (implicit in column sums) -> final adder.
    """
    la, lb = a.shape[-1], b.shape[-1]
    cols = L.ppm(a, b)
    return L.FINAL_ADDERS[adder](cols, la + lb)


def feedback_mul(a: jax.Array, b: jax.Array, ct: int = 2,
                 adder: str = "1ca") -> jax.Array:
    """Feedback (FB) architecture, paper Fig. 1.  Any CT >= 2.

    Per cycle t (LSB chunk first):
      cols  = PPM(A, B_t)                           # M x ceil(N/CT) PPM
      acc   = cols + (prev normalized result >> chunk limbs)   # compressor
      r     = final_adder(acc)                      # M + N/CT adder
      out[t*chunk : (t+1)*chunk] = r[:chunk]        # low limbs retire
    After CT cycles the remaining high limbs of r complete the product.

    The feedback loop forces the carry-propagating adder inside the loop,
    which is why the paper restricts FB to the 1CA adder.
    """
    if ct < 2:
        raise ValueError("FB is a multi-cycle design: ct >= 2")
    if adder != "1ca":
        raise ValueError("FB supports only the 1CA final adder (feedback loop)")
    la, lb = a.shape[-1], b.shape[-1]
    chunk = _chunk_limbs(lb, ct)
    b_pad = L.pad_limbs(b, chunk * ct)
    batch = jnp.broadcast_shapes(a.shape[:-1], b.shape[:-1])
    a = jnp.broadcast_to(a, batch + (la,))
    b_pad = jnp.broadcast_to(b_pad, batch + (chunk * ct,))

    # b chunks stacked on a leading scan axis: (ct, ..., chunk)
    b_chunks = jnp.moveaxis(
        b_pad.reshape(batch + (ct, chunk)), -2, 0)

    width = la + chunk + 1            # compressor / final adder width (M + N/CT + cy)
    r0 = jnp.zeros(batch + (width,), dtype=L.LIMB_DTYPE)

    def cycle(r_prev, b_t):
        cols = L.ppm(a, b_t)                          # (..., la+chunk)
        shifted = r_prev[..., chunk:]                 # feedback, >> chunk limbs
        acc = L.compress([(cols, 0), (shifted, 0)], width)
        r = L.final_adder_1ca(acc, width)
        return r, r[..., :chunk]                      # retire low limbs

    r_final, low_parts = jax.lax.scan(cycle, r0, b_chunks)
    # low_parts: (ct, ..., chunk) -> (..., ct*chunk)
    low = jnp.moveaxis(low_parts, 0, -2).reshape(batch + (ct * chunk,))
    out = jnp.concatenate([low, r_final[..., chunk:]], axis=-1)
    return out[..., :la + lb]


def feedforward_mul(a: jax.Array, b: jax.Array, ct: int = 2,
                    adder: str = "1ca") -> jax.Array:
    """Feed-Forward (FF) architecture, paper Fig. 2.

    No feedback loop: all CT partial-product passes run first (the same
    PPM re-used each cycle, results held in "registers" = scan outputs),
    then a single 2*CT:2 compressor + final adder finish the product.
    Fully pipelineable; area-efficient at CT=2 (paper Sec. III-C) --
    larger CT inflates the register file and compressor, which the area
    model reflects.
    """
    if ct < 2:
        raise ValueError("FF is a multi-cycle design: ct >= 2")
    la, lb = a.shape[-1], b.shape[-1]
    chunk = _chunk_limbs(lb, ct)
    b_pad = L.pad_limbs(b, chunk * ct)
    batch = jnp.broadcast_shapes(a.shape[:-1], b.shape[:-1])
    a = jnp.broadcast_to(a, batch + (la,))
    b_pad = jnp.broadcast_to(b_pad, batch + (chunk * ct,))
    b_chunks = jnp.moveaxis(b_pad.reshape(batch + (ct, chunk)), -2, 0)

    def ppm_pass(_, b_t):                             # shared PPM, no feedback
        return None, L.ppm(a, b_t)

    _, parts = jax.lax.scan(ppm_pass, None, b_chunks)  # (ct, ..., la+chunk)

    width = la + ct * chunk + 1
    terms = [(parts[t], t * chunk) for t in range(ct)]  # 2*CT:2 compressor
    acc = L.compress(terms, width)
    return L.FINAL_ADDERS[adder](acc, la + lb)
