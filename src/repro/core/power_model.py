"""Switching-energy / peak-power cost model for MCIM designs (bit-level).

The paper's headline claims beyond area are **up to 33% energy savings
and 65% average peak power reduction** for TP=1/2 MCIM designs vs the
directly synthesized ``*`` operator.  We cannot measure silicon power
here, so the reproduction models energy the way the area model models
area: by counting, at BIT granularity, the work each stage performs per
multiplication, with physically-motivated activity ratios and ONE
silicon scale calibrated on a single anchor.

Per-op dynamic energy is a *counting* model -- every quantity below is
per multiplication, not per cycle, which makes the folding benefit
explicit:

  PPM         : all Na*Nb partial-product bits are generated and
                carry-save-added exactly once whatever the folding;
                what folding changes is the GLITCH factor.  Spurious
                transitions grow with the uninterrupted combinational
                depth d (carry-save rows traversed before a register
                boundary): Star propagates through all Nb rows, a
                folded design only through Nb/CT rows per pass
                (registers kill glitch propagation).  We model the
                multiplier as glitch(d) = 1 + G_GLITCH * d**GLITCH_EXP
                (sub-linear: array glitching saturates with depth).
  compressor  : reducing Na*Nb PP bits to 2 carry-save rows costs
                (Na*Nb - 2W) full-adder compressions *regardless* of
                architecture (each FA retires one bit) -- same count
                for Star's internal CSA and a folded design's external
                rows -- but it glitches at the same depth as the PPM.
  final adder : every product bit exits through a carry-propagate
                adder exactly once; longer adders glitch more
                (1 + G_ADDER * log2(width)), so Star's full-width CPA
                pays more per bit than FB's Na+Nb/CT+1 adder, and a
                3CA splits the add into 3 shorter, cheaper passes.
  registers   : folded designs clock flip-flops (retired product bits,
                FF's carry-save pairs, Karatsuba's accumulator); Star
                is purely combinational.  Flip-flop energy is dominated
                by the clock pin (A_REG well below logic activity).
  leakage     : proportional to instantiated area (area_model cells) --
                folded designs leak less because they ARE smaller.

Peak power is the largest per-cycle switched capacitance times the
clock frequency: Star switches its entire dynamic energy in ONE cycle,
a folded design spreads it over CT cycles, so peak power drops by
roughly the energy ratio divided by CT -- reproducing the paper's
"65% average peak power reduction" headline direction.

The single silicon scale is calibrated on ONE anchor -- Star 16x16 =
1.0 pJ/op, the 45 nm integer-multiply energy scale of Horowitz's
ISSCC'14 survey -- exactly as ``area_model`` anchors on Star 16x16 =
1348 um^2.  Every other energy/power figure in benchmarks/ is a model
prediction; ``benchmarks.paper_tables.table_energy`` reports the sweep
vs the paper's headline direction.
"""
from __future__ import annotations

import dataclasses
from math import ceil, log2

from .mcim import MCIMConfig
from . import area_model
from . import timing_model

# ------------------------------------------------------------- model knobs
# Activity ratios (fraction of cells that toggle per op), physically
# motivated: random operands toggle ~half the AND/CSA cells; external
# compression re-walks already-partially-settled sums; adder cells are
# larger (RHO_ADD) but settle once; flip-flop energy is mostly clock pin.
A_PPM = 0.5
A_COMP = 0.25
A_ADD = 0.15
A_REG = 0.08

#: glitch factor 1 + G_GLITCH * depth**GLITCH_EXP for a combinational
#: block of carry-save depth ``depth`` (rows before a register boundary)
G_GLITCH = 0.28
GLITCH_EXP = 0.65
#: final-adder glitch slope per log2 of adder length
G_ADDER = 0.12
#: leakage energy per op as a fraction of instantiated area cells
LEAK_RATIO = 0.08
#: extra compress+add pass for the two's-complement sign correction
SIGNED_OVERHEAD = 1.05

#: bump when the model maths change -- keyed into the autotuner's
#: score cache so stale fronts are never served across model revisions
MODEL_VERSION = "power-1"


@dataclasses.dataclass(frozen=True)
class EnergyBreakdown:
    """Per-op energy by stage, in calibrated cell units."""
    ppm: float
    compressor: float
    final_adder: float
    registers: float
    leakage: float

    @property
    def dynamic(self) -> float:
        return self.ppm + self.compressor + self.final_adder + self.registers

    @property
    def total(self) -> float:
        return self.dynamic + self.leakage


def _glitch(depth: float) -> float:
    return 1.0 + G_GLITCH * max(depth, 1.0) ** GLITCH_EXP

def _adder_glitch(width: float, fold: int = 1) -> float:
    return 1.0 + G_ADDER * log2(max(width / fold, 2.0))

def _fa_count(na: int, nb: int, width: int) -> float:
    """Full-adder compressions to reduce na*nb PP bits to 2 rows."""
    return float(max(na * nb - 2 * width, 0))


# -------------------------------------------------------------- per design

def star_energy(na: int, nb: int) -> EnergyBreakdown:
    """Single-cycle '*': full-depth glitch, full-width CPA, no registers."""
    width = na + nb
    return EnergyBreakdown(
        ppm=A_PPM * na * nb * _glitch(nb),
        compressor=A_COMP * _fa_count(na, nb, width) * _glitch(nb),
        final_adder=A_ADD * area_model.RHO_ADD * width * _adder_glitch(width),
        registers=0.0,
        leakage=LEAK_RATIO * area_model.star_units(na, nb).total,
    )


def fb_energy(na: int, nb: int, ct: int) -> EnergyBreakdown:
    """Feedback: Nb/CT rows per pass (average occupied depth -- the last
    pass is partially filled), short Na+Nb/CT+1 adder, retired-bit regs."""
    depth = nb / ct
    chunk = ceil(nb / ct)
    w_add = na + chunk + 1
    return EnergyBreakdown(
        ppm=A_PPM * na * nb * _glitch(depth),
        compressor=A_COMP * _fa_count(na, nb, na + nb) * _glitch(depth),
        final_adder=A_ADD * area_model.RHO_ADD * (na + nb)
                    * _adder_glitch(w_add),
        registers=A_REG * area_model.RHO_REG * (nb - chunk),
        leakage=LEAK_RATIO * area_model.fb_units(na, nb, ct).total,
    )


def ff_energy(na: int, nb: int, ct: int, adder: str = "1ca") -> EnergyBreakdown:
    """Feed-forward: same folded-depth glitch, but every carry-save pair
    is registered (CT pairs written once each) and the final add is
    full-width (split into 3 shorter passes by a 3CA)."""
    depth = nb / ct
    chunk = ceil(nb / ct)
    width = na + nb
    fold = 3 if adder == "3ca" else 1
    return EnergyBreakdown(
        ppm=A_PPM * na * nb * _glitch(depth),
        compressor=A_COMP * _fa_count(na, nb, width) * _glitch(depth),
        final_adder=A_ADD * area_model.RHO_ADD * width
                    * _adder_glitch(width, fold),
        registers=A_REG * area_model.RHO_REG * ct * (na + chunk),
        leakage=LEAK_RATIO * area_model.ff_units(na, nb, ct, adder).total,
    )


def karatsuba_energy(na: int, nb: int, levels: int,
                     adder: str = "1ca") -> EnergyBreakdown:
    """CT=3 folded Karatsuba: 3 passes over one shared (n/2+1)-port PPM
    (3^levels leaf multiplies in total) -- fewer PP bits than Star's n^2
    and a shallower leaf array, at the cost of accumulator registers."""
    n = max(na, nb)
    width = na + nb
    ppm_cells, comb_cells = area_model._kara_ppm_units(n // 2 + 1, levels - 1)
    leaf = n // 2 + 1
    for _ in range(levels - 1):
        leaf = leaf // 2 + 1
    bits = 3 * (ppm_cells + comb_cells)     # PP bits + combine compressions
    fold = 3 if adder == "3ca" else 1
    return EnergyBreakdown(
        ppm=A_PPM * 3 * ppm_cells * _glitch(leaf),
        compressor=A_COMP * (3 * comb_cells + max(bits - 2 * width, 0.0))
                   * _glitch(leaf) / 2.0,
        final_adder=A_ADD * area_model.RHO_ADD * width
                    * _adder_glitch(width, fold),
        registers=A_REG * area_model.RHO_REG * 3 * width,
        leakage=LEAK_RATIO
                * area_model.karatsuba_units(na, nb, levels, adder).total,
    )


def mcim_energy(bits_a: int, bits_b: int, cfg: MCIMConfig) -> EnergyBreakdown:
    """Per-op energy breakdown for one MCIM instance (cell units)."""
    if cfg.arch == "star":
        e = star_energy(bits_a, bits_b)
    elif cfg.arch == "fb":
        e = fb_energy(bits_a, bits_b, cfg.ct)
    elif cfg.arch == "ff":
        e = ff_energy(bits_a, bits_b, cfg.ct, cfg.adder)
    else:
        e = karatsuba_energy(bits_a, bits_b, cfg.levels, cfg.adder)
    if cfg.signed:
        # one extra negate+compress+add pass for the sign corrections
        e = EnergyBreakdown(
            ppm=e.ppm,
            compressor=e.compressor * SIGNED_OVERHEAD,
            final_adder=e.final_adder * SIGNED_OVERHEAD,
            registers=e.registers,
            leakage=e.leakage,
        )
    return e


def peak_switched(bits_a: int, bits_b: int, cfg: MCIMConfig) -> float:
    """Largest per-cycle switched capacitance (cell units).

    Star commits its whole dynamic energy in a single cycle.  FB and
    Karatsuba spread theirs ~uniformly over CT cycles.  FF's fold cycles
    carry the PPM/compressor/register work while the full-width final
    add lands in the retire cycle, which is therefore its peak.
    """
    e = mcim_energy(bits_a, bits_b, cfg)
    if cfg.arch == "star":
        return e.dynamic
    if cfg.arch == "ff":
        per_fold = (e.ppm + e.compressor + e.registers) / cfg.ct
        return per_fold + e.final_adder
    return e.dynamic / cfg.ct


# ------------------------------------------------------------- calibration
# ONE anchor, exactly as area_model: Star 16x16 = 1.0 pJ per multiply
# (the 45 nm integer-multiply scale of Horowitz, ISSCC 2014).
FJ_PER_CELL = 1000.0 / star_energy(16, 16).total


def energy_per_op_pj(bits_a: int, bits_b: int, cfg: MCIMConfig) -> float:
    """Modeled energy per multiplication, picojoules."""
    return mcim_energy(bits_a, bits_b, cfg).total * FJ_PER_CELL / 1000.0


def peak_power_mw(bits_a: int, bits_b: int, cfg: MCIMConfig,
                  clock_ns: float | None = None) -> float:
    """Peak power (mW) = max per-cycle switched energy / clock period.

    ``clock_ns`` defaults to the design's own combinational path (its
    natural clock); pass a common clock to compare designs in a bank.
    """
    period = clock_ns if clock_ns is not None \
        else timing_model.t_comb(cfg.arch, max(bits_a, bits_b))
    sw_fj = peak_switched(bits_a, bits_b, cfg) * FJ_PER_CELL
    return sw_fj / period * 1e-3          # fJ/ns = uW


# ----------------------------------------------------------- vs-Star views

def energy_savings_vs_star(bits_a: int, bits_b: int, cfg: MCIMConfig) -> float:
    """Fractional per-op energy savings vs the single-cycle Star."""
    star = star_energy(bits_a, bits_b).total
    ours = mcim_energy(bits_a, bits_b, cfg).total
    return 1.0 - ours / star


def peak_power_reduction_vs_star(bits_a: int, bits_b: int,
                                 cfg: MCIMConfig) -> float:
    """Fractional peak-power reduction vs Star at a common clock (the
    clock cancels: this is the switched-capacitance ratio)."""
    star = peak_switched(bits_a, bits_b,
                         MCIMConfig(arch="star", ct=1,
                                    signed=cfg.signed))
    ours = peak_switched(bits_a, bits_b, cfg)
    return 1.0 - ours / star


# ------------------------------------------------------------- bank (plan)

def plan_energy_per_op_pj(bits_a: int, bits_b: int, configs,
                          stress: float = 1.0) -> float:
    """Throughput-weighted energy per multiplication of a bank.

    ``configs`` is an iterable of (count, MCIMConfig).  An instance with
    cycle time CT contributes count/CT of the bank's ops per cycle, so
    the average op costs sum(count/ct * E_op) / sum(count/ct).  The
    synthesis-stress multiplier models the larger (higher-capacitance)
    cells a tight clock target forces, mirroring CompiledDesign.area.
    """
    num = den = 0.0
    for count, cfg in configs:
        share = count / cfg.ct
        num += share * energy_per_op_pj(bits_a, bits_b, cfg)
        den += share
    return stress * num / den if den else 0.0


def plan_peak_power_mw(bits_a: int, bits_b: int, configs,
                       clock_ns: float | None = None,
                       stress: float = 1.0) -> float:
    """Bank peak power (mW): all instances switch concurrently in the
    worst cycle; the period defaults to the slowest instance's path."""
    if clock_ns is None:
        clock_ns = max(timing_model.t_comb(cfg.arch, max(bits_a, bits_b))
                       for _, cfg in configs)
    sw_fj = sum(count * peak_switched(bits_a, bits_b, cfg)
                for count, cfg in configs) * FJ_PER_CELL
    return stress * sw_fj / clock_ns * 1e-3
