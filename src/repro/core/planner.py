"""Design-point planner: pick the best MCIM design for an application.

Encodes the paper's Sec. V-D guidance (Table VIII) as an executable
policy, refined by the area model:

  * strict timing           -> FF (no feedback loop, pipelineable)
  * relaxed timing, CT >= 3 -> FB (deepest resource sharing)
  * bits >= 128             -> Karatsuba (CT=3), recursion level by size
  * TP fractional (i/j)     -> mixture of Star and MCIM instances
"""
from __future__ import annotations

import dataclasses
import math
from fractions import Fraction

from .mcim import MCIMConfig
from . import area_model
from . import power_model

#: planning objectives: the scalar each candidate design is ranked by
OBJECTIVES = ("area", "energy")


def _objective_key(bits_a: int, bits_b: int, objective: str):
    if objective == "area":
        return lambda c: area_model.mcim_area(bits_a, bits_b, c).total
    if objective == "energy":
        return lambda c: power_model.mcim_energy(bits_a, bits_b, c).total
    raise ValueError(f"objective must be one of {OBJECTIVES}")

#: Fractional TPs are quantized to this denominator bound (the largest
#: CT combination the Sec. V-B planner explores).  repro.designs mirrors
#: it so a DesignSpec's throughput always matches the plan it compiles.
MAX_TP_DENOMINATOR = 12


@dataclasses.dataclass(frozen=True)
class Plan:
    """A multiplier bank achieving an aggregate throughput."""
    configs: tuple            # tuple[(count, MCIMConfig)]
    throughput: Fraction
    area: float               # um^2 (area-model estimate)

    def describe(self) -> str:
        parts = []
        for c, cfg in self.configs:
            detail = [f"ct={cfg.ct}"]
            if cfg.arch == "karatsuba":
                detail.append(f"K={cfg.levels}")
            if cfg.adder != "1ca":       # e.g. 3CA: a genuinely different
                detail.append(cfg.adder)  # design, must not print as 1CA
            if cfg.signed:
                detail.append("signed")
            parts.append(f"{c}x {cfg.arch}({','.join(detail)})")
        return " + ".join(parts) + f"  TP={self.throughput}  area={self.area:.0f}um2"


def best_single(bits_a: int, bits_b: int, ct: int,
                strict_timing: bool = False,
                objective: str = "area") -> MCIMConfig:
    """Best single MCIM design for a given CT (paper Table VIII policy).

    ``objective`` ranks the candidate set by the area model (default,
    the paper's tables) or by the power model's per-op energy (the
    low-power registry points); the candidate set itself is identical.
    """
    if ct == 1:
        return MCIMConfig(arch="star", ct=1)
    candidates = []
    if ct == 2:
        candidates.append(MCIMConfig(arch="ff", ct=2))
        if not strict_timing:
            candidates.append(MCIMConfig(arch="fb", ct=2))
    else:
        if not strict_timing:
            candidates.append(MCIMConfig(arch="fb", ct=ct))
        if ct == 3:
            best_k = best_karatsuba_levels(bits_a, bits_b)
            candidates.append(MCIMConfig(arch="karatsuba", ct=3, levels=best_k))
            if not strict_timing:
                candidates.append(MCIMConfig(arch="karatsuba", ct=3,
                                             levels=best_k, adder="3ca"))
    if not candidates:   # strict timing && ct>2 without FB: pipeline FF anyway
        candidates.append(MCIMConfig(arch="ff", ct=ct))
    return min(candidates, key=_objective_key(bits_a, bits_b, objective))


def best_karatsuba_levels(bits_a: int, bits_b: int, max_levels: int = 4) -> int:
    """Optimal recursion depth by the area model (paper: size-dependent)."""
    best, best_area = 1, float("inf")
    for k in range(1, max_levels + 1):
        a = area_model.mcim_area(bits_a, bits_b,
                                 MCIMConfig(arch="karatsuba", ct=3, levels=k)).total
        if a < best_area:
            best, best_area = k, a
    return best


def plan_throughput(bits_a: int, bits_b: int, tp: Fraction | float,
                    strict_timing: bool = False,
                    objective: str = "area") -> Plan:
    """Multiplier bank for a (possibly fractional) multiplications/cycle TP.

    Paper use case 1: TP = i/j with i/j not an integer, e.g. 3.5 -> three
    Star multipliers + one CT=2 MCIM instead of four Stars.
    """
    tp = Fraction(tp).limit_denominator(MAX_TP_DENOMINATOR)
    n_full = math.floor(tp)
    frac = tp - n_full
    configs = []
    if n_full:
        configs.append((n_full, MCIMConfig(arch="star", ct=1)))
    if frac:
        ct = int(1 / frac) if (1 / frac) == int(1 / frac) else None
        if ct is not None:
            configs.append((1, best_single(bits_a, bits_b, ct, strict_timing,
                                           objective)))
        else:
            # e.g. 5/6 -> one CT=2 + one CT=3 (paper Sec. V-B combinations)
            remaining = frac
            for ct_try in (2, 3, 4, 6, 8, 12):
                piece = Fraction(1, ct_try)
                while remaining >= piece:
                    configs.append((1, best_single(bits_a, bits_b, ct_try,
                                                   strict_timing, objective)))
                    remaining -= piece
                if remaining == 0:
                    break
    area = sum(c * area_model.area_um2(bits_a, bits_b, cfg)
               for c, cfg in configs)
    return Plan(configs=tuple(configs), throughput=tp, area=area)


def star_bank_area(bits_a: int, bits_b: int, tp: Fraction | float) -> float:
    """Area of the conventional round-up-to-integer Star bank."""
    n = math.ceil(Fraction(tp).limit_denominator(MAX_TP_DENOMINATOR))
    return n * area_model.area_um2(bits_a, bits_b, MCIMConfig(arch="star", ct=1))
