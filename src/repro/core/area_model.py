"""Hardware-area cost model for MCIM designs (bit-level).

The paper's headline results are ASIC areas (TSMC 40 nm, Synopsys DC).
We cannot synthesize silicon here, so the reproduction models area the
way the paper's Sec. III analyses do: a design's area is the sum of the
*per-cycle instantiated* resources of its stages (folded stages are
shared across cycles), counted at BIT granularity:

  PPM(M x C)      : M*C cells        (AND + internal carry-save cell;
                                      DW02_multp-style, 2-row output)
  ext. compressor : (rows-2) * width (3:2 / 4:2 / 5:2 / 10:2 FA rows)
  final adder     : width * RHO_ADD  (carry-propagate cells are larger)
  registers       : bits * RHO_REG   (flip-flops)

Stage ratios are FIXED at physically-motivated values (an external
compressor row ~ one PPM cell; an adder cell ~4x; a flip-flop ~0.7x);
the single silicon scale UM2_PER_CELL is calibrated on ONE paper number
(Star 16x16 = 1348 um^2, Table II).  Every other area in benchmarks/
is a prediction; the paper's Star 32/128 areas land within ~6% and the
full design sweep within ~10% (see benchmarks.paper_tables output).
"""
from __future__ import annotations

import dataclasses
from math import ceil

from .mcim import MCIMConfig

RHO_COMP = 1.0
RHO_ADD = 4.0
RHO_REG = 0.7


@dataclasses.dataclass(frozen=True)
class AreaBreakdown:
    ppm: float
    compressor: float
    final_adder: float
    registers: float

    @property
    def total(self) -> float:
        return self.ppm + self.compressor + self.final_adder + self.registers


def star_units(na: int, nb: int) -> AreaBreakdown:
    """Single-cycle '*': full PPM (internal CSA) + 2(Na+Nb)-ish adder."""
    return AreaBreakdown(
        ppm=float(na * nb),
        compressor=0.0,
        final_adder=RHO_ADD * (na + nb),
        registers=0.0,
    )


def fb_units(na: int, nb: int, ct: int) -> AreaBreakdown:
    """Feedback (Fig. 1): M x ceil(N/CT) PPM, 3:2 comp + adder of
    M + N/CT bits, output registers for the retired low bits."""
    chunk = ceil(nb / ct)
    width = na + chunk + 1
    return AreaBreakdown(
        ppm=float(na * chunk),
        compressor=RHO_COMP * width,           # (3 rows -> 2) x width
        final_adder=RHO_ADD * width,
        registers=RHO_REG * (nb - chunk),
    )


def ff_units(na: int, nb: int, ct: int, adder: str = "1ca") -> AreaBreakdown:
    """Feed-forward (Fig. 2): same folded PPM, all CT carry-save pairs
    held in registers, 2*CT:2 compressor + full-width adder."""
    chunk = ceil(nb / ct)
    width = na + nb
    fold = 3 if adder == "3ca" else 1
    return AreaBreakdown(
        ppm=float(na * chunk),
        compressor=RHO_COMP * (2 * ct - 2) * width,
        final_adder=RHO_ADD * width / fold,
        registers=RHO_REG * ct * (na + chunk),
    )


def _kara_ppm_units(port: int, levels: int) -> tuple:
    """Combinational Karatsuba PPM (Fig. 4): (ppm_cells, comp_cells)."""
    if levels == 0 or port <= 2:
        return float(port * port), 0.0
    sub_p, sub_c = _kara_ppm_units(port // 2 + 1, levels - 1)
    return 3 * sub_p, 3 * sub_c + 8.0 * (2 * port)   # 10:2 combine


def karatsuba_units(na: int, nb: int, levels: int,
                    adder: str = "1ca") -> AreaBreakdown:
    """CT=3 folded Karatsuba (Fig. 3): one (n/2+1)-bit shared PPM,
    5:2 accumulating compressor, full-width adder + accumulator regs."""
    n = max(na, nb)
    width = na + nb
    ppm, comp = _kara_ppm_units(n // 2 + 1, levels - 1)
    fold = 3 if adder == "3ca" else 1
    return AreaBreakdown(
        ppm=ppm,
        compressor=comp + RHO_COMP * 3 * width,      # 5:2 loop
        final_adder=RHO_ADD * width / fold,
        registers=RHO_REG * width,
    )


def mcim_area(bits_a: int, bits_b: int, cfg: MCIMConfig) -> AreaBreakdown:
    if cfg.arch == "star":
        return star_units(bits_a, bits_b)
    if cfg.arch == "fb":
        return fb_units(bits_a, bits_b, cfg.ct)
    if cfg.arch == "ff":
        return ff_units(bits_a, bits_b, cfg.ct, cfg.adder)
    return karatsuba_units(bits_a, bits_b, cfg.levels, cfg.adder)


def star_area(bits_a: int, bits_b: int) -> AreaBreakdown:
    return star_units(bits_a, bits_b)


# Calibration: ONE constant from the paper's Star(16x16) = 1348 um^2.
UM2_PER_CELL = 1348.0 / star_units(16, 16).total


def area_um2(bits_a: int, bits_b: int, cfg: MCIMConfig) -> float:
    return mcim_area(bits_a, bits_b, cfg).total * UM2_PER_CELL


def savings_vs_star(bits_a: int, bits_b: int, cfg: MCIMConfig) -> float:
    """Fractional area savings of an MCIM design vs the Star baseline."""
    star = star_units(bits_a, bits_b).total
    ours = mcim_area(bits_a, bits_b, cfg).total
    return 1.0 - ours / star


def array_area_um2(bits_a: int, bits_b: int) -> float:
    """[16]-style single-cycle custom ARRAY multiplier (paper Table IX
    baseline), calibrated on the paper's synthesis of [16]-1
    (128x64 -> 63387 um^2)."""
    return 63387.0 * (bits_a * bits_b) / (128 * 64)
