"""Timing / synthesis-pressure model for the strict-timing tables.

We cannot run Synopsys DC here, so the strict-timing reproduction uses a
two-part parametric model, calibrated ONCE on the paper's own Star data
points and then applied unchanged to every MCIM design (so all MCIM
numbers are predictions, not fits):

  1. critical path  t_comb(class, bits) = T0 * (1 + S * log2(bits/B0))
     -- one (T0, B0) anchor per design class from the paper's Tables
     V/VIII, shared slope S.
  2. synthesis stress: meeting a target below a design's relaxed path
     forces larger cells / deeper pipelines; the paper's Star rows give
     stress(16b: 10ns->0.31ns) = 5178/1348 = 3.84x and
     stress(128b: 10ns->0.8ns) = 121634/66319 = 1.83x.  We model
         stress = (t_comb / t_target) ** GAMMA   (>= 1)
     and fit GAMMA on those two Star anchors.

  Pipelineable designs (Star, FF, Karatsuba with 1CA) can always meet
  timing by adding latency (retiming); feedback-loop designs (FB, 3CA)
  cannot pipeline through the loop, so they MISS targets below t_comb --
  reproducing the paper's Table IV structure where FB misses 0.31 ns.
"""
from __future__ import annotations

import math

# critical-path anchors (ns @ TSMC 40nm, from the paper's tables)
_ANCHORS = {
    # class: (T0_ns, B0_bits)
    "star": (1.00, 16),       # Table VIII: Star 16x16 meets 1.00 ns, L=1
    "fb": (0.46, 16),         # Table IV: FB CT2 reaches 0.46 ns at 16b
                              # (predicts 0.85 at 128b vs Table V's 0.80)
    "ff": (0.55, 16),         # FF stage path (between pipeline regs)
    "karatsuba": (0.54, 128), # Table V: Karat-1 1CA -> 0.54 ns
    "array": (1.40, 16),      # array multipliers are slower per bit
}
_SLOPE = 0.28                 # shared log2 width slope


def t_comb(design_class: str, bits: int) -> float:
    t0, b0 = _ANCHORS[design_class]
    return t0 * max(0.3, 1.0 + _SLOPE * math.log2(max(bits, 2) / b0))


def _fit_gamma() -> float:
    # two Star anchors: (bits, t_target, stress)
    pts = [(16, 0.31, 5178 / 1348), (128, 0.80, 121634 / 66319)]
    gs = []
    for bits, tgt, stress in pts:
        ratio = t_comb("star", bits) / tgt
        gs.append(math.log(stress) / math.log(ratio))
    return sum(gs) / len(gs)


GAMMA = _fit_gamma()


def pipelineable(design_class: str, adder: str = "1ca") -> bool:
    if design_class in ("star", "ff", "array"):
        return True
    if design_class == "karatsuba":
        return adder == "1ca"   # the 3CA feedback loop blocks retiming
    return False                # fb


def meets_timing(design_class: str, bits: int, t_target: float,
                 adder: str = "1ca") -> bool:
    if pipelineable(design_class, adder):
        return True
    return t_comb(design_class, bits) <= t_target * 1.10


def stress(design_class: str, bits: int, t_target: float) -> float:
    """Area multiplier for synthesizing at t_target vs relaxed timing.

    SHARED across design classes (keyed on the Star critical path): the
    paper's own data shows Star and FF inflate by the same ratio at a
    given (width, target) -- 1.83x for both at 128b/0.8ns -- because
    tight targets force faster cells on *every* design being squeezed
    into the same clock, regardless of its relaxed slack.  design_class
    is kept in the signature for meets_timing symmetry."""
    ratio = t_comb("star", bits) / t_target
    return max(1.0, ratio ** GAMMA)


def latency_at(design_class: str, bits: int, t_target: float,
               ct: int) -> int:
    """Pipeline depth needed: ceil(t_comb / t_target) extra stages."""
    base = ct if design_class != "star" else 1
    if t_target >= t_comb(design_class, bits):
        return base
    stages = math.ceil(t_comb(design_class, bits) / t_target) - 1
    return base + stages
