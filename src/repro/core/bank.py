"""Bank execution engine: run ``planner.Plan`` objects as real multipliers.

``planner.plan_throughput`` picks a *bank* of multiplier instances (e.g.
TP=3.5 -> three Star + one CT=2 MCIM) but until now only estimated its
area.  This module makes plans executable: a batch of multiplications is
dispatched round-robin across the plan's instances exactly the way the
paper's Sec. V-E use case issues work to the silicon bank -- each cycle,
every instance that is free accepts the next pending multiplication; an
instance with cycle time CT accepts one multiplication every CT cycles.

The resulting engine is

  * bit-exact: every instance runs the matching ``mcim_mul`` config (or
    the ``kernels.mcim_fold`` Pallas kernel), so the reassembled batch
    equals the Python-int oracle;
  * cycle-accounted: the dispatch schedule is simulated once per batch
    size (and cached), giving per-instance busy cycles and the bank
    makespan, so measured throughput can be checked against
    ``Plan.throughput``;
  * jit/pjit-compatible: the schedule is static for a given batch size,
    so ``execute`` lowers to gathers + batched multiplies + scatters.

Backends: "core" runs instances through ``mcim_mul`` (pure jnp);
"kernel" routes Star/FB/FF instances through the folded Pallas kernel
(``kernels.mcim_fold.big_mul``) and Karatsuba instances through the
Karatsuba-PPM kernel when operand widths match (core fallback
otherwise).
"""
from __future__ import annotations

import dataclasses
import functools
from fractions import Fraction

import numpy as np
import jax
import jax.numpy as jnp

from . import limbs as L
from .mcim import MCIMConfig, mcim_mul
from .planner import Plan

BACKENDS = ("core", "kernel")


# ------------------------------------------------------------------ schedule

@functools.lru_cache(maxsize=1024)
def round_robin_schedule(cts: tuple, n_ops: int) -> tuple:
    """Cycle-accurate round-robin issue of ``n_ops`` over instances.

    ``cts[i]`` is instance i's cycle time (issue interval).  Each cycle,
    instances are polled in order; a free instance accepts the next
    pending op and stays busy for its CT.  Returns (assignment, cycles):
    ``assignment[i]`` is the tuple of op indices instance i executes and
    ``cycles`` is the bank makespan (cycle the last result retires).
    """
    n_inst = len(cts)
    free_at = [0] * n_inst
    assign = [[] for _ in range(n_inst)]
    issued = 0
    cycle = 0
    while issued < n_ops:
        for i in range(n_inst):
            if issued >= n_ops:
                break
            if free_at[i] <= cycle:
                assign[i].append(issued)
                free_at[i] = cycle + cts[i]
                issued += 1
        cycle += 1
    makespan = max((free_at[i] for i in range(n_inst) if assign[i]),
                   default=0)
    return tuple(tuple(ops) for ops in assign), makespan


# ------------------------------------------------------------------ reports

@dataclasses.dataclass(frozen=True)
class InstanceReport:
    """Per-instance cycle accounting for one executed batch."""
    config: MCIMConfig
    n_ops: int
    busy_cycles: int          # n_ops * ct: cycles the datapath is occupied

    @property
    def ct(self) -> int:
        return self.config.ct


@dataclasses.dataclass(frozen=True)
class BankReport:
    """Throughput accounting for one executed batch."""
    batch: int
    cycles: int                       # bank makespan
    instances: tuple                  # tuple[InstanceReport]
    plan_throughput: Fraction
    working_set_bytes: int            # sum of per-instance VMEM footprints

    @property
    def measured_throughput(self) -> Fraction:
        return Fraction(self.batch, self.cycles) if self.cycles else Fraction(0)

    @property
    def utilization(self) -> float:
        if not self.cycles:
            return 0.0
        return float(self.measured_throughput / self.plan_throughput)


# ------------------------------------------------------------------ the bank

def _instance_mul(cfg: MCIMConfig, la: int, lb: int, backend: str):
    """The batched multiplier function for one bank instance."""
    if backend == "core":
        return functools.partial(mcim_mul, config=cfg)
    # kernel backend
    from repro.kernels.mcim_fold import big_mul
    if cfg.arch in ("star", "fb"):
        return functools.partial(big_mul, ct=cfg.ct if cfg.arch == "fb" else 1,
                                 schedule="fb")
    if cfg.arch == "ff":
        return functools.partial(big_mul, ct=cfg.ct, schedule="ff")
    # karatsuba: the PPM kernel requires equal operand widths; fall back
    # to the core path otherwise.
    if la == lb:
        from repro.kernels.karatsuba_ppm import kara_mul
        return kara_mul
    return functools.partial(mcim_mul, config=cfg)


def _instance_working_set(cfg: MCIMConfig, la: int, lb: int,
                          tile_b: int) -> int:
    """Per-step VMEM footprint of one instance (the TPU 'area')."""
    from repro.kernels.mcim_fold import vmem_bytes_per_step
    if cfg.arch == "star":
        return vmem_bytes_per_step(la, lb, 1, tile_b)
    if cfg.arch == "ff":
        return vmem_bytes_per_step(la, lb, cfg.ct, tile_b, schedule="ff")
    # fb; karatsuba folds its top level over CT=3 like FB
    return vmem_bytes_per_step(la, lb, cfg.ct, tile_b)


class Bank:
    """Executable multiplier bank for one ``planner.Plan``.

    ``execute(a, b)`` multiplies a batch of limb vectors
    (B, LA) x (B, LB) -> (B, LA+LB) bit-exactly; ``last_report`` /
    ``report(batch)`` exposes the cycle accounting.
    """

    # each distinct batch size compiles its own dispatch; bound the set
    # (FIFO eviction) so ragged serving batches cannot grow it unboundedly
    MAX_COMPILED = 32

    def __init__(self, plan: Plan, bits_a: int, bits_b: int, *,
                 backend: str = "core", tile_b: int = 256):
        if backend not in BACKENDS:
            raise ValueError(f"backend must be one of {BACKENDS}")
        self.plan = plan
        self.bits_a, self.bits_b = bits_a, bits_b
        self.la = L.n_limbs_for_bits(bits_a)
        self.lb = L.n_limbs_for_bits(bits_b)
        self.backend = backend
        self.tile_b = tile_b
        # expand [(count, cfg)] -> flat instance list, Stars first so the
        # fast units drain the head of the queue like the paper's bank
        self.instances = tuple(
            cfg for count, cfg in plan.configs for _ in range(count))
        if not self.instances:
            raise ValueError("plan has no instances")
        self._cts = tuple(cfg.ct for cfg in self.instances)
        self._muls = tuple(_instance_mul(cfg, self.la, self.lb, backend)
                           for cfg in self.instances)
        self._compiled = {}           # batch size -> jitted execute
        self.last_report = None

    # -------------------------------------------------------------- reports
    def report(self, batch: int) -> BankReport:
        assign, cycles = round_robin_schedule(self._cts, batch)
        insts = tuple(
            InstanceReport(cfg, len(ops), len(ops) * cfg.ct)
            for cfg, ops in zip(self.instances, assign))
        ws = sum(_instance_working_set(cfg, self.la, self.lb, self.tile_b)
                 for cfg in self.instances)
        return BankReport(batch=batch, cycles=cycles, instances=insts,
                          plan_throughput=self.plan.throughput,
                          working_set_bytes=ws)

    # -------------------------------------------------------------- execute
    def _build(self, batch: int):
        assign, _ = round_robin_schedule(self._cts, batch)
        idx = [np.asarray(ops, np.int32) for ops in assign]
        muls = self._muls
        la, lb = self.la, self.lb

        def run(a, b):
            out = jnp.zeros((batch, la + lb), L.LIMB_DTYPE)
            for ops, mul in zip(idx, muls):
                if ops.size == 0:
                    continue
                part = mul(a[ops], b[ops])
                out = out.at[ops].set(part)
            return out

        return jax.jit(run)

    def execute(self, a: jax.Array, b: jax.Array) -> jax.Array:
        """(B, LA) x (B, LB) -> (B, LA+LB) limbs, bit-exact."""
        if a.ndim == 1:
            return self.execute(a[None], b[None])[0]
        batch = a.shape[0]
        if b.shape[0] != batch:
            # without this, the gather in _build clamps out-of-range op
            # indices and silently returns wrong products
            raise ValueError(
                f"batch mismatch: a has {batch} ops, b has {b.shape[0]}")
        if a.shape[-1] != self.la or b.shape[-1] != self.lb:
            raise ValueError(
                f"operand limbs {a.shape[-1]}x{b.shape[-1]} do not match "
                f"bank widths {self.la}x{self.lb}")
        fn = self._compiled.get(batch)
        if fn is None:
            if len(self._compiled) >= self.MAX_COMPILED:
                self._compiled.pop(next(iter(self._compiled)))
            fn = self._compiled[batch] = self._build(batch)
        self.last_report = self.report(batch)
        return fn(a, b)

    def describe(self) -> str:
        return (f"Bank[{self.plan.describe()}  backend={self.backend}  "
                f"{len(self.instances)} instances]")


# ------------------------------------------------------------------ module API

@functools.lru_cache(maxsize=64)
def _bank_for(plan: Plan, bits_a: int, bits_b: int, backend: str) -> Bank:
    return Bank(plan, bits_a, bits_b, backend=backend)


def execute(plan: Plan, a: jax.Array, b: jax.Array, *,
            backend: str = "core") -> jax.Array:
    """One-shot bank execution: dispatch a batch across ``plan``'s
    instances and return the (B, LA+LB) limb products.

    Operand bit widths are taken from the limb counts.  Banks are cached
    per (plan, widths, backend), so repeated calls re-use the compiled
    dispatch.  Use ``last_report(plan, a, b)`` -- or a ``Bank`` object
    directly -- for the cycle accounting.
    """
    la = a.shape[-1] if a.ndim > 1 else a.shape[0]
    lb = b.shape[-1] if b.ndim > 1 else b.shape[0]
    bank = _bank_for(plan, la * L.RADIX_BITS, lb * L.RADIX_BITS, backend)
    return bank.execute(a, b)


def last_report(plan: Plan, a: jax.Array, b: jax.Array, *,
                backend: str = "core") -> BankReport:
    """Cycle-accounting report for the batch shape of (a, b)."""
    la = a.shape[-1] if a.ndim > 1 else a.shape[0]
    lb = b.shape[-1] if b.ndim > 1 else b.shape[0]
    bank = _bank_for(plan, la * L.RADIX_BITS, lb * L.RADIX_BITS, backend)
    batch = a.shape[0] if a.ndim > 1 else 1
    return bank.report(batch)
