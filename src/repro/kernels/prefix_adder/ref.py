"""Oracle: the core library's sequential 1CA final adder."""
import jax

from repro.core.limbs import final_adder_1ca


def prefix_final_adder_ref(cols: jax.Array) -> jax.Array:
    return final_adder_1ca(cols)
