from .kernel import prefix_final_adder
from .ref import prefix_final_adder_ref
from .ops import fast_final_adder, launch_contract

__all__ = ["prefix_final_adder", "prefix_final_adder_ref",
           "fast_final_adder", "launch_contract"]
