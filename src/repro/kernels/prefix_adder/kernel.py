"""Pallas TPU kernel: parallel-prefix (Brent-Kung) final adder.

The paper's final-adder stage (1CA) is a carry-propagating addition;
its RoCoCo-style "fast adder" variants reduce the carry chain's depth.
The TPU-native analogue of a fast final adder: carry resolution in
log2(n_limbs) generate/propagate rounds instead of a sequential
n_limbs-step scan -- each round is one vectorized shift+combine over
the whole batch tile.

Input: carry-save column sums (uint32, radix 2^16); output: canonical
16-bit limbs.  Two phases:
  1. one local split pass reduces every column to (digit, local carry)
     with digit < 2^16 and carry < 2^16 -- after folding the carries in
     once, each limb holds < 2^17, so every subsequent carry-in is 0/1;
  2. Brent-Kung rounds on (generate, propagate) bits resolve all
     ripple carries in ceil(log2(width)) steps.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import limbs as L


def _adder_kernel(cols_ref, out_ref, *, width):
    cols = cols_ref[...]                          # (TB, W) uint32 columns
    # phase 1: fold high halves once; limbs now < 2^17
    digit = cols & L.MASK
    high = cols >> L.RADIX_BITS
    limb = digit.at[:, 1:].add(high[:, :-1])      # may reach 2^17 - 1

    # initial generate/propagate per limb position
    g = (limb >> L.RADIX_BITS).astype(jnp.uint32)   # carry-out regardless
    p = ((limb & L.MASK) == L.MASK).astype(jnp.uint32)  # propagates carry-in
    base = limb & L.MASK

    # phase 2: Kogge-Stone/Brent-Kung combine: (g,p) o (g',p')
    shift = 1
    gk, pk = g, p
    while shift < width:
        g_prev = jnp.pad(gk, ((0, 0), (shift, 0)))[:, :width]
        p_prev = jnp.pad(pk, ((0, 0), (shift, 0)))[:, :width]
        gk = gk | (pk & g_prev)
        pk = pk & p_prev
        shift *= 2
    # carry INTO position k = combined generate of positions < k
    carry_in = jnp.pad(gk, ((0, 0), (1, 0)))[:, :width]
    out_ref[...] = (base + carry_in) & L.MASK


@functools.partial(jax.jit, static_argnames=("tile_b", "interpret"))
def prefix_final_adder(cols: jax.Array, *, tile_b: int = 256,
                       interpret: bool = True) -> jax.Array:
    """(B, W) carry-save columns -> (B, W) canonical limbs (mod 2^16W).

    Valid for column sums < 2^32 - 2^16 (all MCIM producers satisfy
    this; see core.limbs overflow discipline).
    """
    bsz, width = cols.shape
    tile_b = min(tile_b, bsz)
    if bsz % tile_b:
        raise ValueError((bsz, tile_b))
    kernel = functools.partial(_adder_kernel, width=width)
    return pl.pallas_call(
        kernel,
        grid=(bsz // tile_b,),
        in_specs=[pl.BlockSpec((tile_b, width), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((tile_b, width), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((bsz, width), jnp.uint32),
        interpret=interpret,
    )(cols)
