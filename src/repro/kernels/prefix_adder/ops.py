"""Jitted wrapper for the Brent-Kung final adder kernel."""
import functools

import jax

from repro.kernels import runtime
from .kernel import prefix_final_adder
from .ref import prefix_final_adder_ref


def launch_contract(width: int, batch: int = 256):
    """Static :class:`~repro.kernels.introspect.LaunchContract`.

    One Brent-Kung final-adder launch over a ``batch`` of WIDTH-column
    carry-save rows, same tile rule as :func:`fast_final_adder`.  No
    scratch refs; declared working set is the in/out block pair.
    """
    import jax.numpy as jnp

    from repro.kernels.introspect import LaunchContract
    tile = next(t for t in (256, 128, 64, 32, 16, 8, 4, 2, 1)
                if batch % t == 0)
    cols = jax.ShapeDtypeStruct((batch, width), jnp.uint32)

    def fn(cv):
        return prefix_final_adder(cv, tile_b=tile, interpret=True)

    return LaunchContract(
        name=f"prefix_adder[width={width}]",
        fn=fn, args=(cols,),
        grid=(batch // tile,),
        scratch_shapes=(),
        vmem_model_bytes=tile * (width + width) * 4,
        meta={"tile_b": tile, "width": width})


@functools.partial(jax.jit, static_argnames=("use_kernel",))
def fast_final_adder(cols: jax.Array, use_kernel: bool = True):
    if not use_kernel:
        return prefix_final_adder_ref(cols)
    bsz = cols.shape[0]
    tile = next(t for t in (256, 128, 64, 32, 16, 8, 4, 2, 1)
                if bsz % t == 0)
    return prefix_final_adder(cols, tile_b=tile, interpret=runtime.interpret_mode())
