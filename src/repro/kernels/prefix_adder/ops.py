"""Jitted wrapper for the Brent-Kung final adder kernel."""
import functools

import jax

from repro.kernels import runtime
from .kernel import prefix_final_adder
from .ref import prefix_final_adder_ref


@functools.partial(jax.jit, static_argnames=("use_kernel",))
def fast_final_adder(cols: jax.Array, use_kernel: bool = True):
    if not use_kernel:
        return prefix_final_adder_ref(cols)
    bsz = cols.shape[0]
    tile = next(t for t in (256, 128, 64, 32, 16, 8, 4, 2, 1)
                if bsz % t == 0)
    return prefix_final_adder(cols, tile_b=tile, interpret=runtime.interpret_mode())
