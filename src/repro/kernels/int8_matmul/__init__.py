from .kernel import int8_matmul
from .ref import int8_matmul_ref
from .ops import quantized_matmul, quantize_rows, launch_contract

__all__ = ["int8_matmul", "int8_matmul_ref", "quantized_matmul",
           "quantize_rows", "launch_contract"]
