"""Public quantize/matmul/dequantize ops built on the int8 kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import runtime
from .kernel import int8_matmul
from .ref import int8_matmul_ref


def quantize_rows(x: jax.Array, axis: int = -1):
    """Symmetric per-row int8 quantization: returns (q, scale)."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=axis, keepdims=True)
    scale = jnp.where(amax == 0, 1.0, amax / 127.0)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127
                 ).astype(jnp.int8)
    return q, scale.squeeze(axis)


@functools.partial(jax.jit, static_argnames=("use_kernel", "block"))
def quantized_matmul(x: jax.Array, w: jax.Array, use_kernel: bool = True,
                     block: int = 128) -> jax.Array:
    """bf16/f32 (M,K) @ (K,N) through int8 with per-row/col scales."""
    qx, sx = quantize_rows(x, axis=1)          # per-row of x
    qw, sw = quantize_rows(w, axis=0)          # per-col of w
    m, k = qx.shape
    n = qw.shape[1]
    if use_kernel and m % min(block, m) == 0 and n % min(block, n) == 0 \
            and k % min(block, k) == 0:
        return int8_matmul(qx, qw, sx, sw, block_m=block, block_n=block,
                           block_k=block, interpret=runtime.interpret_mode())
    return int8_matmul_ref(qx, qw, sx, sw)
