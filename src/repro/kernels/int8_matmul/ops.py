"""Public quantize/matmul/dequantize ops built on the int8 kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import runtime
from .kernel import int8_matmul
from .ref import int8_matmul_ref


def launch_contract(m: int = 256, k: int = 512, n: int = 256,
                    block: int = 128):
    """Static :class:`~repro.kernels.introspect.LaunchContract`.

    One int8 matmul launch with the K dimension folded over
    ``k // block`` sequential grid steps -- the int32 scratch
    accumulator is the compressor the analyzer must prove
    init-before-read across the fold.
    """
    from repro.kernels.introspect import LaunchContract
    bm, bn, bk = min(block, m), min(block, n), min(block, k)
    if m % bm or n % bn or k % bk:
        raise ValueError(f"shape {(m, k, n)} not divisible by block {block}")
    x = jax.ShapeDtypeStruct((m, k), jnp.int8)
    w = jax.ShapeDtypeStruct((k, n), jnp.int8)
    sx = jax.ShapeDtypeStruct((m,), jnp.float32)
    sw = jax.ShapeDtypeStruct((n,), jnp.float32)

    def fn(xv, wv, sxv, swv):
        return int8_matmul(xv, wv, sxv, swv, block_m=bm, block_n=bn,
                           block_k=bk, interpret=True)

    in_bytes = bm * bk + bk * bn + bm * 4 + bn * 4
    return LaunchContract(
        name=f"int8_matmul[m={m},k={k},n={n},block={block}]",
        fn=fn, args=(x, w, sx, sw),
        grid=(m // bm, n // bn, k // bk),
        scratch_shapes=(((bm, bn), "int32"),),
        vmem_model_bytes=in_bytes + bm * bn * 4 + bm * bn * 2,
        meta={"blocks": (bm, bn, bk)})


def quantize_rows(x: jax.Array, axis: int = -1):
    """Symmetric per-row int8 quantization: returns (q, scale)."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=axis, keepdims=True)
    scale = jnp.where(amax == 0, 1.0, amax / 127.0)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127
                 ).astype(jnp.int8)
    return q, scale.squeeze(axis)


@functools.partial(jax.jit, static_argnames=("use_kernel", "block"))
def quantized_matmul(x: jax.Array, w: jax.Array, use_kernel: bool = True,
                     block: int = 128) -> jax.Array:
    """bf16/f32 (M,K) @ (K,N) through int8 with per-row/col scales."""
    qx, sx = quantize_rows(x, axis=1)          # per-row of x
    qw, sw = quantize_rows(w, axis=0)          # per-col of w
    m, k = qx.shape
    n = qw.shape[1]
    if use_kernel and m % min(block, m) == 0 and n % min(block, n) == 0 \
            and k % min(block, k) == 0:
        return int8_matmul(qx, qw, sx, sw, block_m=block, block_n=block,
                           block_k=block, interpret=runtime.interpret_mode())
    return int8_matmul_ref(qx, qw, sx, sw)
