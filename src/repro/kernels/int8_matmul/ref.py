"""Pure-jnp oracle for the int8 matmul kernel."""
import jax
import jax.numpy as jnp


def int8_matmul_ref(x: jax.Array, w: jax.Array, sx: jax.Array, sw: jax.Array,
                    out_dtype=jnp.bfloat16) -> jax.Array:
    acc = jnp.matmul(x.astype(jnp.int32), w.astype(jnp.int32))
    out = (acc.astype(jnp.float32)
           * sx.reshape(-1, 1).astype(jnp.float32)
           * sw.reshape(1, -1).astype(jnp.float32))
    return out.astype(out_dtype)
