"""Pallas TPU kernel: int8 x int8 -> bf16 matmul with folded K accumulation.

This is the MCIM idea applied to the matmul reduction dimension: the
MXU-tile product (the "PPM") is instantiated once and folded over
K/BLOCK_K sequential grid steps; the int32 VMEM accumulator plays the
compressor (carry-free accumulation); the dequantizing scale/add on the
final step is the final adder.  The per-step VMEM working set is
bm*bk + bk*bn + bm*bn instead of bm*K + K*bn + bm*bn -- the same
area-for-throughput fold as the paper's FB multiplier, with CT = K/bk.

Used by repro.quant for int8 serving matmuls and by the int8 gradient
compression path.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _matmul_kernel(x_ref, w_ref, sx_ref, sw_ref, out_ref, acc_ref, *, n_k):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # PPM + compressor: one MXU-tile pass, carry-free int32 accumulation.
    x = x_ref[...].astype(jnp.int32)     # (bm, bk) int8 widened in-regs
    w = w_ref[...].astype(jnp.int32)     # (bk, bn)
    acc_ref[...] += jax.lax.dot_general(
        x, w, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)

    # Final adder: dequantize once, on the last fold step.
    @pl.when(k == n_k - 1)
    def _finalize():
        sx = sx_ref[...]                 # (bm, 1) per-row scale
        sw = sw_ref[...]                 # (1, bn) per-col scale
        out_ref[...] = (acc_ref[...].astype(jnp.float32) * sx * sw
                        ).astype(out_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("block_m", "block_n", "block_k", "out_dtype", "interpret"))
def int8_matmul(x: jax.Array, w: jax.Array, sx: jax.Array, sw: jax.Array,
                *, block_m: int = 256, block_n: int = 256, block_k: int = 256,
                out_dtype=jnp.bfloat16, interpret: bool = True) -> jax.Array:
    """(M, K) int8 @ (K, N) int8 -> (M, N) out_dtype, with row/col scales.

    sx: (M,) float32 per-row (activation) scales
    sw: (N,) float32 per-col (weight) scales
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, (x.shape, w.shape)
    bm, bn, bk = min(block_m, m), min(block_n, n), min(block_k, k)
    if m % bm or n % bn or k % bk:
        raise ValueError(f"shape {(m, k, n)} not divisible by "
                         f"blocks {(bm, bk, bn)}")
    n_k = k // bk
    kernel = functools.partial(_matmul_kernel, n_k=n_k)
    return pl.pallas_call(
        kernel,
        grid=(m // bm, n // bn, n_k),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bm, 1), lambda i, j, kk: (i, 0)),
            pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        interpret=interpret,
    )(x, w, sx.reshape(m, 1).astype(jnp.float32),
      sw.reshape(1, n).astype(jnp.float32))
