"""Jitted wrapper with batch-tile selection for the Karatsuba PPM kernel."""
import functools

import jax

from repro.kernels import runtime
from .kernel import karatsuba_ppm_mul
from .ref import karatsuba_ppm_mul_ref


@functools.partial(jax.jit, static_argnames=("use_kernel",))
def kara_mul(a: jax.Array, b: jax.Array, use_kernel: bool = True):
    if not use_kernel:
        return karatsuba_ppm_mul_ref(a, b)
    bsz = a.shape[0]
    tile = next(t for t in (256, 128, 64, 32, 16, 8, 4, 2, 1)
                if bsz % t == 0)
    return karatsuba_ppm_mul(a, b, tile_b=tile, interpret=runtime.interpret_mode())
