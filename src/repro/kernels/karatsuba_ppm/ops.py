"""Jitted wrapper with batch-tile selection for the Karatsuba PPM kernel."""
import functools

import jax

from repro.kernels import runtime
from .kernel import karatsuba_ppm_mul
from .ref import karatsuba_ppm_mul_ref


def launch_contract(n: int, batch: int = 256):
    """Static :class:`~repro.kernels.introspect.LaunchContract`.

    One spatial-Karatsuba launch over a ``batch`` of (N, N) even-limb
    operands, with the same tile rule :func:`kara_mul` applies.  No
    scratch refs: the whole 10:2-compressor tree lives in registers,
    so its declared working set is the three I/O blocks.
    """
    import jax.numpy as jnp

    from repro.kernels.introspect import LaunchContract
    if n % 2:
        raise ValueError("even limb count required (pad first)")
    tile = next(t for t in (256, 128, 64, 32, 16, 8, 4, 2, 1)
                if batch % t == 0)
    a = jax.ShapeDtypeStruct((batch, n), jnp.uint32)

    def fn(av, bv):
        return karatsuba_ppm_mul(av, bv, tile_b=tile, interpret=True)

    return LaunchContract(
        name=f"karatsuba_ppm[n={n}]",
        fn=fn, args=(a, a),
        grid=(batch // tile,),
        scratch_shapes=(),
        vmem_model_bytes=tile * (n + n + 2 * n) * 4,
        meta={"tile_b": tile, "n": n})


@functools.partial(jax.jit, static_argnames=("use_kernel",))
def kara_mul(a: jax.Array, b: jax.Array, use_kernel: bool = True):
    if not use_kernel:
        return karatsuba_ppm_mul_ref(a, b)
    bsz = a.shape[0]
    tile = next(t for t in (256, 128, 64, 32, 16, 8, 4, 2, 1)
                if bsz % t == 0)
    return karatsuba_ppm_mul(a, b, tile_b=tile, interpret=runtime.interpret_mode())
