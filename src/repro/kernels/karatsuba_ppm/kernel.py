"""Pallas TPU kernel: combinational Karatsuba PPM (paper Fig. 4).

One Karatsuba level inside a single kernel invocation: the three
half-width partial products T0=A0*B0, T1=A1*B1, T2=(A0+A1)(B0+B1) are
computed from ONE half-width PPM routine (the fold is spatial here --
the hardware's combinational PPM -- while mcim_fold realizes the
temporal CT=3 fold), combined with the 10:2-compressor placement
pattern T1<<2h + (T2-T1-T0)<<h + T0 using the NOT+1 two's-complement
trick, and carry-propagated once.

Grid: (batch_tiles,).  Demonstrates the sub-quadratic limb-product
count on TPU: 3*(h+1)^2 lane multiplies instead of (2h)^2.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import limbs as L


def _ppm_cols(a, b, la, lb, width):
    """Half-width PPM: (TB, la) x (TB, lb) -> (TB, width) column sums."""
    acc = jnp.zeros((a.shape[0], width), jnp.uint32)
    for j in range(lb):
        p = a * b[:, j:j + 1]
        acc = acc.at[:, j:j + la].add(p & L.MASK)
        acc = acc.at[:, j + 1:j + la + 1].add(p >> L.RADIX_BITS)
    return acc


def _carry_propagate(cols, out_limbs):
    carry = jnp.zeros((cols.shape[0],), jnp.uint32)
    outs = []
    for k in range(out_limbs):
        tot = (cols[:, k] if k < cols.shape[1] else 0) + carry
        outs.append(tot & L.MASK)
        carry = tot >> L.RADIX_BITS
    return jnp.stack(outs, axis=1)


def _kara_kernel(a_ref, b_ref, out_ref, *, n, half):
    a = a_ref[...]                      # (TB, n) canonical limbs
    b = b_ref[...]
    tb = a.shape[0]
    width = 2 * n
    hp = half + 1                       # PPM port width (sum rows carry)

    a0, a1 = a[:, :half], a[:, half:]
    b0, b1 = b[:, :half], b[:, half:]
    # (A0+A1), (B0+B1) normalized to half+1 limbs
    sa = _carry_propagate(
        a0.astype(jnp.uint32) + a1.astype(jnp.uint32), hp)
    sb = _carry_propagate(
        b0.astype(jnp.uint32) + b1.astype(jnp.uint32), hp)

    # the three shared-PPM passes (T2 needs the hp-wide port)
    t0 = _carry_propagate(_ppm_cols(a0, b0, half, half, 2 * half),
                          2 * half)
    t1 = _carry_propagate(_ppm_cols(a1, b1, half, half, 2 * half),
                          2 * half)
    t2 = _carry_propagate(_ppm_cols(sa, sb, hp, hp, 2 * hp), 2 * hp)

    # 10:2-compressor placement: +T0, +T1<<2h, +T2<<h, -T0<<h, -T1<<h
    acc = jnp.zeros((tb, width), jnp.uint32)
    acc = acc.at[:, :2 * half].add(t0)
    acc = acc.at[:, 2 * half:].add(t1[:, :width - 2 * half])
    take2 = min(2 * hp, width - half)
    acc = acc.at[:, half:half + take2].add(t2[:, :take2])
    # two's complement of (T0 + T1) << h: NOT every column + 2
    neg = jnp.full((tb, width), jnp.uint32(2 * L.MASK), jnp.uint32)
    take1 = min(2 * half, width - half)
    neg = neg.at[:, half:half + take1].add(
        -(t0[:, :take1] + t1[:, :take1]))
    acc = acc + neg
    acc = acc.at[:, 0].add(2)           # +1 +1 for the two complements

    out_ref[...] = _carry_propagate(acc, width)


@functools.partial(jax.jit, static_argnames=("tile_b", "interpret"))
def karatsuba_ppm_mul(a: jax.Array, b: jax.Array, *, tile_b: int = 256,
                      interpret: bool = True) -> jax.Array:
    """Batched one-level Karatsuba multiply: (B, N) x (B, N) -> (B, 2N)."""
    bsz, n = a.shape
    assert b.shape == (bsz, n)
    assert n % 2 == 0, "even limb count required (pad first)"
    half = n // 2
    tile_b = min(tile_b, bsz)
    if bsz % tile_b:
        raise ValueError(f"batch {bsz} % tile {tile_b}")
    kernel = functools.partial(_kara_kernel, n=n, half=half)
    return pl.pallas_call(
        kernel,
        grid=(bsz // tile_b,),
        in_specs=[pl.BlockSpec((tile_b, n), lambda i: (i, 0)),
                  pl.BlockSpec((tile_b, n), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((tile_b, 2 * n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((bsz, 2 * n), jnp.uint32),
        interpret=interpret,
    )(a, b)
