from .kernel import karatsuba_ppm_mul
from .ref import karatsuba_ppm_mul_ref
from .ops import kara_mul, launch_contract

__all__ = ["karatsuba_ppm_mul", "karatsuba_ppm_mul_ref", "kara_mul",
           "launch_contract"]
