"""Oracle: the core library's one-level Karatsuba multiplier."""
import jax

from repro.core.karatsuba import karatsuba_mul


def karatsuba_ppm_mul_ref(a: jax.Array, b: jax.Array) -> jax.Array:
    return karatsuba_mul(a, b, levels=1, ct=3)
