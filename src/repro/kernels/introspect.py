"""Launch contracts: what each kernel package *declares* about a launch.

The static dataflow analyzer (:mod:`repro.verify.dataflow`) proves
hazard freedom, bounds, VMEM footprint and roofline numbers for every
Pallas launch in the tree.  It must not reverse-engineer grids, scratch
shapes or idle-step masks out of kernel plumbing -- the package that
builds a ``pallas_call`` owns those facts, so each package exposes a
``launch_contract(...)`` hook returning a :class:`LaunchContract`:

  fn / args         a traceable callable + abstract operands; tracing
                    it (no execution) yields the jaxpr whose single
                    ``pallas_call`` the analyzer interprets
  grid              the grid the package intends to launch
  scratch_shapes    (shape, dtype-name) per VMEM scratch ref
  vmem_model_bytes  the package's declared per-grid-step working set
                    (``vmem_bytes_per_step`` of its geometry module);
                    the analyzer checks the measured block bytes are
                    dominated by this model
  idle_steps        grid-step patterns that must be architectural
                    no-ops on scratch (fused-bank idle-mask padding);
                    ``None`` entries are wildcards over that grid dim
  table             the concrete scalar-prefetch table, if any -- the
                    analyzer evaluates SMEM reads against it and
                    bounds-checks every window

The analyzer then *verifies* the traced jaxpr against the declaration:
a package whose kernel drifts from its own contract fails verification
rather than silently analyzing the wrong launch.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Mapping, Optional


@dataclasses.dataclass(frozen=True)
class LaunchContract:
    """One kernel package's static declaration of one Pallas launch."""
    name: str                      # e.g. "mcim_fold/fb[la=2,lb=2,ct=2]"
    fn: Callable                   # positional callable over ``args``
    args: tuple                    # jax.ShapeDtypeStruct operands
    grid: tuple                    # declared launch grid
    scratch_shapes: tuple          # ((shape, dtype_name), ...) VMEM refs
    vmem_model_bytes: int          # declared per-step working set
    idle_steps: tuple = ()         # grid patterns (int | None per dim)
    table: Optional[Any] = None    # np.ndarray scalar-prefetch table
    meta: Mapping = dataclasses.field(default_factory=dict)

    def trace(self):
        """ClosedJaxpr of one ``fn(*args)`` call -- no execution."""
        import jax
        return jax.make_jaxpr(self.fn)(*self.args)

    def matches_idle(self, step: tuple) -> bool:
        """Whether ``step`` is declared architecturally idle."""
        return any(all(p is None or p == s for p, s in zip(pat, step))
                   for pat in self.idle_steps)
