"""Fused bank megakernel: a whole plan round in one Pallas launch."""
from .geometry import (FUSED_SCHEDULE, SuperGeometry, fused_ct,
                       fused_geometry, fused_windows, super_geometry,
                       vmem_bytes_per_step)
from .kernel import fused_bank_mul
from .ops import fused_block_rows, launch_contract, make_fused_dispatch

__all__ = [
    "FUSED_SCHEDULE", "SuperGeometry", "fused_ct", "fused_geometry",
    "fused_windows", "super_geometry", "vmem_bytes_per_step",
    "fused_bank_mul", "fused_block_rows", "launch_contract",
    "make_fused_dispatch",
]
