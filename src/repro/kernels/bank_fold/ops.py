"""Dispatch glue: one plan round -> one fused megakernel launch.

:func:`make_fused_dispatch` turns a scheduler assignment (which ops run
on which instance) into a pure closure ``run(a, b) -> products`` that

  1. gathers each instance's assigned operand rows into a padded
     ``(N_INST, R, L)`` block (static numpy indices -- jit lowers them
     to constant gathers),
  2. runs :func:`.kernel.fused_bank_mul` ONCE -- the whole bank round is
     a single ``pallas_call``,
  3. scatters the valid rows back to batch order, and
  4. for signed designs, applies the shared two's-complement correction
     pass (:func:`repro.core.mcim.signed_correction`) on the unsigned
     products -- pure jnp, so the round still costs one kernel launch.

Padding rows re-gather op 0's operands; their products are computed and
dropped (never scattered), which keeps every block rectangular without
data-dependent control flow.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.core import limbs as L
from repro.core.mcim import signed_correction
from repro.kernels import runtime
from repro.kernels.mcim_fold import batch_tile
from .geometry import super_geometry
from .kernel import fused_bank_mul


def fused_block_rows(assign) -> tuple:
    """(rows, tile_r) of the padded per-instance op blocks.

    ``rows`` is the per-instance row count after padding the largest
    assignment up to a :func:`batch_tile` multiple; ``tile_r`` the row
    tile the kernel grids over.
    """
    max_ops = max((len(ops) for ops in assign), default=0)
    max_ops = max(max_ops, 1)         # degenerate all-empty round
    tile_r, pad = batch_tile(max_ops)
    return max_ops + pad, tile_r


def launch_contract(configs, la: int, lb: int, rows: int = 64,
                    tile_r: int = None, table=None):
    """Static :class:`~repro.kernels.introspect.LaunchContract`.

    Declares the fused megakernel launch for a bank of ``configs``
    instances: the ``(row tile, instance, grid step)`` grid, the
    full-width scratch accumulator, the concrete SMEM window table and
    -- crucially -- which grid steps the super-geometry pads as *idle*
    (short-CT instances after their last real window), which the
    dataflow analyzer must prove are no-ops on scratch.

    ``table`` overrides the super-geometry's schedule table; the
    override flows into both the traced kernel and the declaration, so
    a corrupted table is analyzed exactly like a shipped one (this is
    how the property tests inject hazards).
    """
    import jax

    from repro.kernels.introspect import LaunchContract
    sg = super_geometry(configs, la, lb)
    if tile_r is None:
        tile_r, pad = batch_tile(rows)
        rows += pad
    n_inst = sg.n_instances
    a = jax.ShapeDtypeStruct((n_inst, rows, la), L.LIMB_DTYPE)
    b = jax.ShapeDtypeStruct((n_inst, rows, lb), L.LIMB_DTYPE)
    if table is None:
        table = sg.table()
    table = np.asarray(table, np.int32)
    tbl = jnp.asarray(table)
    max_steps = sg.max_steps

    def fn(av, bv):
        return fused_bank_mul(av, bv, tbl, max_steps=max_steps,
                              tile_r=tile_r, interpret=True)

    idle = tuple((None, i, j) for i, geo in enumerate(sg.rows)
                 for j in range(geo.ct_run, max_steps))
    from .geometry import vmem_bytes_per_step
    return LaunchContract(
        name=(f"bank_fold[la={la},lb={lb},n={n_inst},"
              f"steps={max_steps}]"),
        fn=fn, args=(a, b),
        grid=(rows // tile_r, n_inst, max_steps),
        scratch_shapes=(((tile_r, la + lb), "uint32"),),
        vmem_model_bytes=vmem_bytes_per_step(la, lb, tile_r, n_inst,
                                             max_steps),
        idle_steps=idle, table=table,
        meta={"super_geometry": sg, "tile_r": tile_r, "rows": rows})


def make_fused_dispatch(assign, configs, la: int, lb: int, batch: int, *,
                        signed: bool = False):
    """Build the one-launch dispatch closure for one (schedule, batch).

    ``assign`` is the scheduler's static assignment (tuple per instance
    of op indices into the batch), ``configs`` the flat instance list
    aligned with it.  The returned closure maps ``(B, LA) x (B, LB) ->
    (B, LA+LB)`` limb products, bit-exact vs the per-instance path.
    """
    sg = super_geometry(configs, la, lb)
    n_inst = sg.n_instances
    if len(assign) != n_inst:
        raise ValueError(
            f"assignment covers {len(assign)} instances, plan has {n_inst}")
    rows, tile_r = fused_block_rows(assign)

    # static gather: padded rows re-fetch op 0 (computed, never scattered)
    gather = np.zeros((n_inst, rows), np.int32)
    inst_ids, row_ids, op_ids = [], [], []
    for i, ops in enumerate(assign):
        for r, op in enumerate(ops):
            gather[i, r] = op
            inst_ids.append(i)
            row_ids.append(r)
            op_ids.append(op)
    inst_ids = np.asarray(inst_ids, np.int32)
    row_ids = np.asarray(row_ids, np.int32)
    op_ids = np.asarray(op_ids, np.int32)

    table = jnp.asarray(sg.table())
    max_steps = sg.max_steps
    interpret = runtime.interpret_mode()

    def run(a, b):
        a_blocks = a[gather]                   # (N_INST, R, LA)
        b_blocks = b[gather]                   # (N_INST, R, LB)
        prod = fused_bank_mul(a_blocks, b_blocks, table,
                              max_steps=max_steps, tile_r=tile_r,
                              interpret=interpret)
        out = jnp.zeros((batch, la + lb), L.LIMB_DTYPE)
        out = out.at[op_ids].set(prod[inst_ids, row_ids])
        if signed:
            out = signed_correction(a, b, out)
        return out

    return run
