"""Fused-bank super-geometry: one shape contract for a whole plan.

A ``planner.Plan`` is a *bank* of folded multiplier instances.  The
per-instance path realizes each instance as its own Pallas launch; the
fused megakernel (:mod:`.kernel`) instead flattens the whole bank round
into a single grid of ``(instance, grid_step)``.  This module owns the
static shape contract of that flattening, exactly the way
:func:`repro.kernels.mcim_fold.fold_geometry` owns the per-instance
contracts -- the kernel plumbing, the VMEM model and the static
verifier (:mod:`repro.verify.contracts`) all derive from here and can
never disagree.

The fused datapath is a *windowed schoolbook fold*: grid step ``j`` of
instance ``i`` masks the B operand to the limb window ``table[i, j]``
and accumulates ``ppm(A, B & window)`` carry-save columns into a
full-width accumulator (the B limbs sit at their absolute positions, so
no per-step shift is needed; the final carry pass runs once, on the
last grid step).  Each instance's window sequence is its
``fold_geometry`` row re-expressed for the shared datapath:

  star       1 window covering all of B         (CT = 1)
  fb / ff    CT windows of ceil(LB/CT) limbs    (the paper's fold)
  karatsuba  3 windows (its CT=3 temporal fold time-shares the fused
             datapath the same way it time-shares the silicon PPM)

Heterogeneous CTs meet in one launch by *masking idle grid steps*: the
super-geometry pads every instance to ``max_steps`` rows and assigns
idle steps the empty window ``(0, 0)``, which masks the whole B operand
to zero -- the step is architecturally a no-op, matching the silicon
bank where a short-CT instance idles while a long-CT neighbour drains.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import limbs as L
from repro.core.mcim import MCIMConfig
from repro.kernels.mcim_fold import FoldGeometry

#: schedule tag of every fused per-instance geometry row
FUSED_SCHEDULE = "fused"


def fused_ct(cfg: MCIMConfig) -> int:
    """Grid steps the fused datapath folds one instance over (its CT)."""
    if cfg.arch == "star":
        return 1
    if cfg.arch == "karatsuba":
        return 3
    return cfg.ct


def fused_geometry(cfg: MCIMConfig, la: int, lb: int) -> FoldGeometry:
    """One instance's row of the fused super-geometry.

    ``chunk``/``ct_run`` describe the instance's B-limb windows on the
    shared datapath; ``scratch_width``/``out_width`` are the full-width
    carry-save accumulator and retired product (every instance shares
    the same accumulator block, so both are ``LA + LB`` regardless of
    arch -- the fused analogue of the FF register file).
    """
    ct = fused_ct(cfg)
    chunk = -(-lb // ct)
    ct_run = -(-lb // chunk)          # CT > LB: trailing steps are idle
    return FoldGeometry(schedule=FUSED_SCHEDULE, la=la, lb=lb,
                        chunk=chunk, ct_run=ct_run,
                        scratch_width=la + lb, out_width=la + lb)


def fused_windows(cfg: MCIMConfig, la: int, lb: int) -> tuple:
    """Per-step (lo, hi) B-limb windows, clipped to the real LB limbs."""
    geo = fused_geometry(cfg, la, lb)
    return tuple((lo, min(hi, lb)) for lo, hi in geo.b_windows)


@dataclasses.dataclass(frozen=True)
class SuperGeometry:
    """Static contract of one fused bank launch.

    ``rows[i]`` is instance i's :func:`fused_geometry`; every row is
    padded to ``max_steps`` grid steps.  ``table()`` materializes the
    per-instance schedule table the kernel holds in SMEM-style scalar
    prefetch: ``table[i, j] = (lo, hi)`` is the B-limb window of
    instance i's step j, ``(0, 0)`` marking masked idle steps.
    """
    la: int
    lb: int
    configs: tuple            # flat tuple[MCIMConfig], one per instance
    rows: tuple               # tuple[FoldGeometry], aligned with configs
    max_steps: int            # padded grid-step count (max ct_run)
    scratch_width: int        # shared carry-save accumulator columns
    out_width: int            # retired product limbs

    @property
    def n_instances(self) -> int:
        return len(self.rows)

    def windows(self, i: int) -> tuple:
        """Instance i's windows padded with idle (0, 0) steps."""
        wins = tuple((lo, min(hi, self.lb))
                     for lo, hi in self.rows[i].b_windows)
        return wins + ((0, 0),) * (self.max_steps - len(wins))

    def table(self) -> np.ndarray:
        """(n_instances, max_steps, 2) int32 schedule table."""
        tbl = np.zeros((self.n_instances, self.max_steps, 2), np.int32)
        for i in range(self.n_instances):
            for j, (lo, hi) in enumerate(self.windows(i)):
                tbl[i, j] = (lo, hi)
        return tbl


def super_geometry(configs, la: int, lb: int) -> SuperGeometry:
    """Fused super-geometry of a flat instance list.

    Raises ``ValueError`` for an empty bank -- a fused launch needs at
    least one instance row.
    """
    configs = tuple(configs)
    if not configs:
        raise ValueError("fused bank needs at least one instance")
    rows = tuple(fused_geometry(cfg, la, lb) for cfg in configs)
    return SuperGeometry(
        la=la, lb=lb, configs=configs, rows=rows,
        max_steps=max(geo.ct_run for geo in rows),
        scratch_width=la + lb, out_width=la + lb)


def vmem_bytes_per_step(la: int, lb: int, tile_r: int,
                        n_instances: int = 1, max_steps: int = 1) -> int:
    """Per-grid-step VMEM working set of the fused datapath.

    One instance's blocks are live per step -- A tile, B tile, the
    full-width accumulator and the output tile -- plus the whole SMEM
    schedule table (scalars, prefetched once).  Because the instances
    time-share this one datapath, the figure does NOT scale with the
    instance count: that is the fused analogue of the paper's folded
    silicon area.
    """
    words = tile_r * (la                    # A tile
                      + lb                  # B tile (masked per step)
                      + (la + lb)           # carry-save accumulator
                      + (la + lb))          # output tile
    return words * 4 + n_instances * max_steps * 2 * 4
