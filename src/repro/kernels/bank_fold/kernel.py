"""Pallas TPU megakernel: a whole bank round in ONE launch.

``Bank.execute`` used to issue one ``pallas_call`` per instance per
round -- a TP=3.5 plan (3 Star + 1 CT=2 FB) paid 4 launches per cycle
where the paper's folded silicon is a single clocked datapath.  Folding
theory (Möller et al., "Model-based Hardware Design for FPGAs using
Folding Transformations") says a resource-shared schedule should
compile to *one* time-multiplexed circuit; this kernel is that circuit
for the TPU: the plan's static schedule flattened into a single Pallas
grid of ``(row_tile, instance, grid_step)``.

Structure per grid step (see :mod:`.geometry` for the shape contract):

  schedule table -> SMEM scalar prefetch: ``(lo, hi)`` B-limb window of
                    (instance, step); ``(0, 0)`` masks idle steps of
                    short-CT instances (the heterogeneity handling)
  PPM            -> static limb loop of 16x16->32 lane products over
                    the *masked* B operand -- limbs sit at absolute
                    positions, so columns land at their final weights
                    without any per-step shift
  compressor     -> full-width uint32 carry-save accumulator in VMEM
                    scratch (the fused analogue of the FF register
                    file), carries deferred
  final adder    -> one carry-propagation pass on the last grid step,
                    retiring the whole LA+LB product

Grid dimensions 1 and 2 are sequential on TPU: instances stream through
the same datapath one after another, each folding over its own CT
windows -- many multiplier instances share one circuit, which is the
fused generalization of the paper's resource-sharing use case.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import limbs as L


def _bank_kernel(tbl_ref, a_ref, b_ref, out_ref, acc_ref, *,
                 la, lb, max_steps):
    """One grid step = one clock cycle of one instance's folded pass."""
    i = pl.program_id(1)                    # instance index
    j = pl.program_id(2)                    # grid step within the fold
    lo = tbl_ref[i, j, 0]                   # this step's B-limb window
    hi = tbl_ref[i, j, 1]                   # (lo == hi: masked idle step)
    a = a_ref[0]                            # (TR, LA) canonical limbs
    b = b_ref[0]                            # (TR, LB) canonical limbs

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # ---- window mask: idle steps and out-of-window limbs contribute 0 ----
    limb = jax.lax.broadcasted_iota(jnp.int32, (1, lb), 1)
    mask = ((limb >= lo) & (limb < hi)).astype(jnp.uint32)
    bm = b * mask

    # ---- PPM + compressor: masked column sums, carries deferred ---------
    # Static loop over B limbs; each iteration is one vector multiply
    # over the row tile (one "row" of the shared hardware PPM array).
    acc = acc_ref[...]
    for jj in range(lb):
        p = a * bm[:, jj:jj + 1]                          # exact 16x16 in u32
        acc = acc.at[:, jj:jj + la].add(p & L.MASK)
        acc = acc.at[:, jj + 1:jj + la + 1].add(p >> L.RADIX_BITS)
    acc_ref[...] = acc

    # ---- last step: single final-adder pass retires the product ---------
    @pl.when(j == max_steps - 1)
    def _finish():
        cols = acc_ref[...]
        carry = jnp.zeros((a.shape[0],), jnp.uint32)
        norm = []
        for k in range(la + lb):
            tot = cols[:, k] + carry
            norm.append(tot & L.MASK)
            carry = tot >> L.RADIX_BITS
        out_ref[0] = jnp.stack(norm, axis=1)


@functools.partial(jax.jit,
                   static_argnames=("max_steps", "tile_r", "interpret"))
def fused_bank_mul(a_blocks: jax.Array, b_blocks: jax.Array,
                   table: jax.Array, *, max_steps: int, tile_r: int,
                   interpret: bool = True) -> jax.Array:
    """One launch: (N_INST, R, LA) x (N_INST, R, LB) -> (N_INST, R, LA+LB).

    ``table`` is the (N_INST, max_steps, 2) int32 schedule table from
    :meth:`.geometry.SuperGeometry.table`, prefetched into SMEM so the
    kernel body reads its window scalars before touching VMEM.  ``R``
    must be divisible by ``tile_r``; rows are independent
    multiplications (an instance's assigned ops, padded), so row tiles
    stream through the same folded datapath.
    """
    n_inst, rows, la = a_blocks.shape
    lb = b_blocks.shape[-1]
    if rows % tile_r:
        raise ValueError(f"rows {rows} not divisible by tile {tile_r}")
    if table.shape != (n_inst, max_steps, 2):
        raise ValueError(f"schedule table {table.shape} does not match "
                         f"{(n_inst, max_steps, 2)}")
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(rows // tile_r, n_inst, max_steps),
        in_specs=[
            pl.BlockSpec((1, tile_r, la), lambda r, i, j, tbl: (i, r, 0)),
            pl.BlockSpec((1, tile_r, lb), lambda r, i, j, tbl: (i, r, 0)),
        ],
        out_specs=pl.BlockSpec((1, tile_r, la + lb),
                               lambda r, i, j, tbl: (i, r, 0)),
        scratch_shapes=[pltpu.VMEM((tile_r, la + lb), jnp.uint32)],
    )
    kernel = functools.partial(_bank_kernel, la=la, lb=lb,
                               max_steps=max_steps)
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_inst, rows, la + lb), jnp.uint32),
        interpret=interpret,
    )(table, a_blocks, b_blocks)
