"""Pallas TPU kernel: batched multi-cycle folded big-integer multiply.

TPU adaptation of the paper's Feedback (FB) architecture (Fig. 1).  The
hardware folds one M x (N/CT) PPM over CT clock cycles; the TPU kernel
folds one (TILE_B, LA) x (TILE_B, CHUNK) limb-product pass over CT grid
steps.  The mapping of hardware stages to kernel structure:

  PPM          -> static limb-loop of 16x16->32 lane products (VPU ops,
                  TILE_B integers per vector op)
  compressor   -> uint32 column-sum accumulator in VMEM scratch,
                  carries deferred (carry-save)
  final adder  -> static carry-propagation loop, run once per grid step
                  over the (LA + CHUNK + 1)-limb window (the paper's
                  M + N/CT adder), retiring CHUNK limbs per step

"Area" in hardware corresponds to the *per-step VMEM working set* here:
it scales with LA + LB/CT instead of LA + LB, so CT folds the footprint
exactly the way the silicon PPM is folded.  Grid dimension 1 (the cycle
axis) is sequential on TPU, which is what lets the scratch accumulator
play the role of the feedback register.

The grid is (batch_tiles, CT): batch tiles stream through the same
folded datapath, i.e. many independent multiplications share one
"multiplier instance", the paper's resource-sharing use case.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# single source of truth for MASK / RADIX_BITS / LIMB_DTYPE: core.limbs
# (the verifier's interval bounds are authoritative only because every
# kernel shares the core constants instead of re-declaring them)
from repro.core import limbs as L


@dataclasses.dataclass(frozen=True)
class FoldGeometry:
    """Static shape contract of one folded schedule.

    Derived in exactly one place so the kernel plumbing, the VMEM
    area model (:func:`.ops.vmem_bytes_per_step`) and the static
    verifier (:mod:`repro.verify.contracts`) can never disagree.
    """
    schedule: str       # fb | ff | karatsuba
    la: int             # A limbs
    lb: int             # B limbs
    chunk: int          # B limbs consumed per grid cycle
    ct_run: int         # grid cycles actually folded (<= requested CT)
    scratch_width: int  # VMEM accumulator columns
    out_width: int      # retired product limbs

    @property
    def b_windows(self) -> tuple:
        """Per-cycle (lo, hi) B-limb windows the PPM consumes (fb/ff)."""
        return tuple((t * self.chunk, (t + 1) * self.chunk)
                     for t in range(self.ct_run))


def fold_geometry(la: int, lb: int, ct: int,
                  schedule: str = "fb") -> FoldGeometry:
    """Static geometry of a folded schedule for (LA, LB) limb operands."""
    if schedule == "karatsuba":
        if ct != 3:
            raise ValueError("the folded Karatsuba schedule is fixed to CT=3")
        n = max(la, lb)
        n += n % 2                               # even split point
        return FoldGeometry(schedule=schedule, la=la, lb=lb,
                            chunk=n // 2 + 1, ct_run=3,
                            scratch_width=2 * n, out_width=la + lb)
    if schedule not in ("fb", "ff"):
        raise ValueError(f"schedule must be fb, ff or karatsuba, "
                         f"got {schedule!r}")
    chunk = -(-lb // ct)
    # CT > LB leaves trailing all-zero chunks: fold only the LB real
    # limbs (the silicon would idle those cycles; the extra cycles exist
    # in the throughput accounting, not in the datapath).
    ct_run = -(-lb // chunk)
    if schedule == "fb":
        scratch = la + chunk + 1                 # M + N/CT folded window
    else:
        scratch = la + ct_run * chunk + 1        # full FF register file
    return FoldGeometry(schedule=schedule, la=la, lb=lb, chunk=chunk,
                        ct_run=ct_run, scratch_width=scratch,
                        out_width=la + lb)


def _fb_kernel(a_ref, b_ref, out_ref, acc_ref, *, la, lb, ct, chunk):
    """One grid step = one MCIM clock cycle for a tile of multiplications."""
    j = pl.program_id(1)                       # cycle index within CT
    width = la + chunk + 1                     # M + N/CT (+carry) window

    a = a_ref[...]                             # (TB, LA) canonical limbs
    b = b_ref[...]                             # (TB, CHUNK) this cycle's chunk

    # ---- feedback shift: acc <- acc >> CHUNK limbs (cycle 0: acc = 0) ----
    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(j != 0)
    def _shift():
        shifted = jnp.concatenate(
            [acc_ref[:, chunk:],
             jnp.zeros((a.shape[0], chunk), jnp.uint32)], axis=1)
        acc_ref[...] = shifted

    # ---- PPM + compressor: column sums, carries deferred ----------------
    # Static loop over the chunk's limbs; every iteration is one vector
    # multiply over the batch tile (the "row" of the hardware PPM array).
    acc = acc_ref[...]
    for jj in range(chunk):
        p = a * b[:, jj:jj + 1]                           # exact 16x16 in u32
        lo = p & L.MASK
        hi = p >> L.RADIX_BITS
        acc = acc.at[:, jj:jj + la].add(lo)
        acc = acc.at[:, jj + 1:jj + la + 1].add(hi)

    # ---- final adder (1CA): carry-propagate the M+N/CT window -----------
    carry = jnp.zeros((a.shape[0],), jnp.uint32)
    norm = []
    for k in range(width):
        tot = acc[:, k] + carry
        norm.append(tot & L.MASK)
        carry = tot >> L.RADIX_BITS
    normalized = jnp.stack(norm, axis=1)
    acc_ref[...] = normalized

    # ---- retire CHUNK low limbs into the output tile ---------------------
    out_ref[:, pl.dslice(j * chunk, chunk)] = normalized[:, :chunk]

    # ---- last cycle: the remaining high limbs complete the product -------
    @pl.when(j == ct - 1)
    def _tail():
        tail_limbs = la + lb - ct * chunk            # may be < la+1 (padding)
        if tail_limbs > 0:
            out_ref[:, pl.dslice(ct * chunk, tail_limbs)] = \
                normalized[:, chunk:chunk + tail_limbs]


def _ff_kernel(a_ref, b_ref, out_ref, acc_ref, *, la, lb, ct, chunk):
    """Feed-Forward (FF) schedule, paper Fig. 2.

    No feedback shift: every grid step runs the shared PPM over this
    cycle's B chunk and adds the carry-save columns into a *full-width*
    accumulator at limb offset j*chunk (the "register file" holding all
    CT partial results).  One final adder pass retires the whole product
    on the last cycle.  The working set is the full LA+LB window --
    exactly the paper's FF area trade: no feedback loop (pipelineable,
    any final adder) in exchange for CT-fold register growth.
    """
    j = pl.program_id(1)                       # cycle index within CT
    width = la + ct * chunk + 1

    a = a_ref[...]                             # (TB, LA) canonical limbs
    b = b_ref[...]                             # (TB, CHUNK) this cycle's chunk

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # ---- shared PPM: carry-save columns of a * b_chunk ------------------
    cols = jnp.zeros((a.shape[0], la + chunk + 1), jnp.uint32)
    for jj in range(chunk):
        p = a * b[:, jj:jj + 1]                           # exact 16x16 in u32
        cols = cols.at[:, jj:jj + la].add(p & L.MASK)
        cols = cols.at[:, jj + 1:jj + la + 1].add(p >> L.RADIX_BITS)

    # ---- 2*CT:2 compressor: add into the register file at j*chunk -------
    window = acc_ref[:, pl.dslice(j * chunk, la + chunk + 1)]
    acc_ref[:, pl.dslice(j * chunk, la + chunk + 1)] = window + cols

    # ---- last cycle: single final-adder pass over the full width --------
    @pl.when(j == ct - 1)
    def _finish():
        acc = acc_ref[...]
        carry = jnp.zeros((a.shape[0],), jnp.uint32)
        norm = []
        for k in range(la + lb):
            tot = (acc[:, k] if k < width else jnp.zeros_like(carry)) + carry
            norm.append(tot & L.MASK)
            carry = tot >> L.RADIX_BITS
        out_ref[...] = jnp.stack(norm, axis=1)


def _kara_carry(cols, out_limbs):
    """Carry-propagate ``out_limbs`` canonical limbs out of column sums."""
    carry = jnp.zeros((cols.shape[0],), jnp.uint32)
    outs = []
    for k in range(out_limbs):
        tot = (cols[:, k] if k < cols.shape[1]
               else jnp.zeros_like(carry)) + carry
        outs.append(tot & L.MASK)
        carry = tot >> L.RADIX_BITS
    return jnp.stack(outs, axis=1)


def _kara_kernel(a_ref, b_ref, out_ref, acc_ref, *, la, lb, n, half):
    """Folded Karatsuba schedule (paper Fig. 3), CT=3: the temporal fold.

    One *shared* half-width PPM runs once per grid step on the cycle's
    operand pair -- cycle 0: (A0, B0) -> T0, cycle 1: (A1, B1) -> T1,
    cycle 2: (A0+A1, B0+B1) -> T2 -- and a compressor feedback loop
    accumulates the placed/complemented terms

        P = T0 + T1<<2h + (T2 - T1 - T0)<<h

    in the VMEM scratch accumulator (subtractions as NOT+1 columns, the
    2**(16*width) wraps vanishing in the final truncation).  The final
    adder runs once, on the last cycle -- in contrast to
    ``karatsuba_ppm`` (the *spatial* fold: three PPMs in one step), this
    kernel keeps exactly one PPM's worth of compute live per step, the
    TPU analogue of the paper's shared-PPM silicon.
    """
    j = pl.program_id(1)                       # Karatsuba cycle, 0..2
    hp = half + 1                              # shared-PPM port width
    width = 2 * n
    a = a_ref[...]                             # (TB, n) padded canonical limbs
    b = b_ref[...]
    tb = a.shape[0]
    zero_col = jnp.zeros((tb, 1), jnp.uint32)

    a0, a1 = a[:, :half], a[:, half:]
    b0, b1 = b[:, :half], b[:, half:]
    sa = _kara_carry(a0 + a1, hp)              # A0+A1, normalized to hp limbs
    sb = _kara_carry(b0 + b1, hp)
    a0p = jnp.concatenate([a0, zero_col], axis=1)
    a1p = jnp.concatenate([a1, zero_col], axis=1)
    b0p = jnp.concatenate([b0, zero_col], axis=1)
    b1p = jnp.concatenate([b1, zero_col], axis=1)

    # this cycle's operands for the ONE shared PPM
    av = jnp.where(j == 0, a0p, jnp.where(j == 1, a1p, sa))
    bv = jnp.where(j == 0, b0p, jnp.where(j == 1, b1p, sb))

    # shared PPM + its 1CA: T_j normalized to 2*hp canonical limbs
    cols = jnp.zeros((tb, 2 * hp), jnp.uint32)
    for jj in range(hp):
        p = av * bv[:, jj:jj + 1]                         # exact 16x16 in u32
        cols = cols.at[:, jj:jj + hp].add(p & L.MASK)
        cols = cols.at[:, jj + 1:jj + hp + 1].add(p >> L.RADIX_BITS)
    t = _kara_carry(cols, 2 * hp)

    def place(shift):
        # jnp.pad, not .at[].add: a full-width scatter would close over an
        # empty index constant, which pallas_call rejects
        take = min(2 * hp, width - shift)
        return jnp.pad(t[:, :take], ((0, 0), (shift, width - shift - take)))

    def neg_place(shift):
        # NOT+1 two's complement of (T_j << shift) mod 2**(16*width);
        # the +1 is returned as a separate column-0 increment
        inv = jnp.full((tb, width), jnp.uint32(L.MASK)) - place(shift)
        return inv.at[:, 0].add(1)

    # compressor feedback: accumulate this cycle's placed terms
    @pl.when(j == 0)
    def _t0():                                 # +T0<<0  -T0<<h
        acc_ref[...] = place(0) + neg_place(half)

    @pl.when(j == 1)
    def _t1():                                 # +T1<<2h -T1<<h
        acc_ref[...] = acc_ref[...] + place(2 * half) + neg_place(half)

    # last cycle: +T2<<h, then the single final-adder pass
    @pl.when(j == 2)
    def _t2():
        acc = acc_ref[...] + place(half)
        out_ref[...] = _kara_carry(acc, la + lb)


def _kara_fold_call(a, b, tile_b, interpret):
    """pallas_call plumbing for the folded Karatsuba CT=3 schedule."""
    bsz, la = a.shape
    lb = b.shape[-1]
    geo = fold_geometry(la, lb, 3, "karatsuba")
    n = geo.scratch_width // 2                  # operands padded even
    a = jnp.pad(a, ((0, 0), (0, n - la)))
    b = jnp.pad(b, ((0, 0), (0, n - lb)))
    kernel = functools.partial(_kara_kernel, la=la, lb=lb, n=n, half=n // 2)
    return pl.pallas_call(
        kernel,
        grid=(bsz // tile_b, geo.ct_run),
        in_specs=[
            pl.BlockSpec((tile_b, n), lambda i, j: (i, 0)),
            pl.BlockSpec((tile_b, n), lambda i, j: (i, 0)),
        ],
        out_specs=pl.BlockSpec((tile_b, geo.out_width), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((bsz, geo.out_width), jnp.uint32),
        scratch_shapes=[pltpu.VMEM((tile_b, geo.scratch_width), jnp.uint32)],
        interpret=interpret,
    )(a, b)


@functools.partial(jax.jit,
                   static_argnames=("ct", "tile_b", "schedule", "interpret"))
def mcim_fold_mul(a: jax.Array, b: jax.Array, *, ct: int = 2,
                  tile_b: int = 256, schedule: str = "fb",
                  interpret: bool = True) -> jax.Array:
    """Batched folded multiply: (B, LA) x (B, LB) -> (B, LA+LB) limbs.

    ``schedule`` picks the paper architecture: "fb" (feedback loop,
    1/CT-width accumulator), "ff" (feed-forward register file, single
    final adder) or "karatsuba" (shared sub-PPM over the fixed CT=3
    Karatsuba fold).  For fb/ff any CT >= 1 folds; the planner emits CT
    in {1, 2, 3, 4, 6} (+8, 12 for deep fractional combinations).

    interpret=True runs the kernel body on CPU for validation; on a real
    TPU pass interpret=False.
    """
    if schedule not in ("fb", "ff", "karatsuba"):
        raise ValueError(
            f"schedule must be fb, ff or karatsuba, got {schedule!r}")
    if schedule == "karatsuba":
        if ct != 3:
            raise ValueError("the folded Karatsuba schedule is fixed to CT=3")
        bsz = a.shape[0]
        tile_b = min(tile_b, bsz)
        if bsz % tile_b:
            raise ValueError(f"batch {bsz} not divisible by tile {tile_b}")
        return _kara_fold_call(a, b, tile_b, interpret)
    if schedule == "ff" and ct < 2:
        raise ValueError("FF is a multi-cycle design: ct >= 2")
    bsz, la = a.shape
    lb = b.shape[-1]
    geo = fold_geometry(la, lb, ct, schedule)
    chunk, ct_run = geo.chunk, geo.ct_run
    b = jnp.pad(b, ((0, 0), (0, chunk * ct_run - lb)))
    tile_b = min(tile_b, bsz)
    if bsz % tile_b:
        raise ValueError(f"batch {bsz} not divisible by tile {tile_b}")

    body = _fb_kernel if schedule == "fb" else _ff_kernel
    kernel = functools.partial(body, la=la, lb=lb, ct=ct_run, chunk=chunk)
    return pl.pallas_call(
        kernel,
        grid=(bsz // tile_b, ct_run),
        in_specs=[
            pl.BlockSpec((tile_b, la), lambda i, j: (i, 0)),
            pl.BlockSpec((tile_b, chunk), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((tile_b, geo.out_width), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((bsz, geo.out_width), jnp.uint32),
        scratch_shapes=[pltpu.VMEM((tile_b, geo.scratch_width), jnp.uint32)],
        interpret=interpret,
    )(a, b)
