"""Pallas TPU kernel: batched multi-cycle folded big-integer multiply.

TPU adaptation of the paper's Feedback (FB) architecture (Fig. 1).  The
hardware folds one M x (N/CT) PPM over CT clock cycles; the TPU kernel
folds one (TILE_B, LA) x (TILE_B, CHUNK) limb-product pass over CT grid
steps.  The mapping of hardware stages to kernel structure:

  PPM          -> static limb-loop of 16x16->32 lane products (VPU ops,
                  TILE_B integers per vector op)
  compressor   -> uint32 column-sum accumulator in VMEM scratch,
                  carries deferred (carry-save)
  final adder  -> static carry-propagation loop, run once per grid step
                  over the (LA + CHUNK + 1)-limb window (the paper's
                  M + N/CT adder), retiring CHUNK limbs per step

"Area" in hardware corresponds to the *per-step VMEM working set* here:
it scales with LA + LB/CT instead of LA + LB, so CT folds the footprint
exactly the way the silicon PPM is folded.  Grid dimension 1 (the cycle
axis) is sequential on TPU, which is what lets the scratch accumulator
play the role of the feedback register.

The grid is (batch_tiles, CT): batch tiles stream through the same
folded datapath, i.e. many independent multiplications share one
"multiplier instance", the paper's resource-sharing use case.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import limbs as L

MASK = L.MASK
RADIX_BITS = L.RADIX_BITS


def _fb_kernel(a_ref, b_ref, out_ref, acc_ref, *, la, lb, ct, chunk):
    """One grid step = one MCIM clock cycle for a tile of multiplications."""
    j = pl.program_id(1)                       # cycle index within CT
    width = la + chunk + 1                     # M + N/CT (+carry) window

    a = a_ref[...]                             # (TB, LA) canonical limbs
    b = b_ref[...]                             # (TB, CHUNK) this cycle's chunk

    # ---- feedback shift: acc <- acc >> CHUNK limbs (cycle 0: acc = 0) ----
    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(j != 0)
    def _shift():
        shifted = jnp.concatenate(
            [acc_ref[:, chunk:],
             jnp.zeros((a.shape[0], chunk), jnp.uint32)], axis=1)
        acc_ref[...] = shifted

    # ---- PPM + compressor: column sums, carries deferred ----------------
    # Static loop over the chunk's limbs; every iteration is one vector
    # multiply over the batch tile (the "row" of the hardware PPM array).
    acc = acc_ref[...]
    for jj in range(chunk):
        p = a * b[:, jj:jj + 1]                           # exact 16x16 in u32
        lo = p & MASK
        hi = p >> RADIX_BITS
        acc = acc.at[:, jj:jj + la].add(lo)
        acc = acc.at[:, jj + 1:jj + la + 1].add(hi)

    # ---- final adder (1CA): carry-propagate the M+N/CT window -----------
    carry = jnp.zeros((a.shape[0],), jnp.uint32)
    norm = []
    for k in range(width):
        tot = acc[:, k] + carry
        norm.append(tot & MASK)
        carry = tot >> RADIX_BITS
    normalized = jnp.stack(norm, axis=1)
    acc_ref[...] = normalized

    # ---- retire CHUNK low limbs into the output tile ---------------------
    out_ref[:, pl.dslice(j * chunk, chunk)] = normalized[:, :chunk]

    # ---- last cycle: the remaining high limbs complete the product -------
    @pl.when(j == ct - 1)
    def _tail():
        tail_limbs = la + lb - ct * chunk            # may be < la+1 (padding)
        if tail_limbs > 0:
            out_ref[:, pl.dslice(ct * chunk, tail_limbs)] = \
                normalized[:, chunk:chunk + tail_limbs]


@functools.partial(jax.jit, static_argnames=("ct", "tile_b", "interpret"))
def mcim_fold_mul(a: jax.Array, b: jax.Array, *, ct: int = 2,
                  tile_b: int = 256, interpret: bool = True) -> jax.Array:
    """Batched folded multiply: (B, LA) x (B, LB) -> (B, LA+LB) limbs.

    interpret=True runs the kernel body on CPU for validation; on a real
    TPU pass interpret=False.
    """
    bsz, la = a.shape
    lb = b.shape[-1]
    chunk = -(-lb // ct)
    b = jnp.pad(b, ((0, 0), (0, chunk * ct - lb)))
    tile_b = min(tile_b, bsz)
    if bsz % tile_b:
        raise ValueError(f"batch {bsz} not divisible by tile {tile_b}")

    kernel = functools.partial(_fb_kernel, la=la, lb=lb, ct=ct, chunk=chunk)
    return pl.pallas_call(
        kernel,
        grid=(bsz // tile_b, ct),
        in_specs=[
            pl.BlockSpec((tile_b, la), lambda i, j: (i, 0)),
            pl.BlockSpec((tile_b, chunk), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((tile_b, la + lb), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((bsz, la + lb), jnp.uint32),
        scratch_shapes=[pltpu.VMEM((tile_b, la + chunk + 1), jnp.uint32)],
        interpret=interpret,
    )(a, b)
