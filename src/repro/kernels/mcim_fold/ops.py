"""Jitted public wrappers for the folded big-int multiply kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import limbs as L
from repro.kernels import runtime
from .kernel import mcim_fold_mul, fold_geometry
from .ref import mcim_fold_mul_ref

_TILES = (512, 256, 128, 64, 32, 16, 8)


def batch_tile(bsz: int) -> tuple:
    """Pick (tile, pad) for a batch of ``bsz`` multiplications.

    Prefer the largest candidate tile that divides the batch exactly.
    Awkward batch sizes (e.g. a large prime, which has no candidate
    divisor at all) used to degenerate into 1-row tiles -- thousands of
    grid steps and per-step VMEM estimates scaled to the full batch.
    Instead, pad the batch up to the nearest multiple of a candidate
    tile wasting at most ~12.5% rows, and let the caller slice the
    result back to ``bsz``; batches too small for any bounded-waste pad
    run as one exact short tile.
    """
    for cand in _TILES:
        if bsz % cand == 0:
            return cand, 0
    for cand in _TILES:
        pad = -bsz % cand
        if cand <= 2 * bsz and pad * 8 <= bsz:
            return cand, pad
    # no bounded-waste candidate: only reachable for bsz < 56 (an 8-row
    # tile pads at most 7 rows), where one exact short tile is cheapest
    return bsz, 0


@functools.partial(jax.jit, static_argnames=("ct", "schedule", "use_kernel"))
def big_mul(a: jax.Array, b: jax.Array, ct: int = 2, schedule: str = "fb",
            use_kernel: bool = True) -> jax.Array:
    """Batched wide-int multiply with automatic batch-tile selection."""
    if a.ndim == 1:
        a, b = a[None], b[None]
        return big_mul(a, b, ct=ct, schedule=schedule,
                       use_kernel=use_kernel)[0]
    bsz = a.shape[0]
    if not use_kernel:
        return mcim_fold_mul_ref(a, b, ct=ct, schedule=schedule)
    tile, pad = batch_tile(bsz)
    if pad:
        a = jnp.pad(a, ((0, pad), (0, 0)))
        b = jnp.pad(b, ((0, pad), (0, 0)))
    out = mcim_fold_mul(a, b, ct=ct, tile_b=tile, schedule=schedule,
                        interpret=runtime.interpret_mode())
    return out[:bsz] if pad else out


def launch_contract(la: int, lb: int, ct: int, schedule: str = "fb",
                    batch: int = 256):
    """Static :class:`~repro.kernels.introspect.LaunchContract`.

    Declares the grid/scratch/VMEM contract of the launch ``big_mul``
    would issue for a ``batch`` of (LA, LB) multiplications, so the
    dataflow analyzer verifies the same tiling the dispatch path uses
    instead of reverse-engineering it.
    """
    from repro.kernels.introspect import LaunchContract
    run_ct = 3 if schedule == "karatsuba" else ct
    geo = fold_geometry(la, lb, run_ct, schedule)
    tile, pad = batch_tile(batch)
    bsz = batch + pad
    a = jax.ShapeDtypeStruct((bsz, la), L.LIMB_DTYPE)
    b = jax.ShapeDtypeStruct((bsz, lb), L.LIMB_DTYPE)

    def fn(av, bv):
        return mcim_fold_mul(av, bv, ct=run_ct, tile_b=tile,
                             schedule=schedule, interpret=True)

    return LaunchContract(
        name=f"mcim_fold/{schedule}[la={la},lb={lb},ct={run_ct}]",
        fn=fn, args=(a, b),
        grid=(bsz // tile, geo.ct_run),
        scratch_shapes=(((tile, geo.scratch_width), "uint32"),),
        vmem_model_bytes=vmem_bytes_per_step(la, lb, ct, tile, schedule),
        meta={"geometry": geo, "tile_b": tile, "batch": bsz})


def vmem_bytes_per_step(la: int, lb: int, ct: int, tile_b: int,
                        schedule: str = "fb") -> int:
    """Per-grid-step VMEM working set (the kernel's 'area').

    Used by benchmarks to show the 1/CT footprint fold, the TPU analogue
    of the paper's silicon-area saving.  The FF schedule keeps the full
    register file live, so only its B-chunk input folds with CT.  The
    folded Karatsuba schedule keeps one half-width (hp = n/2+1) PPM port
    pair plus the full-product compressor accumulator live per cycle --
    its saving is vs the *spatial* Karatsuba (three PPM windows at
    once), not vs Star.
    """
    geo = fold_geometry(la, lb, 3 if schedule == "karatsuba" else ct,
                        schedule)
    if schedule == "karatsuba":
        hp = geo.chunk                  # half-width PPM port (n/2 + 1)
        words = tile_b * (2 * hp        # this cycle's operand port pair
                          + 2 * hp      # shared PPM window (T_j columns)
                          + geo.scratch_width)  # compressor feedback acc
        return words * 4
    words = tile_b * (geo.la          # A tile
                      + geo.chunk     # B chunk
                      + geo.scratch_width)  # acc window / register file
    return words * 4
