"""Jitted public wrappers for the folded big-int multiply kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import limbs as L
from .kernel import mcim_fold_mul
from .ref import mcim_fold_mul_ref

# On this (CPU) container the kernel always runs in interpret mode; on a
# real TPU flip the default with REPRO_PALLAS_INTERPRET=0.
import os
INTERPRET = os.environ.get("REPRO_PALLAS_INTERPRET", "1") == "1"


@functools.partial(jax.jit, static_argnames=("ct", "schedule", "use_kernel"))
def big_mul(a: jax.Array, b: jax.Array, ct: int = 2, schedule: str = "fb",
            use_kernel: bool = True) -> jax.Array:
    """Batched wide-int multiply with automatic batch-tile selection."""
    if a.ndim == 1:
        a, b = a[None], b[None]
        return big_mul(a, b, ct=ct, schedule=schedule,
                       use_kernel=use_kernel)[0]
    bsz = a.shape[0]
    if not use_kernel:
        return mcim_fold_mul_ref(a, b, ct=ct, schedule=schedule)
    tile = bsz
    for cand in (512, 256, 128, 64, 32, 16, 8, 4, 2, 1):
        if bsz % cand == 0:
            tile = cand
            break
    return mcim_fold_mul(a, b, ct=ct, tile_b=tile, schedule=schedule,
                         interpret=INTERPRET)


def vmem_bytes_per_step(la: int, lb: int, ct: int, tile_b: int,
                        schedule: str = "fb") -> int:
    """Per-grid-step VMEM working set (the kernel's 'area').

    Used by benchmarks to show the 1/CT footprint fold, the TPU analogue
    of the paper's silicon-area saving.  The FF schedule keeps the full
    register file live, so only its B-chunk input folds with CT.
    """
    chunk = -(-lb // ct)
    acc = (la + ct * chunk + 1) if schedule == "ff" else (la + chunk + 1)
    words = tile_b * (la              # A tile
                      + chunk         # B chunk
                      + acc)          # accumulator window / register file
    return words * 4
