from .ops import big_mul, vmem_bytes_per_step, batch_tile, launch_contract
from .kernel import mcim_fold_mul, fold_geometry, FoldGeometry
from .ref import mcim_fold_mul_ref

__all__ = ["big_mul", "vmem_bytes_per_step", "batch_tile", "mcim_fold_mul",
           "fold_geometry", "FoldGeometry", "mcim_fold_mul_ref",
           "launch_contract"]
