"""Pure-jnp oracle for the mcim_fold kernel: the core FB multiplier."""
import jax
import jax.numpy as jnp

from repro.core.schoolbook import feedback_mul


def mcim_fold_mul_ref(a: jax.Array, b: jax.Array, *, ct: int = 2) -> jax.Array:
    """(B, LA) x (B, LB) -> (B, LA+LB) limbs, FB architecture."""
    return feedback_mul(a, b, ct=ct)
