"""Pure-jnp oracle for the mcim_fold kernel: the core folded multipliers."""
import jax

from repro.core.schoolbook import feedback_mul, feedforward_mul
from repro.core.karatsuba import karatsuba_mul


def mcim_fold_mul_ref(a: jax.Array, b: jax.Array, *, ct: int = 2,
                      schedule: str = "fb") -> jax.Array:
    """(B, LA) x (B, LB) -> (B, LA+LB) limbs, FB / FF / folded-Karatsuba."""
    if schedule == "fb":
        return feedback_mul(a, b, ct=ct)
    if schedule == "ff":
        return feedforward_mul(a, b, ct=ct)
    if schedule == "karatsuba":
        # the kernel realizes one folded Karatsuba level over CT=3 with
        # schoolbook sub-PPMs, i.e. the paper's Karat-1 design
        return karatsuba_mul(a, b, levels=1, ct=ct)
    raise ValueError(
        f"schedule must be fb, ff or karatsuba, got {schedule!r}")
