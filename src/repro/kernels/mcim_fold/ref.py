"""Pure-jnp oracle for the mcim_fold kernel: the core FB/FF multipliers."""
import jax

from repro.core.schoolbook import feedback_mul, feedforward_mul


def mcim_fold_mul_ref(a: jax.Array, b: jax.Array, *, ct: int = 2,
                      schedule: str = "fb") -> jax.Array:
    """(B, LA) x (B, LB) -> (B, LA+LB) limbs, FB or FF architecture."""
    if schedule == "fb":
        return feedback_mul(a, b, ct=ct)
    if schedule == "ff":
        return feedforward_mul(a, b, ct=ct)
    raise ValueError(f"schedule must be fb or ff, got {schedule!r}")
