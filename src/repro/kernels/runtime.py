"""Single owner of Pallas interpret-mode selection.

Every kernel family used to declare its own module-level
``INTERPRET = os.environ.get("REPRO_PALLAS_INTERPRET", "1") == "1"``
copy; four duplicated policies meant a real-backend port had to flip
four flags (and a fifth for every new kernel).  This module is the one
flag: :func:`interpret_mode` returns True when kernels should run
through the Pallas interpreter (the CPU container) and False the moment
a real TPU/GPU backend is present -- so every kernel, the fused bank
megakernel included, is non-interpret-ready without code changes.

Resolution order:

  1. ``REPRO_INTERPRET``         -- explicit override; "0"/"false"/"off"
                                    force native lowering, anything else
                                    forces the interpreter
  2. ``REPRO_PALLAS_INTERPRET``  -- legacy name, same semantics
  3. auto                        -- interpret on CPU, native on TPU/GPU

The decision is cached for the life of the process (kernels bake it
into their jit traces as a static argument); tests can re-evaluate the
environment via :func:`reset`.
"""
from __future__ import annotations

import functools
import os

#: values that disable the interpreter when set in either env var
_FALSY = ("0", "false", "False", "no", "off")

#: jax backends with native Pallas lowering (no interpreter needed)
_NATIVE_BACKENDS = ("tpu", "gpu")


@functools.lru_cache(maxsize=1)
def interpret_mode() -> bool:
    """Should Pallas kernels run under ``interpret=True``?"""
    for var in ("REPRO_INTERPRET", "REPRO_PALLAS_INTERPRET"):
        val = os.environ.get(var)
        if val is not None:
            return val not in _FALSY
    import jax
    return jax.default_backend() not in _NATIVE_BACKENDS


def reset() -> None:
    """Forget the cached decision (test hook: re-read the environment).

    Kernels that already traced with the old value keep their jit cache;
    callers re-reading :func:`interpret_mode` see the fresh decision.
    """
    interpret_mode.cache_clear()
