"""Pallas TPU kernels for the performance-critical integer hot spots.

  mcim_fold     -- multi-cycle folded big-int multiplier (FB architecture)
  int8_matmul   -- quantized matmul with folded K accumulation
  karatsuba_ppm -- combinational Karatsuba PPM (paper Fig. 4)
  prefix_adder  -- Brent-Kung parallel-prefix final adder (fast 1CA)

All ship a jnp oracle (ref.py) and run under interpret=True on CPU.
"""
from . import mcim_fold
from . import int8_matmul
from . import karatsuba_ppm
from . import prefix_adder

__all__ = ["mcim_fold", "int8_matmul", "karatsuba_ppm", "prefix_adder"]
