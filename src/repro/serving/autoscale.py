"""Replica autoscaling: track offered load against provisioned TP.

The :class:`Autoscaler` is a deliberately small control loop in the
Kubernetes-HPA shape: each dispatch window the worker reports how many
requests arrived, the controller folds that into an EMA of the offered
rate (requests/cycle), and the replica target is the smallest fleet
whose aggregate provisioned throughput -- ``replicas x Plan.throughput``
per-replica ops/cycle -- covers the smoothed rate at the configured
utilization ceiling.

Asymmetric response, because the failure modes are asymmetric:

  * **scale-up is immediate** -- under-provisioning turns directly into
    refusals (the admission controller starts proving deadlines
    infeasible), so the first window the EMA crosses the ceiling grows
    the fleet;
  * **scale-down waits out ``patience`` consecutive low windows** --
    tearing a replica down on one quiet window flaps under bursty and
    diurnal load, and a draining replica still has committed work.

Beyond replica count, :meth:`Autoscaler.recommend` closes the loop with
the autotuner: when the *sustained* rate sits below the provisioned
per-replica throughput, the cheaper answer than "run fewer replicas of
a big design" is often "run a smaller design" -- so the controller can
consult a :class:`repro.autotune.ParetoFront` for the cheapest design
point whose throughput still covers the observed rate.
"""
from __future__ import annotations

import math

__all__ = ["Autoscaler"]


class Autoscaler:
    """EMA-rate replica controller with hysteresis.

    ``provisioned_tp`` is ONE replica's ``Plan.throughput`` in ops/cycle
    (Fraction or float).  ``target_utilization`` is the fill ceiling a
    replica is sized to (0.85 = keep 15% headroom for bursts);
    ``patience`` is how many consecutive windows the target must sit
    below the live count before a replica is actually drained.
    """

    def __init__(self, provisioned_tp, *, min_replicas: int = 1,
                 max_replicas: int = 8, target_utilization: float = 0.85,
                 ema: float = 0.3, patience: int = 3):
        tp = float(provisioned_tp)
        if tp <= 0:
            raise ValueError(f"provisioned_tp must be positive, got {tp}")
        if not 0.0 < target_utilization <= 1.0:
            raise ValueError("target_utilization must be in (0, 1]")
        if not 0.0 < ema <= 1.0:
            raise ValueError("ema must be in (0, 1]")
        if not 1 <= min_replicas <= max_replicas:
            raise ValueError("need 1 <= min_replicas <= max_replicas")
        self.provisioned_tp = tp
        self.min_replicas = min_replicas
        self.max_replicas = max_replicas
        self.target_utilization = target_utilization
        self.ema = ema
        self.patience = patience
        self.rate = 0.0           # EMA of offered requests/cycle
        self._low_windows = 0

    def _clamp(self, n: int) -> int:
        return max(self.min_replicas, min(self.max_replicas, n))

    def desired(self) -> int:
        """Smallest fleet covering the EMA rate at the fill ceiling."""
        if self.rate <= 0.0:
            return self.min_replicas
        need = self.rate / (self.provisioned_tp * self.target_utilization)
        return self._clamp(math.ceil(need))

    def observe(self, cycle: int, n_arrivals: int, elapsed_cycles: int,
                live: int) -> int:
        """Fold one dispatch window into the EMA; return the replica
        target the worker should converge to.

        Scale-up targets apply immediately; scale-down targets are held
        at ``live`` until ``patience`` consecutive windows agree.
        """
        inst = n_arrivals / max(elapsed_cycles, 1)
        self.rate += self.ema * (inst - self.rate)
        target = self.desired()
        if target >= live:
            self._low_windows = 0
            return target
        self._low_windows += 1
        if self._low_windows >= self.patience:
            self._low_windows = 0
            return target
        return live

    def recommend(self, front, objective: str = "area"):
        """Cheapest autotuner design point still covering the sustained
        rate, or None when the front has no feasible point.

        Consulted when the EMA rate sits below one replica's provisioned
        throughput: rather than idling a big design, re-plan onto the
        ``ParetoFront`` point with the least ``objective`` (area by
        default) whose per-replica throughput >= the observed rate.
        """
        if self.rate >= self.provisioned_tp:
            return None            # load fills the current design: keep it
        return front.best_meeting(self.rate, objective)

    def describe(self) -> str:
        return (f"Autoscaler[rate={self.rate:.4f}/cy "
                f"tp={self.provisioned_tp:.4f}/cy/replica "
                f"target={self.desired()} "
                f"range=[{self.min_replicas},{self.max_replicas}]]")
