"""The online serving worker loop: admit -> batch -> dispatch -> complete.

``launch/serve.py``'s replay path is post-hoc: it scores a finished
arrival trace against a bank.  This module makes dispatch *online*, the
vLLM-worker-loop shape: a :class:`Worker` owns N independent bank
replicas of one ``CompiledDesign`` and advances a simulated bank clock
in dispatch windows of ``round_cycles``.  Each window it

  1. **admits** every request that arrived in the window, in
     (arrival, deadline, rid) order -- EDF among simultaneous arrivals.
     A front-end router round-robins requests over live replicas
     (``rid % n_live``, the cheap load balancer real fleets put in
     front of workers); admission control (:mod:`.slo`) commits the
     request to the home replica's earliest-finishing instance, spills
     to the globally best replica when the home misses the deadline,
     and *refuses* when no live instance can provably retire it in
     time.  Committed slots are never preempted, so an admitted
     request structurally cannot miss its SLO -- the failure mode is
     an explicit refusal, recorded with its evidence
     (``Response.earliest_possible``);
  2. **steals work** across replicas: bursty routing leaves ragged
     queues, so the least-backlogged replica pulls not-yet-issued
     commits off the most-backlogged replica's queue tails whenever
     that strictly improves their finish cycle (deadlines can only get
     safer);
  3. **dispatches** every commit retiring inside the window as ONE
     bank round per replica -- one ``Bank.execute`` call over the
     gathered operands (padded to a power-of-two bucket so ragged
     windows reuse jit caches), which on the fused backend is a single
     Pallas megakernel launch per round;
  4. **autoscales**: an optional :class:`~.autoscale.Autoscaler`
     watches the observed arrival rate vs the per-replica provisioned
     ``Plan.throughput`` and grows the fleet immediately / drains it
     patiently (a draining replica takes no new work and retires once
     its queue is empty).

Cycle accounting is exact and shared with the offline path: committed
issue/finish chains are precisely what
``core.bank.schedule.completion_cycles`` reconstructs, and latency
histograms use the same helpers ``Bank.report`` uses.  Numeric results
are bit-exact vs the Python-bigint oracle regardless of policy,
backend or stealing (``check=True`` verifies every response).
"""
from __future__ import annotations

import dataclasses
import time
from fractions import Fraction

import numpy as np

from repro.core import limbs as L
from repro.core.bank import Bank
from repro.core.bank.schedule import histogram_percentile, latency_histogram

from .requests import Request, Response
from .slo import earliest_completion

__all__ = ["Worker", "Replica", "ServingReport"]


@dataclasses.dataclass
class _Commit:
    """One admitted request bound to a (replica, instance, issue) slot."""
    req: Request
    replica: int
    instance: int
    issue: int
    finish: int
    prev_free: int          # instance horizon before this commit (steal undo)
    earliest_possible: int  # admission proof (<= deadline)
    stolen: bool = False


class Replica:
    """One independent bank replica: committed horizon + pending queue."""

    def __init__(self, index: int, bank: Bank):
        self.index = index
        self.bank = bank
        self.cts = tuple(cfg.ct for cfg in bank.instances)
        self.free_at = [0] * len(self.cts)     # committed busy-until
        self.queues = [[] for _ in self.cts]   # pending commits, issue order
        self.busy_cycles = [0] * len(self.cts)
        self.draining = False
        self.retired = False

    def backlog(self, now: int) -> int:
        """Committed cycles beyond ``now`` on the worst instance."""
        return max(max(f - now, 0) for f in self.free_at)

    def pending(self) -> int:
        return sum(len(q) for q in self.queues)

    def commit(self, req: Request, earliest: int, *,
               stolen: bool = False) -> _Commit:
        """Bind ``req`` to this replica's earliest-finishing instance."""
        i = min(range(len(self.cts)),
                key=lambda j: (max(self.free_at[j], req.arrival)
                               + self.cts[j], j))
        issue = max(self.free_at[i], req.arrival)
        c = _Commit(req=req, replica=self.index, instance=i, issue=issue,
                    finish=issue + self.cts[i], prev_free=self.free_at[i],
                    earliest_possible=earliest, stolen=stolen)
        self.free_at[i] = c.finish
        self.queues[i].append(c)
        return c

    def best_completion(self, arrival: int) -> int:
        return earliest_completion(self.cts, self.free_at, arrival)

    def steal_candidate(self, now: int):
        """The latest-finishing queue-tail commit not yet issued."""
        best = None
        for q in self.queues:
            if q and q[-1].issue >= now:
                if best is None or q[-1].finish > best.finish:
                    best = q[-1]
        return best

    def unqueue_tail(self, c: _Commit) -> None:
        """Undo the LAST commit on ``c``'s instance (steal bookkeeping)."""
        q = self.queues[c.instance]
        assert q and q[-1] is c, "only queue tails are stealable"
        q.pop()
        self.free_at[c.instance] = c.prev_free


@dataclasses.dataclass(frozen=True)
class ServingReport:
    """Aggregate metrics of one sustained-load serving run."""
    design: str                 # plan description served
    n_requests: int
    n_admitted: int
    n_refused: int
    n_completed: int
    slo_violations: int         # admitted requests retired past deadline
    steals: int                 # commits rebalanced across replicas
    rounds: int                 # bank rounds dispatched (execute calls)
    max_round_batch: int        # largest single-round batch (pre-padding)
    horizon_cycles: int         # first arrival .. last retire
    offered_rate: float         # requests/cycle over the horizon
    goodput: float              # deadline-met completions/cycle
    provisioned_tp: str         # per-replica Plan.throughput (Fraction)
    latency_hist: tuple         # ((cycles, count), ...) admitted requests
    utilization: tuple          # per replica: per-instance busy/horizon
    replica_timeline: tuple     # ((cycle, n_live), ...) autoscale trace
    wall_s: float
    n_checked: int = 0          # oracle-verified responses (check=True)
    n_mismatch: int = 0

    @property
    def slo_violation_rate(self) -> float:
        return self.slo_violations / self.n_admitted if self.n_admitted \
            else 0.0

    @property
    def refusal_rate(self) -> float:
        return self.n_refused / self.n_requests if self.n_requests else 0.0

    @property
    def bit_exact(self):
        """True/False when oracle-checked, None when check was off."""
        return self.n_mismatch == 0 if self.n_checked else None

    def latency_percentile(self, q: float):
        return histogram_percentile(self.latency_hist, q)

    @property
    def latency_p50(self):
        return self.latency_percentile(0.50)

    @property
    def latency_p99(self):
        return self.latency_percentile(0.99)

    def describe(self) -> str:
        return (f"ServingReport[{self.design}: {self.n_requests} reqs "
                f"offered={self.offered_rate:.3f}/cy "
                f"goodput={self.goodput:.3f}/cy "
                f"p50={self.latency_p50} p99={self.latency_p99} cy "
                f"refused={self.n_refused} viol={self.slo_violations} "
                f"steals={self.steals} rounds={self.rounds}]")


def _bucket(n: int) -> int:
    """Round a ragged round batch up to a power of two (jit-cache reuse)."""
    b = 1
    while b < n:
        b <<= 1
    return b


class Worker:
    """Online serving loop over N replicas of one compiled design.

    ``design`` is a :class:`repro.designs.CompiledDesign` (serving
    replicas are independent Banks on one host's simulated clock --
    distinct from ``spec.replicas``, which shards one logical bank over
    a device mesh).  ``run(requests)`` drives the loop to completion
    and returns a :class:`ServingReport`; ``responses`` holds the
    per-request outcomes afterwards.
    """

    def __init__(self, design, *, replicas: int = 1,
                 round_cycles: int | None = None, steal: bool = True,
                 autoscaler=None, check: bool = False):
        if replicas < 1:
            raise ValueError("replicas must be >= 1")
        self.design = design
        self.plan = design.plan
        self.spec = design.spec
        self.backend = design.bank.backend
        max_ct = max(cfg.ct for cfg in design.bank.instances)
        self.round_cycles = round_cycles or max(16, 2 * max_ct)
        if self.round_cycles < 1:
            raise ValueError("round_cycles must be >= 1")
        self.steal = steal
        self.autoscaler = autoscaler
        self.check = check
        self.replicas = [self._new_replica(i) for i in range(replicas)]
        self.responses = {}
        self.steals = 0
        self.rounds = 0
        self.max_round_batch = 0
        self.n_checked = 0
        self.n_mismatch = 0
        self._timeline = []

    # ---------------------------------------------------------- replicas
    def _new_replica(self, index: int) -> Replica:
        # same plan/backend on every replica: cached_mul shares the
        # per-instance jit traces, so replica N+1 is cheap to spin up
        bank = Bank(self.plan, self.spec.bits_a, self.spec.bits_b,
                    backend=self.backend,
                    scheduler=self.design.bank.scheduler.name)
        return Replica(index, bank)

    def _live(self) -> list:
        return [r for r in self.replicas if not (r.draining or r.retired)]

    # --------------------------------------------------------- admission
    def _admit(self, req: Request) -> None:
        live = self._live()
        earliest = min(r.best_completion(req.arrival) for r in live)
        if earliest > req.deadline:
            # provably infeasible: even the globally best instance,
            # issuing as early as possible, retires past the deadline
            self.responses[req.rid] = Response(
                rid=req.rid, admitted=False, arrival=req.arrival,
                deadline=req.deadline, earliest_possible=earliest)
            return
        home = live[req.rid % len(live)]
        rep = home if home.best_completion(req.arrival) <= req.deadline \
            else min(live, key=lambda r: (r.best_completion(req.arrival),
                                          r.index))
        rep.commit(req, earliest)

    # ------------------------------------------------------ work stealing
    def _steal_pass(self, now: int) -> None:
        """Rebalance queue tails until no steal improves a finish cycle."""
        budget = sum(r.pending() for r in self.replicas)
        while budget > 0:
            budget -= 1
            live = self._live()
            if len(live) < 2:
                return
            thief = min(live, key=lambda r: (r.backlog(now), r.index))
            victim = max(live, key=lambda r: (r.backlog(now), -r.index))
            if victim is thief:
                return
            c = victim.steal_candidate(now)
            if c is None:
                return
            j = min(range(len(thief.cts)),
                    key=lambda i: (max(thief.free_at[i], c.req.arrival)
                                   + thief.cts[i], i))
            new_finish = max(thief.free_at[j], c.req.arrival) + thief.cts[j]
            if new_finish >= c.finish:
                return
            victim.unqueue_tail(c)
            thief.commit(c.req, c.earliest_possible, stolen=True)
            self.steals += 1

    # --------------------------------------------------------- execution
    def _oracle(self, req: Request) -> int:
        """Python-bigint product, signed-corrected to the bank's output
        width when the design is signed."""
        ia = L.from_limbs(np.asarray(req.a, np.uint32))
        ib = L.from_limbs(np.asarray(req.b, np.uint32))
        if self.spec.signed:
            if ia >= 1 << (self.spec.bits_a - 1):
                ia -= 1 << (L.RADIX_BITS * self.design.la)
            if ib >= 1 << (self.spec.bits_b - 1):
                ib -= 1 << (L.RADIX_BITS * self.design.lb)
        width = L.RADIX_BITS * (self.design.la + self.design.lb)
        return (ia * ib) % (1 << width)

    def _execute_round(self, rep: Replica, window_end: int) -> None:
        """Run every commit retiring inside the window as ONE bank round."""
        due = []
        for q in rep.queues:
            while q and q[0].finish <= window_end:
                due.append(q.pop(0))
        if not due:
            return
        due.sort(key=lambda c: (c.finish, c.req.rid))
        n = len(due)
        bucket = _bucket(n)
        a = np.zeros((bucket, self.design.la), np.uint32)
        b = np.zeros((bucket, self.design.lb), np.uint32)
        for k, c in enumerate(due):
            a[k] = c.req.a
            b[k] = c.req.b
        import jax.numpy as jnp
        out = np.asarray(rep.bank.execute(jnp.asarray(a), jnp.asarray(b)))
        self.rounds += 1
        self.max_round_batch = max(self.max_round_batch, n)
        for k, c in enumerate(due):
            rep.busy_cycles[c.instance] += rep.cts[c.instance]
            product = tuple(int(x) for x in out[k])
            if self.check:
                self.n_checked += 1
                if L.from_limbs(out[k]) != self._oracle(c.req):
                    self.n_mismatch += 1
            self.responses[c.req.rid] = Response(
                rid=c.req.rid, admitted=True, arrival=c.req.arrival,
                deadline=c.req.deadline,
                earliest_possible=c.earliest_possible,
                issue=c.issue, finish=c.finish, replica=rep.index,
                instance=c.instance, stolen=c.stolen, product=product)

    # -------------------------------------------------------- autoscaling
    def _autoscale(self, window_end: int, n_arrived: int,
                   elapsed: int) -> None:
        live = self._live()
        target = self.autoscaler.observe(window_end, n_arrived, elapsed,
                                         len(live))
        if target > len(live):
            for _ in range(target - len(live)):
                # un-drain a held replica before building a new one
                held = next((r for r in self.replicas
                             if r.draining and not r.retired), None)
                if held is not None:
                    held.draining = False
                else:
                    self.replicas.append(
                        self._new_replica(len(self.replicas)))
        elif target < len(live):
            for rep in sorted(live, key=lambda r: -r.index)[
                    :len(live) - target]:
                rep.draining = True

    def _retire_drained(self) -> None:
        for rep in self.replicas:
            if rep.draining and not rep.retired and rep.pending() == 0:
                rep.retired = True

    # -------------------------------------------------------------- loop
    def run(self, requests) -> ServingReport:
        reqs = sorted(requests, key=lambda r: (r.arrival, r.rid))
        if not reqs:
            raise ValueError("no requests to serve")
        self.responses = {}
        t0 = time.perf_counter()
        now = reqs[0].arrival
        i = 0
        self._timeline = [(now, len(self._live()))]
        while i < len(reqs) or any(r.pending() for r in self.replicas):
            window_end = now + self.round_cycles
            batch = []
            while i < len(reqs) and reqs[i].arrival < window_end:
                batch.append(reqs[i])
                i += 1
            # EDF among simultaneous arrivals: a tight-deadline request
            # in a burst claims its slot before lax ones
            batch.sort(key=lambda r: (r.arrival, r.deadline, r.rid))
            for req in batch:
                self._admit(req)
            if self.steal and len(self._live()) > 1:
                self._steal_pass(now)
            for rep in self.replicas:
                self._execute_round(rep, window_end)
            self._retire_drained()
            if self.autoscaler is not None:
                self._autoscale(window_end, len(batch), self.round_cycles)
                if self._timeline[-1][1] != len(self._live()):
                    self._timeline.append((window_end, len(self._live())))
            now = window_end
            if i < len(reqs) and not any(r.pending() for r in self.replicas) \
                    and reqs[i].arrival > now:
                now = reqs[i].arrival        # fast-forward an idle fleet
        wall = time.perf_counter() - t0
        return self._report(reqs, wall)

    # ------------------------------------------------------------ report
    def _report(self, reqs, wall: float) -> ServingReport:
        rs = [self.responses[r.rid] for r in reqs]
        admitted = [r for r in rs if r.admitted]
        met = [r for r in admitted if r.met_deadline]
        first = min(r.arrival for r in reqs)
        last = max([r.finish for r in admitted]
                   + [r.arrival for r in reqs])
        horizon = max(last - first, 1)
        hist = latency_histogram(r.latency for r in admitted)
        util = tuple(
            tuple(b / horizon for b in rep.busy_cycles)
            for rep in self.replicas)
        return ServingReport(
            design=self.plan.describe(),
            n_requests=len(rs),
            n_admitted=len(admitted),
            n_refused=len(rs) - len(admitted),
            n_completed=len(admitted),
            slo_violations=len(admitted) - len(met),
            steals=self.steals,
            rounds=self.rounds,
            max_round_batch=self.max_round_batch,
            horizon_cycles=horizon,
            offered_rate=len(rs) / horizon,
            goodput=len(met) / horizon,
            provisioned_tp=str(Fraction(self.plan.throughput)),
            latency_hist=hist,
            utilization=util,
            replica_timeline=tuple(self._timeline),
            wall_s=wall,
            n_checked=self.n_checked,
            n_mismatch=self.n_mismatch,
        )
