"""Requests, responses and synthetic load for online bank serving.

The serving layer's unit of work is one multiplication with a latency
budget: a :class:`Request` carries its operands (limb tuples at the
design's widths), the cycle it enters the system, and the absolute
deadline by which its product must retire.  A :class:`Response` records
what the worker did with it -- the committed issue/finish cycles and
the product limbs for admitted requests, or the refusal evidence
(``earliest_possible``, the best completion any instance could have
offered) for refused ones, so admission control is auditable after the
fact: a refusal is only ever justified by ``earliest_possible >
deadline``.

Synthetic load generators produce the arrival shapes sustained traffic
actually has (all seeded, all in integer bank cycles):

  ``poisson_arrivals``   memoryless arrivals at a mean rate -- the
                         baseline open-loop load model;
  ``bursty_arrivals``    whole bursts land on one cycle (the serve
                         driver's grouped prefills look like this),
                         spaced to hold the same mean rate;
  ``diurnal_arrivals``   sinusoidally modulated Poisson rate -- the
                         millions-of-users day/night envelope an
                         autoscaler must track.

``synthesize`` turns any arrival trace into concrete requests with
random operands, round-robined over multi-tenant width classes.
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np

from repro.core import limbs as L


@dataclasses.dataclass(frozen=True)
class Request:
    """One multiplication with a latency budget (all cycles absolute)."""
    rid: int
    arrival: int                # cycle the request enters the system
    deadline: int               # absolute retire-by cycle (SLO)
    a: tuple                    # operand A limbs (len LA, uint32 values)
    b: tuple                    # operand B limbs (len LB)
    bits_a: int = 0             # width class (0 = the design's width)
    bits_b: int = 0
    tenant: int = 0             # tenant the width class belongs to

    @property
    def budget(self) -> int:
        """Latency budget in cycles (deadline relative to arrival)."""
        return self.deadline - self.arrival

    def oracle(self) -> int:
        """The Python-bigint product every response is checked against."""
        return L.from_limbs(np.asarray(self.a, np.uint32)) * \
            L.from_limbs(np.asarray(self.b, np.uint32))


@dataclasses.dataclass(frozen=True)
class Response:
    """What the worker did with one request."""
    rid: int
    admitted: bool
    arrival: int
    deadline: int
    #: best completion cycle ANY live instance could have offered at
    #: decision time: the admission proof (admitted => <= deadline) and
    #: the refusal evidence (refused => > deadline)
    earliest_possible: int
    issue: int = -1             # committed start cycle (admitted only)
    finish: int = -1            # committed retire cycle (admitted only)
    replica: int = -1           # replica that executed it
    instance: int = -1          # instance index within that replica
    stolen: bool = False        # rebalanced off its home replica's queue
    product: tuple = ()         # (LA+LB) product limbs

    @property
    def latency(self) -> int:
        """End-to-end cycles from arrival to retire (-1 if refused)."""
        return self.finish - self.arrival if self.admitted else -1

    @property
    def met_deadline(self) -> bool:
        return self.admitted and self.finish <= self.deadline


# ------------------------------------------------------------ load shapes

def poisson_arrivals(n: int, rate: float, seed: int = 0) -> tuple:
    """``n`` Poisson arrivals at ``rate`` requests/cycle (mean)."""
    if rate <= 0:
        raise ValueError(f"rate must be positive, got {rate}")
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / rate, size=n)
    return tuple(int(c) for c in np.floor(np.cumsum(gaps)))


def bursty_arrivals(n: int, rate: float, seed: int = 0,
                    burst: int = 8) -> tuple:
    """Bursts of ``burst`` simultaneous arrivals at mean ``rate``.

    Burst instants are Poisson at ``rate / burst``, so the mean request
    rate matches ``poisson_arrivals`` while the instantaneous rate is
    ``burst`` times spikier -- the worst case for per-replica queues
    (and the case work stealing exists for).
    """
    if burst < 1:
        raise ValueError(f"burst must be >= 1, got {burst}")
    n_bursts = -(-n // burst)
    instants = poisson_arrivals(n_bursts, rate / burst, seed)
    out = [c for c in instants for _ in range(burst)]
    return tuple(out[:n])


def diurnal_arrivals(n: int, rate: float, seed: int = 0,
                     period: int = 512, depth: float = 0.8) -> tuple:
    """Sinusoidally modulated Poisson arrivals (mean ``rate``).

    The instantaneous rate is ``rate * (1 + depth*sin(2*pi*t/period))``:
    a day/night envelope squeezed into ``period`` cycles, peaking at
    ``(1+depth)x`` the mean -- the trace an autoscaler must follow up
    AND back down.
    """
    if not 0.0 <= depth < 1.0:
        raise ValueError(f"depth must be in [0, 1), got {depth}")
    rng = np.random.default_rng(seed)
    out = []
    t = 0
    while len(out) < n:
        inst = rate * (1.0 + depth * math.sin(2.0 * math.pi * t / period))
        k = rng.poisson(max(inst, 0.0))
        out.extend([t] * int(k))
        t += 1
    return tuple(out[:n])


# --------------------------------------------------------------- requests

def synthesize(arrivals, bits_a: int, bits_b: int, budget: int, *,
               seed: int = 0, width_classes=None) -> tuple:
    """Concrete requests for an arrival trace: random operands, fixed
    latency budget, width classes round-robined over tenants.

    ``bits_a``/``bits_b`` are the serving design's operand widths;
    ``width_classes`` optionally lists per-tenant ``(wa, wb)`` pairs no
    wider than the design (narrow tenants' operands are generated at
    their own width and zero-extend into the design's limbs, so one
    bank serves every tenant bit-exactly).  ``budget`` is the SLO in
    cycles: ``deadline = arrival + budget``.
    """
    arrivals = tuple(int(c) for c in arrivals)
    if any(y < x for x, y in zip(arrivals, arrivals[1:])):
        raise ValueError("arrival trace must be nondecreasing")
    if budget < 1:
        raise ValueError(f"budget must be >= 1 cycle, got {budget}")
    classes = tuple(width_classes or ((bits_a, bits_b),))
    for wa, wb in classes:
        if wa > bits_a or wb > bits_b:
            raise ValueError(
                f"width class {wa}x{wb} exceeds the design's "
                f"{bits_a}x{bits_b}")
    rng = np.random.default_rng(seed)
    la = L.n_limbs_for_bits(bits_a)
    lb = L.n_limbs_for_bits(bits_b)
    out = []
    for rid, arr in enumerate(arrivals):
        tenant = rid % len(classes)
        wa, wb = classes[tenant]
        a = np.zeros((la,), np.uint32)
        b = np.zeros((lb,), np.uint32)
        a[:L.n_limbs_for_bits(wa)] = L.random_limbs(rng, (), wa)
        b[:L.n_limbs_for_bits(wb)] = L.random_limbs(rng, (), wb)
        out.append(Request(rid=rid, arrival=arr, deadline=arr + budget,
                           a=tuple(int(x) for x in a),
                           b=tuple(int(x) for x in b),
                           bits_a=wa, bits_b=wb, tenant=tenant))
    return tuple(out)
