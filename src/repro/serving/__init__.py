"""Online multi-tenant bank serving: SLO admission, stealing, autoscale.

The offline layers answer "how fast is this design on a batch?"; this
package answers the production question: *under sustained multi-tenant
load, which requests meet their latency SLO, and at what fleet size?*

  :mod:`.requests`   -- Request/Response records plus seeded synthetic
                        load (Poisson, bursty, diurnal traces) and
                        multi-tenant width classes.
  :mod:`.slo`        -- the ``slo_edf`` Scheduler (EDF, registered with
                        the core scheduler family and swept by the
                        verifier contracts) and the admission-control
                        predicates: refuse iff provably infeasible.
  :mod:`.worker`     -- the event loop: admit -> batch into bank rounds
                        (one fused Pallas launch per round) -> dispatch
                        -> complete, with per-replica queues and work
                        stealing for ragged bursts.
  :mod:`.autoscale`  -- EMA replica controller against provisioned
                        ``Plan.throughput``, with a ParetoFront hook
                        recommending cheaper design points under
                        sustained low load.

Importing this package registers ``slo_edf`` in
``core.bank.schedule.SCHEDULERS`` (so ``DesignSpec(scheduler="slo_edf")``
compiles and ``python -m repro.verify`` sweeps it).  The high-level
entry point is ``CompiledDesign.serve(...)``.
"""
from .requests import (Request, Response, poisson_arrivals, bursty_arrivals,
                       diurnal_arrivals, synthesize)
from .slo import (SLOScheduler, SLO_SCHEDULER, NO_DEADLINE, edf_schedule,
                  earliest_completion, admissible)
from .worker import Worker, Replica, ServingReport
from .autoscale import Autoscaler

__all__ = [
    "Request", "Response", "poisson_arrivals", "bursty_arrivals",
    "diurnal_arrivals", "synthesize",
    "SLOScheduler", "SLO_SCHEDULER", "NO_DEADLINE", "edf_schedule",
    "earliest_completion", "admissible",
    "Worker", "Replica", "ServingReport",
    "Autoscaler",
]
