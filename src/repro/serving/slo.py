"""SLO-aware dispatch: earliest-deadline-first scheduling + admission.

Two layers, deliberately split:

* :class:`SLOScheduler` joins the round_robin/greedy/streaming family
  in :mod:`repro.core.bank.schedule`: a *complete* policy mapping
  ``(cts, n_ops)`` to a static ``(assignment, makespan)`` pair.  Ops
  are ordered earliest-deadline-first (ties: arrival, then index) and
  list-scheduled onto the instance that finishes each earliest.  With
  no deadlines and no arrivals configured the order degenerates to op
  index and the placement rule to earliest-completion-time, i.e. the
  policy reproduces ``greedy_schedule`` exactly -- a property the test
  suite pins.  Because it is complete and deterministic it passes the
  same verifier contracts (``verify/contracts.check_scheduler``) as
  every other registered policy; it is registered at import, so the
  ``python -m repro.verify`` scheduler sweep covers it by construction.

* Admission control lives in :func:`earliest_completion` /
  :func:`admissible`: *refusing* work is a serving-loop decision, not a
  schedule-shape one (a Scheduler must assign every op -- the
  completeness contract).  The worker consults these against the
  committed per-instance ``free_at`` horizon before a request ever
  reaches a schedule: a request is refused iff even the best instance,
  issuing as early as possible, would retire it after its deadline --
  so every refusal is provably infeasible (no preemption, committed
  work is never reordered) and every admission carries a slot that
  meets the SLO.  Missing an SLO silently is therefore structurally
  impossible: the failure mode is an explicit refusal at admission.
"""
from __future__ import annotations

import dataclasses
import functools
import math

from repro.core.bank.schedule import register_scheduler

#: deadline value meaning "no SLO" (sorts after every real deadline)
NO_DEADLINE = math.inf


@functools.lru_cache(maxsize=1024)
def edf_schedule(cts: tuple, n_ops: int, arrivals: tuple,
                 deadlines: tuple) -> tuple:
    """EDF list scheduling: static (assignment, makespan), complete.

    Ops are taken in (deadline, arrival, index) order; each goes to the
    instance minimizing its completion ``max(free, arrival) + ct``
    (ties: lowest instance index).  Per-instance issue order equals
    append order, so :func:`~repro.core.bank.schedule.completion_cycles`
    reconstructs this schedule's finish times exactly -- one accounting
    path for offline reports and online serving alike.
    """
    if len(arrivals) != n_ops:
        raise ValueError(
            f"arrival trace has {len(arrivals)} entries for {n_ops} ops")
    if len(deadlines) != n_ops:
        raise ValueError(
            f"deadline trace has {len(deadlines)} entries for {n_ops} ops")
    n_inst = len(cts)
    order = sorted(range(n_ops),
                   key=lambda k: (deadlines[k], arrivals[k], k))
    free = [0] * n_inst
    assign = [[] for _ in range(n_inst)]
    makespan = 0
    for k in order:
        best = min(range(n_inst),
                   key=lambda i: (max(free[i], arrivals[k]) + cts[i], i))
        done = max(free[best], arrivals[k]) + cts[best]
        free[best] = done
        assign[best].append(k)
        makespan = max(makespan, done)
    return tuple(tuple(ops) for ops in assign), makespan


@dataclasses.dataclass(frozen=True)
class SLOScheduler:
    """Earliest-deadline-first dispatch with optional arrival trace.

    ``deadlines``/``arrivals`` fix absolute per-op traces (prefixes are
    taken per batch, like StreamingScheduler); with neither set every
    op is due "eventually" and available at cycle 0, which reduces the
    policy to greedy earliest-completion-time dispatch.
    """
    arrivals: tuple | None = None
    deadlines: tuple | None = None
    name: str = "slo_edf"

    def arrivals_for(self, n_ops: int) -> tuple:
        if self.arrivals is None:
            return (0,) * n_ops
        trace = tuple(self.arrivals)[:n_ops]
        if len(trace) < n_ops:
            raise ValueError(
                f"arrival trace has {len(trace)} entries, need {n_ops}")
        return trace

    def deadlines_for(self, n_ops: int) -> tuple:
        if self.deadlines is None:
            return (NO_DEADLINE,) * n_ops
        trace = tuple(self.deadlines)[:n_ops]
        if len(trace) < n_ops:
            raise ValueError(
                f"deadline trace has {len(trace)} entries, need {n_ops}")
        return trace

    def schedule(self, cts: tuple, n_ops: int) -> tuple:
        return edf_schedule(tuple(cts), n_ops,
                            self.arrivals_for(n_ops),
                            self.deadlines_for(n_ops))


#: the registered default instance (spec.scheduler="slo_edf" resolves
#: to it once repro.serving is imported)
SLO_SCHEDULER = register_scheduler(SLOScheduler())


# ------------------------------------------------------------- admission

def earliest_completion(cts: tuple, free_at, arrival: int) -> int:
    """Best retire cycle any instance can offer a new op.

    ``free_at[i]`` is instance i's committed busy-until horizon; the op
    can issue at ``max(free_at[i], arrival)`` and retires ``cts[i]``
    later.  This is exact for non-preemptive committed work: no
    reordering of already-admitted ops can make any instance free
    earlier than its horizon.
    """
    return min(max(f, arrival) + ct for f, ct in zip(free_at, cts))


def admissible(cts: tuple, free_at, arrival: int, deadline) -> bool:
    """Can ANY instance provably retire the op by its deadline?"""
    return earliest_completion(cts, free_at, arrival) <= deadline
