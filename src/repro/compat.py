"""Version-compat shims for the jax API surface this repo uses.

The repo targets the modern jax API (``jax.shard_map`` with
``check_vma=``); older versions (< 0.5) expose the same function as
``jax.experimental.shard_map.shard_map`` with the flag spelled
``check_rep=``.  ``shard_map`` here accepts the modern signature and
translates as needed, so call sites never branch on version.
"""
from __future__ import annotations

import functools
import inspect

try:                                        # jax >= 0.5
    from jax import shard_map as _shard_map
except ImportError:                         # jax < 0.5
    from jax.experimental.shard_map import shard_map as _shard_map

_PARAMS = inspect.signature(_shard_map).parameters
_HAS_CHECK_VMA = "check_vma" in _PARAMS


@functools.wraps(_shard_map)
def shard_map(f, /, *, mesh, in_specs, out_specs, check_vma: bool = True,
              **kw):
    if _HAS_CHECK_VMA:
        kw["check_vma"] = check_vma
    elif "check_rep" in _PARAMS:
        kw["check_rep"] = check_vma
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      **kw)
