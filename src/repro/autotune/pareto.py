"""Candidates and Pareto fronts for the MCIM design autotuner.

A :class:`Candidate` is one concrete decomposition of a
:class:`~repro.designs.DesignSpec`'s throughput into MCIM instances,
scored on the five objectives the paper's tables report:

  area (um^2) . latency (cycles) . fmax (GHz) . energy/op (pJ) .
  peak power (mW)

:func:`pareto_front` splits a candidate pool into the non-dominated
front and the dominated rest.  Everything here is deterministic and
order-invariant: the front is a set property of the pool, and each
dominated candidate records the *lexicographically smallest* dominating
candidate key as provenance, so shuffling the enumeration order can
never change the result (a property the hypothesis suite asserts).
"""
from __future__ import annotations

import dataclasses
import json

from repro.core.mcim import MCIMConfig
from repro.designs import DesignSpec, compile_plan

#: objective name -> (metric attribute, maximize?)
OBJECTIVES = {
    "area": ("area_um2", False),
    "latency": ("latency_cycles", False),
    "fmax": ("fmax_ghz", True),
    "energy": ("energy_per_op_pj", False),
    "peak_power": ("peak_power_mw", False),
}


def _cfg_dict(cfg: MCIMConfig) -> dict:
    return {"arch": cfg.arch, "ct": cfg.ct, "levels": cfg.levels,
            "adder": cfg.adder, "signed": cfg.signed}


def _cfg_from_dict(d: dict) -> MCIMConfig:
    return MCIMConfig(**d)


@dataclasses.dataclass(frozen=True)
class Candidate:
    """One scored decomposition: spec + explicit instance list + metrics."""
    spec: DesignSpec
    configs: tuple                 # tuple[(count, MCIMConfig)]
    area_um2: float
    latency_cycles: int
    fmax_ghz: float
    energy_per_op_pj: float
    peak_power_mw: float
    slack_ns: tuple                # per instance, at the scoring period
    dominated_by: str | None = None

    @property
    def key(self) -> str:
        """Canonical identity: the sorted instance multiset + the spec.
        Stable across enumeration order and process restarts."""
        insts = sorted(
            f"{c}x{cfg.arch}.ct{cfg.ct}.k{cfg.levels}.{cfg.adder}"
            f"{'.s' if cfg.signed else ''}"
            for c, cfg in self.configs)
        return f"{self.spec.bits_a}x{self.spec.bits_b}" \
               f"@{self.spec.throughput}:" + "+".join(insts)

    def objective_vector(self) -> tuple:
        """All five metrics as minimized values (period, not fmax)."""
        return (self.area_um2, float(self.latency_cycles),
                1.0 / self.fmax_ghz, self.energy_per_op_pj,
                self.peak_power_mw)

    def dominates(self, other: "Candidate") -> bool:
        a, b = self.objective_vector(), other.objective_vector()
        return all(x <= y for x, y in zip(a, b)) and \
            any(x < y for x, y in zip(a, b))

    def compile(self, mesh=None):
        """Materialize this candidate as an executable CompiledDesign
        (through ``designs.compile_plan`` -- the same timing gate)."""
        return compile_plan(self.spec, self.configs, mesh=mesh)

    def describe(self) -> str:
        insts = " + ".join(f"{c}x {cfg.arch}(ct={cfg.ct}"
                           + (f",K={cfg.levels}" if cfg.arch == "karatsuba"
                              else "")
                           + (f",{cfg.adder}" if cfg.adder != "1ca" else "")
                           + ")"
                           for c, cfg in self.configs)
        return (f"{insts}  area={self.area_um2:.0f}um2 "
                f"lat={self.latency_cycles}cy fmax={self.fmax_ghz:.2f}GHz "
                f"E={self.energy_per_op_pj:.2f}pJ/op "
                f"Ppeak={self.peak_power_mw:.2f}mW")

    # --------------------------------------------------------- serialization
    def to_dict(self) -> dict:
        return {
            "spec": self.spec.to_dict(),
            "configs": [[c, _cfg_dict(cfg)] for c, cfg in self.configs],
            "area_um2": self.area_um2,
            "latency_cycles": self.latency_cycles,
            "fmax_ghz": self.fmax_ghz,
            "energy_per_op_pj": self.energy_per_op_pj,
            "peak_power_mw": self.peak_power_mw,
            "slack_ns": list(self.slack_ns),
            "dominated_by": self.dominated_by,
        }

    @classmethod
    def from_dict(cls, d: dict) -> "Candidate":
        return cls(
            spec=DesignSpec.from_dict(d["spec"]),
            configs=tuple((int(c), _cfg_from_dict(cfg))
                          for c, cfg in d["configs"]),
            area_um2=d["area_um2"],
            latency_cycles=d["latency_cycles"],
            fmax_ghz=d["fmax_ghz"],
            energy_per_op_pj=d["energy_per_op_pj"],
            peak_power_mw=d["peak_power_mw"],
            slack_ns=tuple(d["slack_ns"]),
            dominated_by=d.get("dominated_by"),
        )


def pareto_front(candidates) -> tuple:
    """Split ``candidates`` into (front, dominated), order-invariantly.

    front: candidates no other candidate dominates, sorted by area;
    dominated: the rest, each carrying ``dominated_by`` = the smallest
    (by key) candidate that dominates it.  Duplicate keys collapse to
    one representative.
    """
    # canonical processing order -> deterministic output for any input order
    pool = sorted({c.key: c for c in candidates}.values(),
                  key=lambda c: c.key)
    front, dominated = [], []
    for c in pool:
        dominators = sorted(o.key for o in pool if o.dominates(c))
        if dominators:
            dominated.append(dataclasses.replace(
                c, dominated_by=dominators[0]))
        else:
            front.append(c)
    front.sort(key=lambda c: (c.objective_vector(), c.key))
    dominated.sort(key=lambda c: (c.objective_vector(), c.key))
    return tuple(front), tuple(dominated)


class ParetoFront:
    """The autotuner's result: the non-dominated set plus provenance.

    ``front`` lists the surviving candidates (sorted area-ascending);
    ``dominated`` keeps every pruned candidate with the key of a
    dominator, so a sweep's full decision record is serializable.
    """

    def __init__(self, front, dominated=(), *, space_key: str = "",
                 n_scored: int = 0, from_cache: bool = False):
        self.front = tuple(front)
        self.dominated = tuple(dominated)
        self.space_key = space_key
        self.n_scored = n_scored
        self.from_cache = from_cache

    def __len__(self) -> int:
        return len(self.front)

    def __iter__(self):
        return iter(self.front)

    def best(self, objective: str = "energy") -> Candidate:
        """The front point minimizing (or, for fmax, maximizing) one
        objective; ties break on the canonical key."""
        try:
            attr, maximize = OBJECTIVES[objective]
        except KeyError:
            raise ValueError(f"objective must be one of "
                             f"{sorted(OBJECTIVES)}") from None
        if not self.front:
            raise ValueError("empty Pareto front")
        sign = -1.0 if maximize else 1.0
        return min(self.front,
                   key=lambda c: (sign * getattr(c, attr), c.key))

    def best_meeting(self, min_throughput, objective: str = "area"):
        """Cheapest front point whose throughput covers ``min_throughput``
        (ops/cycle), or None when no front point is fast enough.

        This is the serving autoscaler's consultation hook
        (``repro.serving.Autoscaler.recommend``): under sustained load
        below the provisioned TP, re-plan onto the least-``objective``
        design that still sustains the observed rate.  Unlike
        :meth:`best` it filters on a throughput floor first, and returns
        None instead of raising so a controller can fall back to "keep
        the current design".
        """
        try:
            attr, maximize = OBJECTIVES[objective]
        except KeyError:
            raise ValueError(f"objective must be one of "
                             f"{sorted(OBJECTIVES)}") from None
        feasible = [c for c in self.front
                    if float(c.spec.throughput) >= float(min_throughput)]
        if not feasible:
            return None
        sign = -1.0 if maximize else 1.0
        return min(feasible,
                   key=lambda c: (sign * getattr(c, attr), c.key))

    def describe(self) -> str:
        lines = [f"ParetoFront[{len(self.front)} points, "
                 f"{len(self.dominated)} dominated, "
                 f"scored={self.n_scored}"
                 + (", cached" if self.from_cache else "") + "]"]
        lines += [f"  {c.describe()}" for c in self.front]
        return "\n".join(lines)

    # --------------------------------------------------------- serialization
    def to_dict(self) -> dict:
        return {
            "space_key": self.space_key,
            "n_scored": self.n_scored,
            "front": [c.to_dict() for c in self.front],
            "dominated": [c.to_dict() for c in self.dominated],
        }

    @classmethod
    def from_dict(cls, d: dict, *, from_cache: bool = False) -> "ParetoFront":
        return cls(
            front=[Candidate.from_dict(c) for c in d["front"]],
            dominated=[Candidate.from_dict(c) for c in d["dominated"]],
            space_key=d.get("space_key", ""),
            n_scored=0 if from_cache else d.get("n_scored", 0),
            from_cache=from_cache,
        )

    def to_json(self) -> str:
        return json.dumps(self.to_dict(), sort_keys=True)

    @classmethod
    def from_json(cls, s: str, *, from_cache: bool = False) -> "ParetoFront":
        return cls.from_dict(json.loads(s), from_cache=from_cache)
