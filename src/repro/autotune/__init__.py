"""repro.autotune: Pareto-front search over MCIM decompositions.

``repro.designs.generate`` compiles ONE plan per spec.  This subsystem
searches the whole decomposition space instead and returns the
area/latency/fmax/energy/peak-power Pareto front -- the multi-objective
view the paper's energy and peak-power claims (up to 33% / 65% vs Star)
live on, and the substrate later architecture work plugs new planner
archs into:

    from repro import autotune, designs

    front = autotune.search(designs.DesignSpec(32, 32, "1/3"))
    print(front.describe())             # non-dominated candidates
    d = front.best("energy").compile()  # any point -> CompiledDesign

    # or in one call (generate() stays the single-plan path):
    d = autotune.generate_best(spec, objective="peak_power")

Fronts are cached on a spec-space hash (JSON files, see ``cache``):
re-running the same sweep loads the stored front with zero re-scores.
Scoring is pure cost-model arithmetic (``core.area_model``,
``core.power_model``, ``core.timing_model``) and every candidate
compiles through ``designs.compile_plan`` under the same timing gate
``generate()`` applies.
"""
from .pareto import Candidate, ParetoFront, pareto_front, OBJECTIVES
from .candidates import (enumerate_configs, ct_decompositions, CT_SET,
                         MAX_CANDIDATES)
from .search import search, generate_best, score
from .cache import space_key, cache_dir_path, AUTOTUNE_VERSION

__all__ = [
    "Candidate", "ParetoFront", "pareto_front", "OBJECTIVES",
    "enumerate_configs", "ct_decompositions", "CT_SET", "MAX_CANDIDATES",
    "search", "generate_best", "score",
    "space_key", "cache_dir_path", "AUTOTUNE_VERSION",
]
