"""Candidate enumeration: every MCIM decomposition of a DesignSpec.

``generate()`` runs the paper's pick-one policy; the autotuner instead
enumerates the whole space that policy chooses from:

  1. the fractional part of the throughput is decomposed into every
     multiset of 1/CT terms over the planner's CT set (Sec. V-B: e.g.
     5/6 = 1/2 + 1/3, 11/12 = 1/2 + 1/3 + 1/12, ...);
  2. each CT slot is filled with every architecture variant that can
     realize it -- FB, FF, and (CT=3) folded Karatsuba at recursion
     levels 1..3 with 1CA or 3CA final adders;
  3. the integer part stays Star instances (a full multiply per cycle
     has no folded realization), matching the paper's use-case banks.

Timing constraints are enforced with the SAME gate ``generate()`` uses
(``timing_model.meets_timing`` / ``pipelineable`` via the helpers in
``repro.designs.compile``), not a reimplementation, so a candidate
surviving enumeration is by construction compilable by
``designs.compile_plan``.
"""
from __future__ import annotations

import itertools
import math
from fractions import Fraction

from repro.core import timing_model
from repro.core.mcim import MCIMConfig
from repro.designs import DesignSpec, DesignError
from repro.designs.compile import _instance_latency, _timing_bits

#: the planner's CT vocabulary (Sec. V-B combinations)
CT_SET = (2, 3, 4, 6, 8, 12)
#: Karatsuba recursion depths explored per CT=3 slot
KARATSUBA_LEVELS = (1, 2, 3)
#: bound on the number of folded instances per bank (11/12 needs 3)
MAX_PARTS = 6
#: safety valve on the cross-product size per spec
MAX_CANDIDATES = 4096


def ct_decompositions(frac: Fraction) -> list:
    """All multisets of CTs from CT_SET with sum(1/ct) == frac,
    as non-increasing ct tuples (canonical, duplicate-free)."""
    out = []

    def rec(remaining: Fraction, max_ct: int, parts: tuple):
        if remaining == 0:
            if parts:
                out.append(parts)
            return
        if len(parts) >= MAX_PARTS:
            return
        for ct in CT_SET:
            if ct < max_ct:          # non-increasing ct == non-decreasing 1/ct
                continue
            piece = Fraction(1, ct)
            if piece <= remaining:
                rec(remaining - piece, ct, parts + (ct,))

    rec(frac, 0, ())
    return out


def _arch_variants(bits_a: int, bits_b: int, ct: int) -> list:
    """Every MCIMConfig that realizes one 1/ct slot."""
    variants = [MCIMConfig(arch="fb", ct=ct), MCIMConfig(arch="ff", ct=ct)]
    if ct == 3:
        for levels in KARATSUBA_LEVELS:
            for adder in ("1ca", "3ca"):
                variants.append(MCIMConfig(arch="karatsuba", ct=3,
                                           levels=levels, adder=adder))
    return variants


def _meets_spec_timing(cfg: MCIMConfig, spec: DesignSpec, bits: int) -> bool:
    """The generate() timing gate, applied per candidate instance."""
    if spec.strict_timing and \
            not timing_model.pipelineable(cfg.arch, cfg.adder):
        return False
    if spec.clock_ns is not None and \
            not timing_model.meets_timing(cfg.arch, bits, spec.clock_ns,
                                          cfg.adder):
        return False
    if spec.latency_budget is not None and \
            _instance_latency(cfg, bits, spec.clock_ns) > spec.latency_budget:
        return False
    return True


def enumerate_configs(spec: DesignSpec) -> list:
    """All candidate instance lists for ``spec``, timing-gated.

    Returns a list of ``tuple[(count, MCIMConfig)]`` entries, each
    summing to exactly ``spec.throughput``; deduplicated as multisets
    and deterministically ordered.
    """
    tp = spec.throughput
    bits = _timing_bits(spec)
    n_full = math.floor(tp)
    frac = tp - n_full
    base = ((n_full, MCIMConfig(arch="star", ct=1)),) if n_full else ()
    if base and not _meets_spec_timing(base[0][1], spec, bits):
        return []                       # Star itself misses the target
    if frac == 0:
        return [base] if base else []

    seen, out = set(), []
    for cts in ct_decompositions(frac):
        pools = []
        for ct in cts:
            pool = [cfg for cfg in _arch_variants(spec.bits_a, spec.bits_b,
                                                  ct)
                    if _meets_spec_timing(cfg, spec, bits)]
            pools.append(pool)
        if any(not pool for pool in pools):
            continue                    # a slot nothing can fill in time
        for combo in itertools.product(*pools):
            multiset = tuple(sorted(
                ((cfg.arch, cfg.ct, cfg.levels, cfg.adder) for cfg in combo)))
            if multiset in seen:
                continue
            seen.add(multiset)
            counts = {}
            for cfg in combo:
                counts[cfg] = counts.get(cfg, 0) + 1
            configs = base + tuple(
                (count, cfg) for cfg, count in sorted(
                    counts.items(),
                    key=lambda kv: (kv[0].ct, kv[0].arch, kv[0].levels,
                                    kv[0].adder)))
            out.append(configs)
            if len(out) >= MAX_CANDIDATES:
                raise DesignError(
                    f"candidate space for {spec.describe()} exceeds "
                    f"{MAX_CANDIDATES}; constrain the spec (clock, "
                    f"strict_timing) to prune it")
    return out
