"""search(): score every MCIM decomposition, keep the Pareto front.

The search layer ROADMAP item 1 asks for: instead of ``generate()``'s
pick-one-plan behavior, enumerate every candidate decomposition of a
``DesignSpec`` (``candidates.enumerate_configs``), score each on the
five paper objectives (area / latency / fmax / energy / peak power --
all from the calibrated ``core`` models, no execution needed), and
return the non-dominated :class:`~.pareto.ParetoFront` with dominated-
by provenance and per-instance timing slack.

Scoring mirrors ``CompiledDesign``'s properties exactly (same stress
multiplier, same instance-latency/period helpers), so a candidate's
metrics equal those of ``candidate.compile()`` -- the front IS a set of
compilable designs, not a separate estimate.
"""
from __future__ import annotations

import dataclasses

from repro import verify
from repro.core import area_model, power_model, timing_model
from repro.designs import DesignSpec
from repro.designs.compile import (_instance_latency, _instance_period,
                                   _timing_bits)
from .candidates import enumerate_configs
from .pareto import Candidate, ParetoFront, pareto_front
from . import cache as _cache


def score(spec: DesignSpec, configs) -> Candidate:
    """Score one explicit decomposition on all five objectives."""
    if spec.signed:
        configs = tuple((c, dataclasses.replace(cfg, signed=True))
                        for c, cfg in configs)
    # static gate: an unprovable candidate never reaches the front (the
    # per-instance analyses are lru-cached, so sweeping thousands of
    # candidates re-verifies each distinct design point once)
    verify.assert_plan(spec.bits_a, spec.bits_b, configs,
                       spec.throughput)
    bits = _timing_bits(spec)
    stress = 1.0 if spec.clock_ns is None else \
        timing_model.stress("star", bits, spec.clock_ns)
    area = sum(c * area_model.area_um2(spec.bits_a, spec.bits_b, cfg)
               for c, cfg in configs) * stress * spec.replicas
    latency = max(_instance_latency(cfg, bits, spec.clock_ns)
                  for _, cfg in configs)
    periods = [_instance_period(cfg, bits, spec.clock_ns)
               for _, cfg in configs]
    period = max(periods)
    energy = power_model.plan_energy_per_op_pj(
        spec.bits_a, spec.bits_b, configs, stress=stress)
    peak = power_model.plan_peak_power_mw(
        spec.bits_a, spec.bits_b, configs, clock_ns=period,
        stress=stress) * spec.replicas
    slack = tuple(round(period - p, 6) for p in periods)
    return Candidate(spec=spec, configs=tuple(configs),
                     area_um2=area, latency_cycles=latency,
                     fmax_ghz=1.0 / period, energy_per_op_pj=energy,
                     peak_power_mw=peak, slack_ns=slack)


def _as_specs(spec_space) -> tuple:
    from repro.designs import registry
    if isinstance(spec_space, (DesignSpec, str)):
        spec_space = [spec_space]
    return tuple(registry.get(s) if isinstance(s, str) else s
                 for s in spec_space)


def search(spec_space, *, use_cache: bool = True,
           cache_dir: str | None = None) -> ParetoFront:
    """Sweep a spec space and return its Pareto front.

    ``spec_space`` is one ``DesignSpec`` (or registered name), or an
    iterable of them; candidates from every spec are pooled into one
    front (pool comparable problems -- same widths/TP -- unless you
    deliberately want a cross-problem sweep).  Results are cached on
    the spec-space hash: a repeated ``search`` over the same space
    loads the stored front and performs ZERO re-scores
    (``front.from_cache`` / ``front.n_scored`` report which path ran).
    """
    specs = _as_specs(spec_space)
    if not specs:
        raise ValueError("empty spec space")
    key = _cache.space_key(specs)
    if use_cache:
        hit = _cache.load(key, cache_dir)
        if hit is not None:
            return hit
    scored = []
    for spec in specs:
        for configs in enumerate_configs(spec):
            scored.append(score(spec, configs))
    front, dominated = pareto_front(scored)
    result = ParetoFront(front, dominated, space_key=key,
                         n_scored=len(scored))
    if use_cache:
        _cache.store(key, result, cache_dir)
    return result


def generate_best(spec, objective: str = "energy", mesh=None,
                  **search_kw):
    """One point off the front, compiled: ``search`` + ``best`` +
    ``compile`` in one call.  ``generate()`` stays the single-plan
    path; this is the multi-objective convenience next to it."""
    front = search(spec, **search_kw)
    return front.best(objective).compile(mesh=mesh)
