"""JSON file cache for autotuner fronts: spec-space hash -> ParetoFront.

A sweep's result is fully determined by (the specs searched, the cost
models' calibration, the enumeration vocabulary), so the cache key
hashes exactly those.  Any change to the power model (MODEL_VERSION),
the candidate vocabulary, or a spec field produces a new key -- stale
fronts are never served, and a cached re-run of the same spec space
performs zero re-scores (asserted by tests and the bench).

The cache directory resolves, in order: an explicit ``cache_dir``
argument, ``$REPRO_AUTOTUNE_CACHE``, ``~/.cache/repro_autotune``.
"""
from __future__ import annotations

import hashlib
import json
import os

from repro.core.power_model import MODEL_VERSION
from .pareto import ParetoFront

#: bump when enumeration/scoring semantics change
AUTOTUNE_VERSION = "autotune-1"


def cache_dir_path(cache_dir: str | None = None) -> str:
    if cache_dir is not None:
        return cache_dir
    env = os.environ.get("REPRO_AUTOTUNE_CACHE")
    if env:
        return env
    return os.path.join(os.path.expanduser("~"), ".cache",
                        "repro_autotune")


def space_key(specs) -> str:
    """Deterministic hash of a spec space (order-insensitive)."""
    payload = json.dumps({
        "autotune": AUTOTUNE_VERSION,
        "power_model": MODEL_VERSION,
        "specs": sorted(s.to_json() for s in specs),
    }, sort_keys=True)
    return hashlib.sha256(payload.encode()).hexdigest()[:24]


def _path(cache_dir: str | None, key: str) -> str:
    return os.path.join(cache_dir_path(cache_dir), f"front_{key}.json")


def load(key: str, cache_dir: str | None = None) -> ParetoFront | None:
    """The cached front for ``key``, or None (corrupt files = miss)."""
    path = _path(cache_dir, key)
    try:
        with open(path) as f:
            front = ParetoFront.from_json(f.read(), from_cache=True)
    except (OSError, ValueError, KeyError):
        return None
    if front.space_key != key:          # stale/foreign file: ignore
        return None
    return front


def store(key: str, front: ParetoFront,
          cache_dir: str | None = None) -> str:
    """Persist ``front`` under ``key``; returns the file path."""
    root = cache_dir_path(cache_dir)
    os.makedirs(root, exist_ok=True)
    path = _path(cache_dir, key)
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        f.write(front.to_json())
    os.replace(tmp, path)               # atomic: concurrent sweeps are safe
    return path
