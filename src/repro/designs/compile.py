"""generate(): compile a DesignSpec into an executable CompiledDesign.

This is the paper's design generator as one function.  Planning is no
longer area-only: candidate plans are filtered through
``core.timing_model`` so the clock-period / fmax customization is wired
into design selection (a relaxed plan whose feedback-loop instances
cannot meet ``spec.clock_ns`` falls back to pipelineable designs, and a
latency budget rejects designs whose pipeline depth at the target
exceeds it).  The resulting ``CompiledDesign`` owns the whole pipeline:
the chosen ``planner.Plan``, an executable ``bank.Bank`` (scheduler and
backend resolved from the spec), optional mesh replication, and the
area/latency/fmax properties the paper's tables report.
"""
from __future__ import annotations

import dataclasses
import math
from fractions import Fraction

import numpy as np
import jax

from repro.core import limbs as L
from repro.core import planner, timing_model
from repro.core.bank import Bank, BankReport, StreamingScheduler, \
    sharded_execute
from repro.core.mcim import MCIMConfig
from repro.core import area_model
from repro.core import power_model
from repro import verify

from .spec import DesignSpec, DesignError, TimingError, LatencyError


def _timing_bits(spec: DesignSpec) -> int:
    """Width driving the critical path (the wider operand dominates)."""
    return max(spec.bits_a, spec.bits_b)


def _timing_violations(plan: planner.Plan, bits: int,
                       clock_ns: float) -> list:
    return [cfg for _, cfg in plan.configs
            if not timing_model.meets_timing(cfg.arch, bits, clock_ns,
                                             cfg.adder)]


def _instance_latency(cfg: MCIMConfig, bits: int,
                      clock_ns: float | None) -> int:
    t = clock_ns if clock_ns is not None else math.inf
    return timing_model.latency_at(cfg.arch, bits, t, cfg.ct)


def _instance_period(cfg: MCIMConfig, bits: int,
                     clock_ns: float | None) -> float:
    """Achievable clock period of one instance.

    Non-pipelineable instances are capped at their combinational path;
    pipelineable ones retime down to the requested target (paying
    latency), or run at their natural path when the spec is relaxed.
    """
    t = timing_model.t_comb(cfg.arch, bits)
    if clock_ns is not None and clock_ns < t and \
            timing_model.pipelineable(cfg.arch, cfg.adder):
        return clock_ns
    return t


class CompiledDesign:
    """An executable multiplier design compiled from a :class:`DesignSpec`.

    One object owns the whole pipeline the call sites used to hand-wire:
    the timing-filtered ``plan``, the executable ``bank`` (scheduler +
    backend resolved), optional mesh replication, the paper's
    area / latency / fmax figures as properties, and full provenance
    (``spec`` / ``to_json``).  ``mul(a, b)`` multiplies limb arrays --
    or plain Python ints -- bit-exactly through whichever substrate the
    spec selected.
    """

    def __init__(self, spec: DesignSpec, plan: planner.Plan, bank: Bank,
                 mesh=None, timing_fallback: bool = False):
        self.spec = spec
        self.plan = plan
        self.bank = bank
        self.mesh = mesh
        #: True when the relaxed plan missed spec.clock_ns and planning
        #: was redone with strict (pipelineable-only) candidates.
        self.timing_fallback = timing_fallback
        self.la = bank.la
        self.lb = bank.lb

    # ------------------------------------------------------------ execute
    def mul(self, a, b):
        """Multiply: limb arrays (B, LA) x (B, LB) -> (B, LA+LB), or two
        Python ints -> int (two's-complement when the spec is signed).

        Routes to the replicated sharded engine when the spec asked for
        replicas, else to the single bank's jitted dispatch.
        """
        if isinstance(a, (int, np.integer)) and isinstance(b, (int,
                                                               np.integer)):
            return self._mul_ints(int(a), int(b))
        if self.mesh is not None:
            return sharded_execute(self.plan, a, b, self.mesh,
                                   self.spec.mesh_axis,
                                   backend=self.bank.backend,
                                   scheduler=self.spec.scheduler)
        return self.bank.execute(a, b)

    def _mul_ints(self, a: int, b: int) -> int:
        enc_a = self._encode(a, self.spec.bits_a, self.la)
        enc_b = self._encode(b, self.spec.bits_b, self.lb)
        import jax.numpy as jnp
        out = self.bank.execute(jnp.asarray(enc_a)[None],
                                jnp.asarray(enc_b)[None])[0]
        total = L.from_limbs(np.asarray(out))
        if self.spec.signed:
            width = L.RADIX_BITS * (self.la + self.lb)
            if total >= 1 << (width - 1):
                total -= 1 << width
        return total

    def _encode(self, v: int, bits: int, limbs: int) -> np.ndarray:
        if self.spec.signed:
            if not -(1 << (bits - 1)) <= v < (1 << (bits - 1)):
                raise ValueError(f"{v} out of signed {bits}-bit range")
            v %= 1 << (L.RADIX_BITS * limbs)
        elif not 0 <= v < (1 << bits):
            raise ValueError(f"{v} out of unsigned {bits}-bit range")
        return L.to_limbs(v, limbs)

    # ------------------------------------------------------------ reports
    def report(self, batch: int) -> BankReport:
        """Cycle accounting for one batch (per replica when sharded),
        with the design's modeled energy/op and peak power attached."""
        if self.spec.replicas > 1:
            if batch % self.spec.replicas:
                raise ValueError(f"batch {batch} does not divide over "
                                 f"{self.spec.replicas} replicas")
            batch //= self.spec.replicas
        return dataclasses.replace(self.bank.report(batch),
                                   energy_per_op_pj=self.energy_per_op_pj,
                                   peak_power_mw=self.peak_power_mw)

    def replay(self, arrivals) -> BankReport:
        """Replay an arrival trace (e.g. ``ServeEngine.arrival_trace()``)
        through this design's bank under the streaming scheduler: one
        work item per trace entry, issued no earlier than its arrival
        cycle."""
        trace = tuple(int(c) for c in arrivals)
        sched = StreamingScheduler(arrivals=trace)
        return self.bank.report(len(trace), scheduler=sched)

    def serve(self, requests, *, replicas: int = 1,
              round_cycles: int | None = None, steal: bool = True,
              autoscaler=None, check: bool = False):
        """Serve a request stream *online* through this design.

        Where :meth:`replay` scores a finished arrival trace,
        ``serve`` runs the full event loop of
        :class:`repro.serving.Worker`: SLO admission control, EDF
        dispatch in bank rounds (one fused Pallas launch per round on
        the fused backend), work stealing across ``replicas``
        independent bank replicas, and optional autoscaling (pass a
        ``repro.serving.Autoscaler``).  ``check=True`` verifies every
        response against the Python-bigint oracle.

        Returns ``(report, responses)``: the
        :class:`~repro.serving.ServingReport` and the per-request
        ``{rid: Response}`` outcomes.
        """
        from repro.serving import Worker
        worker = Worker(self, replicas=replicas, round_cycles=round_cycles,
                        steal=steal, autoscaler=autoscaler, check=check)
        report = worker.run(requests)
        return report, worker.responses

    # --------------------------------------------------------- properties
    @property
    def throughput(self):
        """Aggregate multiplications/cycle (replicas x per-bank TP)."""
        return self.plan.throughput * self.spec.replicas

    @property
    def area(self) -> float:
        """Modeled silicon area (um^2), all replicas, including the
        synthesis stress of meeting ``spec.clock_ns`` when set."""
        bits = _timing_bits(self.spec)
        total = 0.0
        for count, cfg in self.plan.configs:
            a = area_model.area_um2(self.spec.bits_a, self.spec.bits_b, cfg)
            if self.spec.clock_ns is not None:
                a *= timing_model.stress(cfg.arch, bits, self.spec.clock_ns)
            total += count * a
        return total * self.spec.replicas

    @property
    def latency_cycles(self) -> int:
        """Cycles from issue to retire for one multiplication: the worst
        instance's CT plus any retiming stages the clock target forces."""
        bits = _timing_bits(self.spec)
        return max(_instance_latency(cfg, bits, self.spec.clock_ns)
                   for _, cfg in self.plan.configs)

    @property
    def fmax_estimate(self) -> float:
        """Achievable clock (GHz): the slowest instance's period, with
        pipelineable instances retimed down to the spec's target."""
        bits = _timing_bits(self.spec)
        period = max(_instance_period(cfg, bits, self.spec.clock_ns)
                     for _, cfg in self.plan.configs)
        return 1.0 / period

    @property
    def _stress(self) -> float:
        """Synthesis-stress multiplier of the spec's clock target (1.0
        when relaxed): tight clocks force larger, higher-capacitance
        cells, inflating area AND switched energy alike."""
        if self.spec.clock_ns is None:
            return 1.0
        return timing_model.stress("star", _timing_bits(self.spec),
                                   self.spec.clock_ns)

    @property
    def energy_per_op_pj(self) -> float:
        """Modeled energy per multiplication (pJ), throughput-weighted
        over the bank's instances, including synthesis stress."""
        return power_model.plan_energy_per_op_pj(
            self.spec.bits_a, self.spec.bits_b, self.plan.configs,
            stress=self._stress)

    @property
    def peak_power_mw(self) -> float:
        """Modeled peak power (mW, all replicas): worst-cycle switched
        capacitance of every instance together, at the spec's clock (or
        the slowest instance's natural period when relaxed)."""
        period = 1.0 / self.fmax_estimate
        return power_model.plan_peak_power_mw(
            self.spec.bits_a, self.spec.bits_b, self.plan.configs,
            clock_ns=period, stress=self._stress) * self.spec.replicas

    def describe(self) -> str:
        extra = " timing_fallback" if self.timing_fallback else ""
        return (f"CompiledDesign[{self.spec.describe()} -> "
                f"{self.plan.describe()}  "
                f"energy={self.energy_per_op_pj:.2f}pJ/op  "
                f"peak={self.peak_power_mw:.2f}mW  "
                f"backend={self.bank.backend}  "
                f"scheduler={self.bank.scheduler.name}{extra}]")

    # --------------------------------------------------------- provenance
    def to_json(self) -> str:
        """The spec's lossless JSON: compiling it again reproduces this
        design bit-exactly (see DesignSpec.from_json)."""
        return self.spec.to_json()


# ---------------------------------------------------------------- generate

def _resolve_backend(spec: DesignSpec, plan: planner.Plan) -> str:
    if spec.backend == "kernel" and spec.signed:
        raise DesignError("the kernel capability is unsigned-only; use "
                          "backend='core', 'fused' or 'auto' for signed "
                          "designs (fused retires signedness through the "
                          "shared correction pass)")
    if spec.backend != "auto":
        return spec.backend
    # auto: one fused megakernel launch per round where Pallas is
    # native and every instance arch has a fused backend; per-instance
    # kernels as the unsigned fallback; pure-jnp elsewhere (the CPU
    # container would pay interpret-mode kernel cost for nothing)
    if jax.default_backend() == "tpu":
        from repro.core.bank.backends import registered_backends
        registered = set(registered_backends())
        if all((cfg.arch, "fused") in registered
               for _, cfg in plan.configs):
            return "fused"
        if not spec.signed:
            return "kernel"
    return "core"


def _achieved_throughput(plan: planner.Plan):
    return sum(Fraction(count, cfg.ct) for count, cfg in plan.configs)


def _plan_with_timing(spec: DesignSpec):
    plan = planner.plan_throughput(spec.bits_a, spec.bits_b,
                                   spec.throughput,
                                   strict_timing=spec.strict_timing,
                                   objective=spec.objective)
    if _achieved_throughput(plan) != spec.throughput:
        # plan_throughput silently drops the residual when a fractional
        # TP cannot be decomposed over its CT set; the facade's contract
        # is that the compiled design sustains exactly what was asked
        raise DesignError(
            f"throughput {spec.throughput} is not decomposable over the "
            f"planner's CT combinations (best plan sums to "
            f"{_achieved_throughput(plan)}); pick a TP whose fractional "
            f"part is a sum of 1/ct for ct in (2, 3, 4, 6, 8, 12)")
    fallback = False
    bits = _timing_bits(spec)
    if spec.clock_ns is not None:
        bad = _timing_violations(plan, bits, spec.clock_ns)
        if bad and not spec.strict_timing:
            # relaxed winner misses the clock: re-plan over pipelineable
            # candidates only (the paper's strict-timing tables)
            plan = planner.plan_throughput(spec.bits_a, spec.bits_b,
                                           spec.throughput,
                                           strict_timing=True,
                                           objective=spec.objective)
            fallback = True
            bad = _timing_violations(plan, bits, spec.clock_ns)
        if bad:
            worst = max(timing_model.t_comb(cfg.arch, bits) for cfg in bad)
            raise TimingError(
                f"no design meets clock {spec.clock_ns} ns for "
                f"{spec.describe()}: {[cfg.arch for cfg in bad]} bottom "
                f"out at t_comb={worst:.2f} ns and cannot pipeline")
    if spec.latency_budget is not None:
        lat = max(_instance_latency(cfg, bits, spec.clock_ns)
                  for _, cfg in plan.configs)
        if lat > spec.latency_budget:
            raise LatencyError(
                f"{spec.describe()} needs {lat} cycles of latency at "
                f"clock={spec.clock_ns} ns, over the budget of "
                f"{spec.latency_budget}")
    if spec.signed:
        plan = dataclasses.replace(plan, configs=tuple(
            (count, dataclasses.replace(cfg, signed=True))
            for count, cfg in plan.configs))
    # static verification gate: a plan the interval/contract analyzers
    # cannot prove overflow-safe and schedule-conformant never compiles
    verify.assert_plan(spec.bits_a, spec.bits_b, plan.configs,
                       plan.throughput)
    # dataflow gate: every Pallas launch the plan implies must prove
    # hazard-free, in-bounds and within its VMEM model -- without
    # executing (cached per distinct launch geometry)
    verify.assert_plan_dataflow(spec.bits_a, spec.bits_b, plan.configs)
    return plan, fallback


def _resolve_mesh(spec: DesignSpec, mesh):
    if spec.replicas == 1:
        return None
    if mesh is not None:
        if spec.mesh_axis not in mesh.shape:
            raise DesignError(f"mesh has no axis {spec.mesh_axis!r}")
        if mesh.shape[spec.mesh_axis] != spec.replicas:
            raise DesignError(
                f"mesh axis {spec.mesh_axis!r} has "
                f"{mesh.shape[spec.mesh_axis]} devices, spec wants "
                f"{spec.replicas} replicas")
        return mesh
    devices = jax.devices()
    if len(devices) < spec.replicas:
        raise DesignError(
            f"{spec.replicas} replicas need {spec.replicas} devices, "
            f"only {len(devices)} available (pass an explicit mesh or "
            f"lower spec.replicas)")
    return jax.sharding.Mesh(np.asarray(devices[:spec.replicas]),
                             (spec.mesh_axis,))


def generate(spec: DesignSpec, mesh=None) -> CompiledDesign:
    """Compile ``spec`` into an executable :class:`CompiledDesign`.

    The single front door for the repo: planner selection filtered by
    the timing model (clock + latency customization), scheduler/backend
    resolution, bank construction and optional mesh replication all
    happen here.  ``mesh`` may supply an existing device mesh for
    ``spec.replicas > 1``; otherwise one is built over the first
    ``replicas`` devices.
    """
    if isinstance(spec, str):
        from .registry import get
        spec = get(spec)
    plan, fallback = _plan_with_timing(spec)
    backend = _resolve_backend(spec, plan)
    bank = Bank(plan, spec.bits_a, spec.bits_b, backend=backend,
                scheduler=spec.scheduler)
    return CompiledDesign(spec, plan, bank,
                          mesh=_resolve_mesh(spec, mesh),
                          timing_fallback=fallback)


def compile_plan(spec: DesignSpec, configs, mesh=None) -> CompiledDesign:
    """Compile ``spec`` with an EXPLICIT instance list, bypassing the
    planner's pick-one policy.

    This is the autotuner's compile path: ``repro.autotune`` enumerates
    candidate decompositions itself and materializes any point off its
    Pareto front through here.  ``configs`` is an iterable of
    ``(count, MCIMConfig)``; it must sum to exactly ``spec.throughput``
    and every instance must meet the spec's clock/latency constraints
    (the same gate ``generate`` applies, not a duplicate of it).
    """
    configs = tuple((int(count), cfg) for count, cfg in configs)
    if spec.signed:
        configs = tuple((count, dataclasses.replace(cfg, signed=True))
                        for count, cfg in configs)
    area = sum(count * area_model.area_um2(spec.bits_a, spec.bits_b, cfg)
               for count, cfg in configs)
    plan = planner.Plan(configs=configs, throughput=spec.throughput,
                        area=area)
    if _achieved_throughput(plan) != spec.throughput:
        raise DesignError(
            f"explicit configs sum to TP={_achieved_throughput(plan)}, "
            f"spec wants {spec.throughput}")
    bits = _timing_bits(spec)
    if spec.strict_timing:
        bad = [cfg for _, cfg in configs
               if not timing_model.pipelineable(cfg.arch, cfg.adder)]
        if bad:
            raise TimingError(f"strict spec given non-pipelineable "
                              f"instances: {[cfg.arch for cfg in bad]}")
    if spec.clock_ns is not None:
        bad = _timing_violations(plan, bits, spec.clock_ns)
        if bad:
            raise TimingError(
                f"explicit configs miss clock {spec.clock_ns} ns: "
                f"{[cfg.arch for cfg in bad]}")
    if spec.latency_budget is not None:
        lat = max(_instance_latency(cfg, bits, spec.clock_ns)
                  for _, cfg in configs)
        if lat > spec.latency_budget:
            raise LatencyError(f"explicit configs need {lat} cycles, "
                               f"over the budget of {spec.latency_budget}")
    # same static gates generate() applies: explicit instance lists must
    # prove safe before a bank is built around them
    verify.assert_plan(spec.bits_a, spec.bits_b, plan.configs,
                       plan.throughput)
    verify.assert_plan_dataflow(spec.bits_a, spec.bits_b, plan.configs)
    backend = _resolve_backend(spec, plan)
    bank = Bank(plan, spec.bits_a, spec.bits_b, backend=backend,
                scheduler=spec.scheduler)
    return CompiledDesign(spec, plan, bank,
                          mesh=_resolve_mesh(spec, mesh))
