"""repro.designs: the one design-generator API (the repo's front door).

The paper's deliverable is a design generator "offering customization in
terms of throughput, latency, and clock frequency".  This package is
that generator as a two-step facade:

    from repro import designs

    spec = designs.DesignSpec(32, 32, throughput=3.5)   # declarative
    d = designs.generate(spec)                          # compiled
    d.mul(a, b)             # jitted bank execution (or two Python ints)
    d.area, d.latency_cycles, d.fmax_estimate, d.throughput
    d.report(batch)         # cycle accounting
    d.to_json()             # lossless provenance -> DesignSpec.from_json

``generate`` owns everything callers used to hand-wire: planner
selection filtered by the timing model (clock / latency customization),
scheduler + backend resolution, bank construction, and sharded
replication (``spec.replicas`` over ``spec.mesh_axis``).  Named design
points -- the paper's Table VIII rows and the Sec. V-E use-case banks --
are pre-registered: ``designs.generate("tp3p5_w32")``.

The PR-2/PR-3 layers (``repro.core.planner``, ``repro.core.bank``,
``repro.core.timing_model``) stay public for power users; new code
should start here.
"""
from .spec import (DesignSpec, DesignError, TimingError, LatencyError,
                   MAX_TP_DENOMINATOR)
from .compile import CompiledDesign, generate, compile_plan
from .registry import (register, get, names, TABLE_VIII, USE_CASES,
                       LOW_POWER)

__all__ = [
    "DesignSpec", "CompiledDesign", "generate", "compile_plan",
    "DesignError", "TimingError", "LatencyError", "MAX_TP_DENOMINATOR",
    "register", "get", "names", "TABLE_VIII", "USE_CASES", "LOW_POWER",
]
