"""DesignSpec: the declarative input of the design generator.

The paper's deliverable is a *generator* that "offers customization in
terms of throughput, latency, and clock frequency".  A ``DesignSpec``
is exactly that customization surface, frozen and serializable:

  * operand widths        -- ``bits_a`` x ``bits_b``
  * throughput            -- multiplications/cycle, fractional allowed
                             (``Fraction``, float, int or "7/2" string)
  * clock target          -- ``clock_ns`` period (or build the spec via
                             :meth:`DesignSpec.at_fmax`); designs that
                             cannot meet it are filtered out by
                             :func:`repro.designs.generate`
  * latency budget        -- max pipeline depth in cycles at the target
  * strict_timing         -- restrict planning to pipelineable designs
                             up front (paper Tables IV/VI/VIII)
  * signed                -- two's-complement operands
  * scheduler / backend   -- bank dispatch policy and execution
                             substrate ("auto" resolves per platform)
  * replicas / mesh_axis  -- sharded multi-bank replication
  * objective             -- scalar the planner ranks candidate designs
                             by: "area" (default, the paper's tables)
                             or "energy" (the low-power registry
                             points); :mod:`repro.autotune` searches
                             the full multi-objective front instead

``to_json``/``from_json`` round-trip losslessly (the throughput
Fraction is carried as an exact "num/den" string), so BENCH artifacts
and CI runs can embed full design provenance and recompile the very
same design later.
"""
from __future__ import annotations

import dataclasses
import json
from fractions import Fraction

#: single owner of the TP quantization bound: the spec quantizes with
#: exactly the denominator plan_throughput will use, so a spec's
#: throughput always equals its compiled plan's.
from repro.core.planner import MAX_TP_DENOMINATOR, OBJECTIVES

_BACKENDS = ("auto", "core", "kernel", "fused")
_SPEC_VERSION = 1


class DesignError(ValueError):
    """A spec that cannot be compiled into a design."""


class TimingError(DesignError):
    """No planner design meets the spec's clock target."""


class LatencyError(DesignError):
    """The design's pipeline depth exceeds the spec's latency budget."""


@dataclasses.dataclass(frozen=True)
class DesignSpec:
    """Declarative multiplier-bank design point (see module docstring)."""
    bits_a: int
    bits_b: int
    throughput: Fraction
    clock_ns: float | None = None       # target clock period (None=relaxed)
    latency_budget: int | None = None   # max latency in cycles
    strict_timing: bool = False
    signed: bool = False
    scheduler: str = "round_robin"
    backend: str = "auto"               # auto | core | kernel | fused
    replicas: int = 1                   # bank replicas over a mesh axis
    mesh_axis: str = "data"
    objective: str = "area"             # planner ranking: area | energy

    def __post_init__(self):
        tp = Fraction(self.throughput).limit_denominator(MAX_TP_DENOMINATOR)
        object.__setattr__(self, "throughput", tp)
        if self.bits_a < 1 or self.bits_b < 1:
            raise DesignError("operand widths must be >= 1 bit")
        if tp <= 0:
            raise DesignError(f"throughput must be positive, got {tp}")
        if self.clock_ns is not None and self.clock_ns <= 0:
            raise DesignError(f"clock_ns must be positive, got {self.clock_ns}")
        if self.latency_budget is not None and self.latency_budget < 1:
            raise DesignError("latency_budget must be >= 1 cycle")
        if self.backend not in _BACKENDS:
            raise DesignError(f"backend must be one of {_BACKENDS}")
        if self.replicas < 1:
            raise DesignError("replicas must be >= 1")
        if self.objective not in OBJECTIVES:
            raise DesignError(f"objective must be one of {OBJECTIVES}")

    # ------------------------------------------------------------ builders
    @classmethod
    def at_fmax(cls, bits_a: int, bits_b: int, throughput,
                fmax_ghz: float, **kw) -> "DesignSpec":
        """Spec from a clock-*frequency* target instead of a period."""
        if fmax_ghz <= 0:
            raise DesignError(f"fmax_ghz must be positive, got {fmax_ghz}")
        return cls(bits_a, bits_b, throughput, clock_ns=1.0 / fmax_ghz, **kw)

    # ------------------------------------------------------- serialization
    def to_dict(self) -> dict:
        """JSON-safe dict; the exact inverse of :meth:`from_dict`."""
        return {
            "version": _SPEC_VERSION,
            "bits_a": self.bits_a,
            "bits_b": self.bits_b,
            "throughput": f"{self.throughput.numerator}/"
                          f"{self.throughput.denominator}",
            "clock_ns": self.clock_ns,
            "latency_budget": self.latency_budget,
            "strict_timing": self.strict_timing,
            "signed": self.signed,
            "scheduler": self.scheduler,
            "backend": self.backend,
            "replicas": self.replicas,
            "mesh_axis": self.mesh_axis,
            "objective": self.objective,
        }

    @classmethod
    def from_dict(cls, d: dict) -> "DesignSpec":
        d = dict(d)
        version = d.pop("version", _SPEC_VERSION)
        if version > _SPEC_VERSION:
            raise DesignError(f"spec version {version} is newer than this "
                              f"library's {_SPEC_VERSION}")
        if "fmax_ghz" in d and d.get("clock_ns") is None:
            d["clock_ns"] = 1.0 / float(d.pop("fmax_ghz"))
        else:
            d.pop("fmax_ghz", None)
        return cls(throughput=Fraction(d.pop("throughput")), **d)

    def to_json(self) -> str:
        return json.dumps(self.to_dict(), sort_keys=True)

    @classmethod
    def from_json(cls, s: str) -> "DesignSpec":
        return cls.from_dict(json.loads(s))

    # ------------------------------------------------------------- display
    def describe(self) -> str:
        parts = [f"{self.bits_a}x{self.bits_b}b", f"TP={self.throughput}"]
        if self.clock_ns is not None:
            parts.append(f"clock={self.clock_ns}ns")
        if self.latency_budget is not None:
            parts.append(f"latency<={self.latency_budget}cy")
        if self.strict_timing:
            parts.append("strict")
        if self.signed:
            parts.append("signed")
        if self.replicas > 1:
            parts.append(f"x{self.replicas}@{self.mesh_axis}")
        if self.objective != "area":
            parts.append(f"obj={self.objective}")
        return "DesignSpec(" + " ".join(parts) + ")"
