"""Named-design registry: reusable, provenance-carrying design points.

``register(name, spec)`` publishes a :class:`~.spec.DesignSpec` under a
stable name; ``get(name)`` / ``generate(name)`` recompiles it anywhere
(benchmarks, CI, serving) with full provenance.  The paper's Table-VIII
"best design per width/timing" points and the Sec. V-E use-case banks
ship pre-registered, so e.g. ``designs.generate("tp3p5_w32")`` is the
headline TP=3.5 deployment story in one call.
"""
from __future__ import annotations

from fractions import Fraction

from .spec import DesignSpec

_REGISTRY: dict = {}


def register(name: str, spec: DesignSpec, *,
             overwrite: bool = False) -> DesignSpec:
    """Publish ``spec`` under ``name`` (refuses silent redefinition)."""
    if not overwrite and name in _REGISTRY and _REGISTRY[name] != spec:
        raise ValueError(f"design {name!r} is already registered with a "
                         f"different spec; pass overwrite=True to replace")
    _REGISTRY[name] = spec
    return spec


def get(name: str) -> DesignSpec:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(f"unknown design {name!r}; registered: "
                         f"{sorted(_REGISTRY)}") from None


def names() -> tuple:
    """Registered design names, sorted."""
    return tuple(sorted(_REGISTRY))


# ---------------------------------------------------------- paper designs
# Table VIII: the paper's best design per (width, timing) row.  Strict
# rows carry their clock target so generate() reproduces the table's
# timing-aware selection; relaxed rows leave the clock unconstrained.
TABLE_VIII = {
    "tbl8_w8_relaxed": DesignSpec(8, 8, Fraction(1, 2)),
    "tbl8_w16_strict": DesignSpec(16, 16, Fraction(1, 2), clock_ns=0.31,
                                  strict_timing=True),
    "tbl8_w16_relaxed": DesignSpec(16, 16, Fraction(1, 2)),
    "tbl8_w32_strict": DesignSpec(32, 32, Fraction(1, 2), clock_ns=0.31,
                                  strict_timing=True),
    "tbl8_w32_relaxed": DesignSpec(32, 32, Fraction(1, 2)),
    "tbl8_w128_strict": DesignSpec(128, 128, Fraction(1, 3), clock_ns=0.80,
                                   strict_timing=True),
}

# Sec. V-B / V-E use-case banks (the fractional-throughput stories).
# Naming: "p" is a decimal point (tp3p5 = 3.5); exact fractions spell
# out the division (tp5over6 = 5/6) to avoid misreading 5/6 as 5.6.
USE_CASES = {
    "tp3p5_w32": DesignSpec(32, 32, Fraction(7, 2)),
    "tp5over6_w128": DesignSpec(128, 128, Fraction(5, 6)),
}

# Low-power companions to the Table-VIII rows: the best-ENERGY design
# per width at TP=1/2 (objective="energy" makes generate() rank the
# planner's candidate set by the power model -- the point the
# autotuner's Pareto front puts at its energy-minimal end), covering
# the paper's 8-128 bit energy/peak-power claim (up to 33% / 65%).
LOW_POWER = {
    f"tbl8_w{_b}_lowpower": DesignSpec(_b, _b, Fraction(1, 2),
                                       objective="energy")
    for _b in (8, 16, 32, 64, 128)
}

for _name, _spec in {**TABLE_VIII, **USE_CASES, **LOW_POWER}.items():
    register(_name, _spec)
del _name, _spec
