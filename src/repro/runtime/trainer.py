"""Fault-tolerant distributed training loop.

Production behaviors, all exercised by tests on 1 CPU device and
designed for 1000+ nodes:

  * jit-compiled train step with donated params/optimizer state and
    explicit in/out shardings from the model's logical spec tree;
  * microbatch gradient accumulation (optionally *exact* via the MCIM
    128-bit fixed-point path -- bit-identical for any microbatch order);
  * non-finite-gradient guard: skip the update, count the event
    (protects against transient HW faults / loss spikes);
  * periodic async checkpointing + resume-from-latest (preemption
    recovery); SIGTERM handler requests a final checkpoint;
  * straggler watchdog: per-step wall-time EWMA, steps slower than
    ``straggler_factor``x the EWMA are logged with their step index
    (on real fleets this feeds the scheduler's replacement policy);
  * multi-process bootstrap hook (jax.distributed.initialize) when the
    standard cluster env vars are present.
"""
from __future__ import annotations

import dataclasses
import os
import signal
import time
from typing import Any, Callable, Optional

import numpy as np
import jax
import jax.numpy as jnp

from ..models import base as mbase
from ..models.api import Model
from ..optim import AdamWConfig, init_state, apply_updates
from ..exact import exact_tree_sum
from ..checkpoint import CheckpointManager


@dataclasses.dataclass
class TrainerConfig:
    steps: int = 100
    microbatches: int = 1
    exact_accum: bool = False        # MCIM fixed-point accumulation
    checkpoint_every: int = 50
    checkpoint_dir: str = "/tmp/repro_ckpt"
    keep_checkpoints: int = 3
    log_every: int = 10
    straggler_factor: float = 3.0
    skip_nonfinite: bool = True


def maybe_init_distributed() -> None:
    """Multi-process bootstrap (no-op single-process)."""
    if "JAX_COORDINATOR_ADDRESS" in os.environ and \
            jax.process_count() == 1:
        jax.distributed.initialize(
            coordinator_address=os.environ["JAX_COORDINATOR_ADDRESS"],
            num_processes=int(os.environ.get("JAX_NUM_PROCESSES", "1")),
            process_id=int(os.environ.get("JAX_PROCESS_ID", "0")))


def make_train_step(model: Model, opt_cfg: AdamWConfig, mesh=None,
                    microbatches: int = 1, exact_accum: bool = False):
    """Build the jitted (params, opt_state, batch) -> ... step."""

    def loss_fn(params, batch):
        return model.train_loss(params, batch, mesh)

    def step_fn(params, opt_state, batch):
        if microbatches == 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        else:
            def micro(i):
                mb = jax.tree_util.tree_map(
                    lambda x: x.reshape((microbatches,
                                         x.shape[0] // microbatches)
                                        + x.shape[1:])[i], batch)
                return jax.value_and_grad(loss_fn)(params, mb)
            pairs = [micro(i) for i in range(microbatches)]
            losses = [p[0] for p in pairs]
            gs = [p[1] for p in pairs]
            if exact_accum:
                grads = exact_tree_sum(gs)
                grads = jax.tree_util.tree_map(
                    lambda g: g / microbatches, grads)
            else:
                grads = jax.tree_util.tree_map(
                    lambda *x: sum(x) / microbatches, *gs)
            loss = sum(losses) / microbatches

        gnorm_sq = sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                       for g in jax.tree_util.tree_leaves(grads))
        finite = jnp.isfinite(gnorm_sq) & jnp.isfinite(loss)

        new_params, new_opt, stats = apply_updates(params, grads,
                                                   opt_state, opt_cfg)
        # non-finite guard: keep old state, bump step anyway
        keep = lambda new, old: jax.tree_util.tree_map(
            lambda n, o: jnp.where(finite, n, o), new, old)
        new_params = keep(new_params, params)
        new_opt = keep(
            {k: v for k, v in new_opt.items() if k != "step"},
            {k: v for k, v in opt_state.items() if k != "step"})
        new_opt["step"] = opt_state["step"] + 1
        stats = dict(stats, loss=loss, finite=finite)
        return new_params, new_opt, stats

    if mesh is None:
        return jax.jit(step_fn, donate_argnums=(0, 1))

    pspecs = model.param_specs(mesh)
    from jax.sharding import NamedSharding, PartitionSpec as P
    ns = lambda spec: NamedSharding(mesh, spec)
    param_sh = jax.tree_util.tree_map(ns, pspecs)
    opt_sh = {"step": ns(P()),
              "m": param_sh, "v": param_sh}
    data_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    batch_sh = ns(P(data_axes))
    return jax.jit(
        step_fn,
        in_shardings=(param_sh, opt_sh, batch_sh),
        out_shardings=(param_sh, opt_sh, None),
        donate_argnums=(0, 1))


@dataclasses.dataclass
class TrainResult:
    losses: list
    skipped_steps: int
    straggler_steps: list
    final_step: int


def train(model: Model, source, opt_cfg: AdamWConfig,
          tcfg: TrainerConfig, mesh=None, params=None,
          resume: bool = True, seed: int = 0) -> TrainResult:
    maybe_init_distributed()
    ckpt = CheckpointManager(tcfg.checkpoint_dir, keep=tcfg.keep_checkpoints)

    if params is None:
        params = model.init(jax.random.PRNGKey(seed))
    opt_state = init_state(params)
    start_step = 0

    if resume and ckpt.latest_step() is not None:
        s = ckpt.latest_step()
        tree = ckpt.restore(s, {"params": params, "opt": opt_state})
        params, opt_state = tree["params"], tree["opt"]
        start_step = s
        print(f"[trainer] resumed from step {s}")

    step_fn = make_train_step(model, opt_cfg, mesh, tcfg.microbatches,
                              tcfg.exact_accum)

    stop = {"now": False}

    def _sigterm(signum, frame):   # preemption notice
        stop["now"] = True
    old_handler = signal.signal(signal.SIGTERM, _sigterm)

    losses, stragglers = [], []
    skipped = 0
    ewma = None
    step = start_step
    try:
        for step in range(start_step, tcfg.steps):
            t0 = time.perf_counter()
            batch = source.batch_at(step)
            from ..data.pipeline import device_batch
            batch = device_batch(batch, mesh)
            params, opt_state, stats = step_fn(params, opt_state, batch)
            loss = float(stats["loss"])
            if not bool(stats["finite"]):
                skipped += 1
            losses.append(loss)
            dt = time.perf_counter() - t0
            ewma = dt if ewma is None else 0.9 * ewma + 0.1 * dt
            if dt > tcfg.straggler_factor * ewma and step > start_step + 2:
                stragglers.append(step)
                print(f"[trainer] straggler step {step}: "
                      f"{dt:.2f}s vs EWMA {ewma:.2f}s")
            if tcfg.log_every and step % tcfg.log_every == 0:
                print(f"[trainer] step {step} loss {loss:.4f} "
                      f"gnorm {float(stats['grad_norm']):.3f} {dt:.2f}s")
            if tcfg.checkpoint_every and \
                    (step + 1) % tcfg.checkpoint_every == 0:
                ckpt.save_async(step + 1,
                                {"params": params, "opt": opt_state})
            if stop["now"]:
                print(f"[trainer] SIGTERM at step {step}; checkpointing")
                break
        ckpt.wait()
        ckpt.save(step + 1, {"params": params, "opt": opt_state})
    finally:
        signal.signal(signal.SIGTERM, old_handler)
    return TrainResult(losses=losses, skipped_steps=skipped,
                       straggler_steps=stragglers, final_step=step + 1)
