from .trainer import TrainerConfig, train, make_train_step, TrainResult

__all__ = ["TrainerConfig", "train", "make_train_step", "TrainResult"]
