"""Int8 serving/compression built on the MCIM int8 matmul kernel."""
from ..kernels.int8_matmul import quantized_matmul, quantize_rows

__all__ = ["quantized_matmul", "quantize_rows"]
