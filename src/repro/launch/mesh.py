"""Production mesh construction.

A FUNCTION, not a module-level constant: importing this module never
touches jax device state (the dry-run must set
--xla_force_host_platform_device_count before first jax init).

Production topology (TPU v5e):
  single pod : (16, 16)    -> ("data", "model")   = 256 chips
  multi-pod  : (2, 16, 16) -> ("pod", "data", "model") = 512 chips
The "pod" axis carries only data parallelism (+ gradient reduction) --
the cross-pod links are the slowest, so no tensor-parallel collective
ever crosses them.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model_parallel: int = 1):
    """Mesh over whatever devices exist (tests / single-host training)."""
    n = len(jax.devices())
    assert n % model_parallel == 0
    return jax.make_mesh((n // model_parallel, model_parallel),
                         ("data", "model"))


def data_axes(mesh) -> tuple:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)
