"""Sharding resolution for runtime state (caches, tokens, optimizer).

Parameters get their specs from the model template (models.base).  This
module covers the remaining state that exists only at run time, with
divisibility-checked fallbacks:

  attention KV caches (..., B, S, KV, hd):
      B -> (pod, data) when divisible, else S -> data (long-context,
      batch=1 decode shards the *cache sequence* across the data axis),
      KV -> model when divisible.
  ssm conv cache (..., B, W, CH):   B -> data axes, CH -> model
  ssm state      (..., B, H, N, P): B -> data axes, H -> model
  tokens/pos     (B, ...):          B -> data axes
"""
from __future__ import annotations

import math

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from .mesh import data_axes


def _size(mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, tuple):
        return math.prod(mesh.shape[a] for a in axes)
    return mesh.shape[axes]


def _div(dim, mesh, axes):
    if not (axes and dim % _size(mesh, axes) == 0):
        return None
    # PartitionSpec equality distinguishes 'data' from ('data',): collapse
    # single-axis tuples to the bare name so specs compare as documented.
    if isinstance(axes, tuple) and len(axes) == 1:
        return axes[0]
    return axes


def batch_spec(mesh, ndim: int, batch_dim_size: int) -> P:
    da = data_axes(mesh)
    first = _div(batch_dim_size, mesh, da)
    return P(*((first,) + (None,) * (ndim - 1)))


def bank_batch_spec(mesh, axis: str, ndim: int, batch_dim_size: int) -> P:
    """Spec for a multiplier-bank batch replicated along one mesh axis.

    Unlike :func:`batch_spec` (which silently replicates when the batch
    does not divide), bank replicas each need an equal shard, so
    non-divisible batches are an error, not a fallback."""
    if axis not in mesh.axis_names:
        raise ValueError(f"axis {axis!r} not in mesh axes {mesh.axis_names}")
    if batch_dim_size % mesh.shape[axis]:
        raise ValueError(
            f"batch {batch_dim_size} not divisible by mesh axis "
            f"{axis!r} size {mesh.shape[axis]}")
    return P(*((axis,) + (None,) * (ndim - 1)))


def attn_cache_spec(mesh, shape) -> P:
    """shape: (*prefix, B, S, KV, hd).

    B -> data axes; if B=1 (long-context decode) the cache *sequence*
    shards across data instead.  The model axis takes KV heads when they
    divide, else head_dim (GQA models routinely have kv < model-axis
    size; without the hd fallback a 32B model's 32k cache is 68
    GB/device and cannot fit)."""
    b, s, kv, hd = shape[-4:]
    prefix = (None,) * (len(shape) - 4)
    da = data_axes(mesh)
    b_ax = _div(b, mesh, da)
    s_ax = None
    if b_ax is None:
        s_ax = _div(s, mesh, "data" if "data" in mesh.axis_names else None)
    model = "model" if "model" in mesh.axis_names else None
    kv_ax = _div(kv, mesh, model)
    hd_ax = None
    if kv_ax is None:
        hd_ax = _div(hd, mesh, model)
    return P(*(prefix + (b_ax, s_ax, kv_ax, hd_ax)))


def ssm_conv_spec(mesh, shape) -> P:
    b, _, ch = shape[-3:]
    prefix = (None,) * (len(shape) - 3)
    b_ax = _div(b, mesh, data_axes(mesh))
    ch_ax = _div(ch, mesh, "model" if "model" in mesh.axis_names else None)
    return P(*(prefix + (b_ax, None, ch_ax)))


def ssm_state_spec(mesh, shape) -> P:
    b, h, _, _ = shape[-4:]
    prefix = (None,) * (len(shape) - 4)
    b_ax = _div(b, mesh, data_axes(mesh))
    h_ax = _div(h, mesh, "model" if "model" in mesh.axis_names else None)
    return P(*(prefix + (b_ax, h_ax, None, None)))


def cache_specs(cache_tree, mesh):
    """PartitionSpec tree for a cache ShapeDtypeStruct tree (path-keyed)."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(cache_tree)
    out = []
    for path, leaf in flat:
        keys = [str(getattr(k, "key", k)) for k in path]
        last = keys[-1]
        if last in ("k", "v"):
            out.append(attn_cache_spec(mesh, leaf.shape))
        elif last in ("k_scale", "v_scale"):
            # (*prefix, B, S, KV): same layout minus the head_dim axis
            spec = attn_cache_spec(mesh, leaf.shape + (1,))
            out.append(P(*spec[:-1]))
        elif last == "conv":
            out.append(ssm_conv_spec(mesh, leaf.shape))
        elif last == "state":
            out.append(ssm_state_spec(mesh, leaf.shape))
        else:
            raise ValueError(f"unknown cache leaf {keys}")
    return jax.tree_util.tree_unflatten(treedef, out)


def named(mesh, spec_tree):
    return jax.tree_util.tree_map(lambda s: NamedSharding(mesh, s),
                                  spec_tree)


def batch_shardings(batch_specs_tree, mesh):
    """NamedShardings for a train/prefill input-spec dict."""
    out = {}
    for k, sds in batch_specs_tree.items():
        out[k] = NamedSharding(mesh,
                               batch_spec(mesh, len(sds.shape),
                                          sds.shape[0]))
    return out
