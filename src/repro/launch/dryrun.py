import os
os.environ["XLA_FLAGS"] = os.environ.get(
    "REPRO_DRYRUN_XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST run before any jax-importing import: jax locks
the device count at first init, and the production meshes need 512
placeholder host devices.  (Smoke tests / benches never import this
module, so they see 1 device.)

Per cell this script:
  1. builds the production mesh (16x16 or 2x16x16),
  2. constructs the jitted step (train_step / prefill / serve_step) with
     explicit in/out shardings from the model's logical spec trees,
  3. ``.lower(**input_specs).compile()`` -- ShapeDtypeStruct only, no
     arrays are ever allocated,
  4. records memory_analysis(), cost_analysis(), and the collective
     schedule parsed from the optimized HLO into a JSON artifact for
     EXPERIMENTS.md §Dry-run / §Roofline.

Usage:
  python -m repro.launch.dryrun --arch qwen3-32b --shape train_4k \
      --mesh pod1 --out experiments/dryrun
  python -m repro.launch.dryrun --list        # enumerate runnable cells
"""
import argparse
import json
import sys
import time

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_config, SHAPES, ARCH_NAMES, cell_runnable, SKIPS
from repro.models import build_model
from repro.models.api import Model
from repro.optim import AdamWConfig
from repro.runtime import make_train_step
from repro.launch.mesh import make_production_mesh, data_axes
from repro.launch import sharding as shd
from repro.launch import roofline, hlo_cost


def _sds(tree):
    return jax.tree_util.tree_map(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype), tree)


def build_train(model: Model, shape, mesh):
    step = make_train_step(model, AdamWConfig(), mesh)
    params = model.abstract_params()
    opt = {"step": jax.ShapeDtypeStruct((), jnp.int32),
           "m": jax.tree_util.tree_map(
               lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32), params),
           "v": jax.tree_util.tree_map(
               lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32), params)}
    batch = model.train_input_specs(shape)
    return step, (params, opt, batch)


def build_prefill(model: Model, shape, mesh):
    batch = model.prefill_input_specs(shape)
    pspecs = model.param_specs(mesh)
    ns = lambda t: jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), t)
    in_sh = (ns(pspecs), shd.batch_shardings(batch, mesh))
    s_cap = shape.seq_len

    def fn(params, inputs):
        return model.prefill(params, inputs, mesh, s_cap=s_cap)

    if model.cfg.family == "encoder":
        out_sh = None
    else:
        cache_sds = model.cache_spec(shape.global_batch, s_cap)
        out_sh = (ns(shd.cache_specs(cache_sds, mesh)),
                  NamedSharding(mesh, shd.batch_spec(
                      mesh, 2, shape.global_batch)))
    step = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh)
    return step, (model.abstract_params(), batch)


def build_decode(model: Model, shape, mesh):
    b, s_cap = shape.global_batch, shape.seq_len
    pspecs = model.param_specs(mesh)
    ns = lambda t: jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), t)
    cache_sds = _sds(model.cache_spec(b, s_cap))
    cache_sh = ns(shd.cache_specs(cache_sds, mesh))
    tok_sh = NamedSharding(mesh, shd.batch_spec(mesh, 1, b))

    def fn(params, caches, token, pos):
        return model.decode_step(params, caches, token, pos, mesh)

    step = jax.jit(
        fn,
        in_shardings=(ns(pspecs), cache_sh, tok_sh, tok_sh),
        out_shardings=(cache_sh,
                       NamedSharding(mesh, shd.batch_spec(mesh, 2, b))),
        donate_argnums=(1,))
    args = (model.abstract_params(), cache_sds,
            jax.ShapeDtypeStruct((b,), jnp.int32),
            jax.ShapeDtypeStruct((b,), jnp.int32))
    return step, args


def run_cell(arch: str, shape_name: str, mesh_kind: str,
             overrides: dict | None = None, *, mesh=None,
             shape_cfg=None, smoke: bool = False) -> dict:
    shape = shape_cfg or SHAPES[shape_name]
    if mesh is None:
        mesh = make_production_mesh(multi_pod=(mesh_kind == "pod2"))
    cfg = get_config(arch, smoke=smoke, **(overrides or {}))
    model = build_model(cfg)

    with mesh:
        if shape.kind == "train":
            step, args = build_train(model, shape, mesh)
        elif shape.kind == "prefill":
            step, args = build_prefill(model, shape, mesh)
        else:
            step, args = build_decode(model, shape, mesh)

        t0 = time.perf_counter()
        lowered = step.lower(*args)
        t1 = time.perf_counter()
        compiled = lowered.compile()
        t2 = time.perf_counter()

        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        # jax < 0.4.31 returns a one-element list of dicts; later versions
        # return the dict directly.
        if isinstance(cost, (list, tuple)):
            cost = cost[0] if cost else {}
        hlo = compiled.as_text()

    # Scan-aware accounting from the compiled artifact (hlo_cost): XLA's
    # own cost_analysis counts while bodies once, so scanned layer stacks
    # are undercounted by ~n_layers; hlo_cost propagates trip counts.
    hc = hlo_cost.analyze(hlo)
    coll = hc["collectives"]
    link_bytes = hc["link_bytes"]
    flops = float(hc["flops"])
    raw_flops = float((cost or {}).get("flops", 0.0))
    raw_bytes = float((cost or {}).get("bytes accessed", 0.0))
    # bytes: scale XLA's (loop-undercounted) traffic by the same factor
    # the dot-flops were undercounted -- loop bodies dominate both.
    scale = max(1.0, flops / raw_flops) if raw_flops > 0 else 1.0
    bytes_acc = raw_bytes * scale
    terms = roofline.roofline_terms(flops, bytes_acc, link_bytes)

    n_active = model.active_param_count()
    tokens = (shape.global_batch * shape.seq_len
              if shape.kind in ("train", "prefill") else shape.global_batch)
    mflops = roofline.model_flops(n_active, tokens, shape.kind)

    mem_fields = {}
    if mem is not None:
        for f in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "generated_code_size_in_bytes"):
            try:
                mem_fields[f] = int(getattr(mem, f))
            except Exception:
                pass

    result = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind,
        "kind": shape.kind,
        "n_devices": mesh.size,
        "lower_s": round(t1 - t0, 2),
        "compile_s": round(t2 - t1, 2),
        "params": model.param_count(),
        "active_params": n_active,
        "flops_per_device": flops,
        "bytes_per_device": bytes_acc,
        "raw_cost_analysis": {"flops": raw_flops, "bytes": raw_bytes,
                              "loop_scale": scale},
        "unknown_trip_whiles": hc["unknown_trip_whiles"],
        "collectives": coll,
        "link_bytes_per_device": link_bytes,
        "roofline": terms,
        "model_flops_global": mflops,
        "model_flops_per_device": mflops / mesh.size,
        "useful_flops_ratio": (mflops / mesh.size) / flops if flops else 0.0,
        "memory_analysis": mem_fields,
        "overrides": overrides or {},
    }
    return result


def all_cells():
    for arch in ARCH_NAMES:
        for shape in SHAPES:
            if cell_runnable(arch, shape):
                yield arch, shape


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--mesh", choices=["pod1", "pod2"], default="pod1")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--list", action="store_true")
    ap.add_argument("--override", default="",
                    help="comma k=v config overrides (perf experiments)")
    ap.add_argument("--tag", default="", help="artifact filename suffix")
    args = ap.parse_args()

    if args.list:
        for arch, shape in all_cells():
            print(f"{arch} {shape}")
        for (arch, shape), why in SKIPS.items():
            print(f"SKIP {arch} {shape}: {why}", file=sys.stderr)
        return

    overrides = {}
    for kv in filter(None, args.override.split(",")):
        k, v = kv.split("=")
        overrides[k] = (v if not v.replace("-", "").isdigit() else int(v))
        if v in ("true", "false"):
            overrides[k] = v == "true"

    os.makedirs(args.out, exist_ok=True)
    res = run_cell(args.arch, args.shape, args.mesh, overrides or None)
    tag = f"_{args.tag}" if args.tag else ""
    path = os.path.join(args.out,
                        f"{args.arch}_{args.shape}_{args.mesh}{tag}.json")
    with open(path, "w") as f:
        json.dump(res, f, indent=1)
    r = res["roofline"]
    print(f"OK {args.arch} {args.shape} {args.mesh}: "
          f"compile {res['compile_s']}s "
          f"compute {r['compute_s']:.2e}s memory {r['memory_s']:.2e}s "
          f"collective {r['collective_s']:.2e}s dominant={r['dominant']} "
          f"useful={res['useful_flops_ratio']:.2f}")


if __name__ == "__main__":
    main()
