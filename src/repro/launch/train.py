"""Training launcher.

Runs on whatever devices exist (CPU here, TPU pod in production; with
cluster env vars set, maybe_init_distributed() brings up multi-process
JAX).  Examples:

  # tiny end-to-end on CPU
  PYTHONPATH=src python -m repro.launch.train --arch qwen3-32b --smoke \
      --steps 50 --source pattern

  # ~100M-param run
  PYTHONPATH=src python -m repro.launch.train --arch qwen3-32b \
      --preset 100m --steps 200 --seq-len 256 --global-batch 8
"""
from __future__ import annotations

import argparse
import dataclasses

import jax

from repro.configs import get_config, ARCH_NAMES
from repro.models import build_model
from repro.optim import AdamWConfig
from repro.runtime import TrainerConfig, train
from repro.data import DataConfig, make_source
from repro.launch.mesh import make_host_mesh

# ~100M-parameter preset wiring (applied on top of any arch's family)
PRESET_100M = dict(n_layers=12, d_model=768, n_heads=12, n_kv_heads=4,
                   head_dim=64, d_ff=3072, vocab_size=32000,
                   q_chunk=256, k_chunk=256, ce_chunk=256)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list(ARCH_NAMES), default="qwen3-32b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--preset", choices=["", "100m"], default="")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--exact-accum", action="store_true",
                    help="MCIM 128-bit fixed-point grad accumulation")
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--source", default="pattern",
                    choices=["pattern", "synthetic", "binfile"])
    ap.add_argument("--data-path", default="")
    ap.add_argument("--checkpoint-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--checkpoint-every", type=int, default=50)
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--no-resume", action="store_true")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=args.smoke)
    if args.preset == "100m":
        cfg = dataclasses.replace(get_config(args.arch), **PRESET_100M)
    model = build_model(cfg)
    print(f"[train] arch={cfg.name} params={model.param_count()/1e6:.1f}M "
          f"family={cfg.family}")

    mesh = make_host_mesh(args.model_parallel) \
        if len(jax.devices()) > 1 else None

    data = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq_len,
                      global_batch=args.global_batch, source=args.source,
                      path=args.data_path)
    src = make_source(data, host_index=jax.process_index(),
                      host_count=jax.process_count())

    opt = AdamWConfig(lr=args.lr, warmup_steps=min(20, args.steps // 5),
                      total_steps=args.steps)
    tcfg = TrainerConfig(steps=args.steps,
                         microbatches=args.microbatches,
                         exact_accum=args.exact_accum,
                         checkpoint_every=args.checkpoint_every,
                         checkpoint_dir=args.checkpoint_dir)
    res = train(model, src, opt, tcfg, mesh=mesh, resume=not args.no_resume)
    print(f"[train] done: step={res.final_step} "
          f"loss {res.losses[0]:.3f} -> {res.losses[-1]:.3f} "
          f"skipped={res.skipped_steps} stragglers={len(res.straggler_steps)}")
    return res


if __name__ == "__main__":
    main()
