"""Batched serving driver: continuous-batching-lite over prefill/decode.

A slot manager keeps ``--slots`` concurrent sequences in flight; requests
(prompts) are admitted into free slots in arrival order, prefilled, then
decoded one token per engine step across the whole batch.  Finished
sequences free their slot immediately (continuous batching), and bursts
of same-length arrivals share ONE batched prefill call.

Admissions are recorded as an *arrival trace* (``arrival_trace()``):
the engine cycle each request entered the system, nondecreasing, which
feeds the bank layer's streaming scheduler.  ``--mcim-design`` names a
registered ``repro.designs`` point (default the paper's TP=3.5 bank);
after serving, the trace is replayed through that compiled design so
the run reports how the silicon bank would have dispatched the same
request stream (the ROADMAP's end-to-end async-serving wiring).

  PYTHONPATH=src python -m repro.launch.serve --arch gemma3-1b --smoke \
      --requests 12 --slots 4 --max-new 16 --mcim-design tp3p5_w32
"""
from __future__ import annotations

import argparse
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get_config, ARCH_NAMES
from repro.models import build_model
from repro.rng import random_tokens


class ServeEngine:
    """Fixed-slot continuous batching around prefill + decode_step."""

    def __init__(self, model, params, slots: int, prompt_len: int,
                 s_cap: int, mesh=None):
        self.model, self.params, self.mesh = model, params, mesh
        self.slots = slots
        self.prompt_len = prompt_len
        self.s_cap = s_cap
        self.caches = None
        self.pos = jnp.zeros((slots,), jnp.int32)
        self.cur = jnp.zeros((slots,), jnp.int32)
        self.live = np.zeros((slots,), bool)
        self.outputs = {}          # request_id -> generated tokens
        self.request_of_slot = [-1] * slots
        self.cycle = 0             # engine steps taken (decode cycles)
        self._arrivals = []        # (request_id, admission cycle)
        self._completions = {}     # request_id -> completion cycle
        self._cache_batch_axes = None
        self._decode = jax.jit(
            lambda p, c, t, pos: model.decode_step(p, c, t, pos, mesh),
            donate_argnums=(1,))

    def admit(self, request_id: int, prompt: np.ndarray) -> None:
        self.admit_many([(request_id, prompt)])

    def admit_many(self, requests) -> None:
        """Admit ``[(request_id, prompt)]`` into free slots.

        Requests with equal prompt lengths prefill as ONE batched model
        call: with >= 2 slots free a burst of arrivals costs a single
        prefill instead of one per request (ragged lengths fall back to
        one call per length group).
        """
        if not requests:
            return
        free = [int(s) for s in np.flatnonzero(~self.live)]
        if len(requests) > len(free):
            raise ValueError(
                f"admitting {len(requests)} requests with {len(free)} "
                f"free slots")
        for rid, _ in requests:    # admission cycle, in arrival order;
            # recorded only once capacity is confirmed, so a rejected
            # burst that is retried later cannot corrupt the trace
            self._arrivals.append((rid, self.cycle))
        by_len = {}
        for rid, prompt in requests:
            by_len.setdefault(prompt.shape[0], []).append((rid, prompt))
        for plen, group in by_len.items():
            slots = [free.pop(0) for _ in group]
            tokens = jnp.asarray(np.stack([p for _, p in group]))
            caches, logits = self.model.prefill(
                self.params, {"tokens": tokens}, self.mesh,
                s_cap=self.s_cap)
            toks = jnp.argmax(logits, -1).astype(jnp.int32)
            if self.caches is None:
                self.caches = self._alloc_like(caches)
            for row, (slot, (rid, _)) in enumerate(zip(slots, group)):
                self._write_slot(slot, caches, row=row, rows=len(group))
                self.pos = self.pos.at[slot].set(plen)
                self.cur = self.cur.at[slot].set(toks[row])
                self.live[slot] = True
                self.request_of_slot[slot] = rid
                self.outputs[rid] = [int(toks[row])]

    def _alloc_like(self, caches_b1):
        spec = self.model.cache_spec(self.slots, self.s_cap)
        return jax.tree_util.tree_map(
            lambda s: jnp.zeros(s.shape, s.dtype), spec)

    def _batch_axes(self):
        """Per-cache-leaf batch axis, derived from the model's cache spec:
        the axis whose size tracks the spec's batch argument.  Shape
        matching cannot disambiguate (a stacked layer-group dim can equal
        the slot count); asking the spec can."""
        if self._cache_batch_axes is None:
            s1 = jax.tree_util.tree_leaves(
                self.model.cache_spec(self.slots, self.s_cap))
            s2 = jax.tree_util.tree_leaves(
                self.model.cache_spec(self.slots + 1, self.s_cap))
            axes = []
            for l1, l2 in zip(s1, s2):
                diff = [ax for ax in range(len(l1.shape))
                        if l1.shape[ax] != l2.shape[ax]]
                assert len(diff) == 1, (l1.shape, l2.shape)
                axes.append(diff[0])
            self._cache_batch_axes = axes
        return self._cache_batch_axes

    def _write_slot(self, slot: int, caches_br, row: int = 0,
                    rows: int = 1):
        axes = iter(self._batch_axes())     # tree_map runs in leaf order

        def put(full, batched):
            ax = next(axes)
            assert full.shape[ax] == self.slots and batched.shape[ax] == rows
            idx = [slice(None)] * full.ndim
            idx[ax] = slice(slot, slot + 1)
            src = [slice(None)] * batched.ndim
            src[ax] = slice(row, row + 1)
            return full.at[tuple(idx)].set(batched[tuple(src)])
        self.caches = jax.tree_util.tree_map(put, self.caches, caches_br)

    def arrival_trace(self) -> tuple:
        """Admission cycles of every admitted request, in arrival order.

        Nondecreasing by construction (``cycle`` only grows), so the
        trace feeds straight into the bank layer's streaming scheduler:
        ``StreamingScheduler(arrivals=eng.arrival_trace())`` -- or, via
        the facade, ``designs.generate(name).replay(trace)`` -- dispatches
        one work item per request at its real admission cycle.
        """
        return tuple(cycle for _, cycle in self._arrivals)

    def step(self) -> None:
        self.cycle += 1
        self.caches, logits = self._decode(self.params, self.caches,
                                           self.cur, self.pos)
        nxt = jnp.argmax(logits, -1).astype(jnp.int32)
        self.pos = self.pos + 1
        self.cur = nxt
        for slot in range(self.slots):
            if self.live[slot]:
                self.outputs[self.request_of_slot[slot]].append(
                    int(nxt[slot]))

    def completion_trace(self) -> tuple:
        """Completion cycles aligned with ``arrival_trace()`` (same
        request order), so per-request end-to-end latency is just the
        elementwise difference.  Requests still in flight report -1."""
        return tuple(self._completions.get(rid, -1)
                     for rid, _ in self._arrivals)

    def latency_trace(self) -> tuple:
        """Per-request end-to-end engine cycles (admission to finish),
        in arrival order; in-flight requests are excluded."""
        return tuple(done - arr for (_, arr), done
                     in zip(self._arrivals, self.completion_trace())
                     if done >= 0)

    def finish(self, slot: int) -> None:
        rid = self.request_of_slot[slot]
        if rid >= 0:
            self._completions[rid] = self.cycle
        self.live[slot] = False
        self.request_of_slot[slot] = -1


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list(ARCH_NAMES), default="gemma3-1b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--mcim-design", default="tp3p5_w32",
                    help="registered repro.designs name to replay the "
                         "admission trace through ('none' to skip)")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=args.smoke)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    s_cap = args.prompt_len + args.max_new + 8
    eng = ServeEngine(model, params, args.slots, args.prompt_len, s_cap)

    prompts = [np.asarray(random_tokens(7, r, jnp.arange(args.prompt_len,
                                                         dtype=jnp.uint32),
                                        cfg.vocab_size))
               for r in range(args.requests)]
    t0 = time.perf_counter()
    next_req = 0
    done = 0
    new_counts = {}
    while done < args.requests:
        # admit all pending requests that fit into free slots at once:
        # they share one batched prefill instead of a model call each
        n_free = int(eng.slots - eng.live.sum())
        pending = []
        while next_req < args.requests and len(pending) < n_free:
            pending.append((next_req, prompts[next_req]))
            new_counts[next_req] = 0
            next_req += 1
        eng.admit_many(pending)
        eng.step()
        for slot in range(args.slots):
            rid = eng.request_of_slot[slot]
            if rid >= 0:
                new_counts[rid] += 1
                if new_counts[rid] >= args.max_new:
                    eng.finish(slot)
                    done += 1
    dt = time.perf_counter() - t0
    total_tokens = sum(len(o) for o in eng.outputs.values())
    print(f"[serve] {args.requests} requests, {total_tokens} tokens "
          f"in {dt:.1f}s ({total_tokens/dt:.1f} tok/s)")
    if args.mcim_design != "none":
        # end-to-end wiring: the real admission trace drives the bank
        # layer's streaming scheduler through the designs facade
        from repro import designs
        from repro.core.bank import histogram_percentile, latency_histogram
        design = designs.generate(args.mcim_design)
        rep = design.replay(eng.arrival_trace())
        print(f"[serve] mcim replay of {len(eng.arrival_trace())} "
              f"admissions over {eng.cycle} engine cycles through "
              f"{design.plan.describe()}: makespan {rep.cycles} bank "
              f"cycles, {rep.measured_throughput} ops/cycle "
              f"(scheduler={rep.scheduler})")
        # end-to-end latency, both sides of the wiring: what the engine
        # measured (admission -> finish) and what the bank's replay
        # attributes to dispatch (admission -> retire), one accounting
        # path (core.bank.schedule histograms) for both
        eng_hist = latency_histogram(eng.latency_trace())
        print(f"[serve] engine latency p50/p99 = "
              f"{histogram_percentile(eng_hist, 0.50)}/"
              f"{histogram_percentile(eng_hist, 0.99)} engine cycles; "
              f"bank replay latency p50/p99 = "
              f"{rep.latency_p50}/{rep.latency_p99} bank cycles")
    return eng


if __name__ == "__main__":
    main()
