"""Launch layer: production meshes, dry-run, train/serve CLIs.

NOTE: never import .dryrun from here -- it mutates XLA_FLAGS on import
(by design, for the 512-device placeholder mesh).
"""
from .mesh import make_production_mesh, make_host_mesh, data_axes
from . import sharding, roofline

__all__ = ["make_production_mesh", "make_host_mesh", "data_axes",
           "sharding", "roofline"]
