"""Roofline-term computation from compiled dry-run artifacts.

Hardware model (TPU v5e):
  peak bf16 compute : 197 TFLOP/s per chip
  HBM bandwidth     : 819 GB/s per chip
  ICI link bandwidth: ~50 GB/s per link

Terms (seconds, per step, per chip -- cost_analysis of the SPMD
executable is already per-device):

  compute    = flops_per_device / PEAK_FLOPS
  memory     = bytes_per_device / HBM_BW
  collective = collective_link_bytes_per_device / ICI_BW

collective_link_bytes uses ring-cost accounting per op type: an
all-reduce of R result bytes moves 2R(k-1)/k per device; an all-gather
of R result bytes moves R(k-1)/k; reduce-scatter R(k-1)/k of its operand
(= result*k); all-to-all R(k-1)/k; collective-permute R.
"""
from __future__ import annotations

import re

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"=\s+((?:\([^)]*\))|(?:[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?))\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(text: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(text):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def parse_collectives(hlo_text: str) -> dict:
    """Per-collective-type {count, result_bytes, link_bytes} from HLO."""
    out = {c: {"count": 0, "result_bytes": 0, "link_bytes": 0.0}
           for c in _COLLECTIVES}
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        result_text, op = m.group(1), m.group(2)
        rbytes = _shape_bytes(result_text)
        k = 1
        gm = _GROUPS_RE.search(line)
        if gm:
            k = len(gm.group(1).split(","))
        else:
            gi = _GROUPS_IOTA_RE.search(line)
            if gi:
                k = int(gi.group(2))
        if k <= 1 and op != "collective-permute":
            continue
        frac = (k - 1) / max(k, 1)
        if op == "all-reduce":
            link = 2.0 * rbytes * frac
        elif op == "all-gather":
            link = rbytes * frac
        elif op == "reduce-scatter":
            link = rbytes * k * frac
        elif op == "all-to-all":
            link = rbytes * frac
        else:                         # collective-permute
            link = float(rbytes)
        out[op]["count"] += 1
        out[op]["result_bytes"] += rbytes
        out[op]["link_bytes"] += link
    return out


def roofline_terms(flops_per_device: float, bytes_per_device: float,
                   link_bytes_per_device: float) -> dict:
    compute = flops_per_device / PEAK_FLOPS
    memory = bytes_per_device / HBM_BW
    collective = link_bytes_per_device / ICI_BW
    dominant = max(("compute", compute), ("memory", memory),
                   ("collective", collective), key=lambda kv: kv[1])[0]
    bound = max(compute, memory, collective)
    return {
        "compute_s": compute,
        "memory_s": memory,
        "collective_s": collective,
        "dominant": dominant,
        "step_lower_bound_s": bound,
        # fraction of the bound that is pure compute == roofline fraction
        # achievable if the dominant term were fully overlapped
        "compute_fraction": compute / bound if bound > 0 else 0.0,
    }


def model_flops(n_active_params: int, tokens: int, kind: str) -> float:
    """6ND for training, 2ND for forward-only (per the assignment)."""
    mult = 6.0 if kind == "train" else 2.0
    return mult * n_active_params * tokens


# ------------------------------------------------------------ launch counting

def _count_pallas_eqns(jaxpr) -> int:
    """Recursively count ``pallas_call`` equations in a jaxpr."""
    from repro.verify import jaxpr_walk
    return jaxpr_walk.count_primitive(jaxpr, "pallas_call")


def count_pallas_launches(fn, *args) -> int:
    """Kernel launches one call of ``fn(*args)`` issues.

    Traces ``fn`` (no execution) and counts ``pallas_call`` primitives
    recursively through nested jaxprs (jit/closed-call bodies) via the
    shared ``verify.jaxpr_walk`` traversal.  This is the dispatch-tax
    metric of the fused-bank work: a per-instance bank round costs one
    launch per busy instance, the fused megakernel exactly one.
    """
    import jax
    closed = jax.make_jaxpr(fn)(*args)
    return _count_pallas_eqns(closed.jaxpr)
