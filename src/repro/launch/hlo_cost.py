"""Scan-aware cost analysis of optimized HLO text.

XLA's HloCostAnalysis (and therefore compiled.cost_analysis()) counts
each while-loop body ONCE, so any scan-over-layers program is
undercounted by ~n_layers.  This module parses the optimized HLO text
into its computation call graph, propagates execution multipliers
through ``while`` ops using their known_trip_count backend configs, and
accumulates:

  * dot FLOPs   -- 2 * prod(result dims) * prod(contracted dims), per
                   dot, times the enclosing computation's multiplier
                   (matmul-dominated programs: this IS the compute term)
  * convolution FLOPs (same treatment, from the dot-like dims)
  * collective traffic -- per-op ring-cost link bytes (see
    launch.roofline), times multiplier

Everything is derived from the compiled artifact itself; no analytic
model of the architecture is involved.
"""
from __future__ import annotations

import dataclasses
import re

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "token": 0,
    "s4": 1, "u4": 1,
}

_COMP_HEADER = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->")
_OP_LINE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*"
    r"((?:\([^)]*\))|(?:[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?))\s+"
    r"([\w\-]+)")
_SHAPE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_TRIP = re.compile(r'known_trip_count[^}]*?"n"\s*:\s*"?(\d+)')
_CALL_ATTRS = ("body", "condition", "calls", "to_apply")
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_dims(text: str):
    """First array shape in text -> (dtype, [dims])."""
    m = _SHAPE.search(text)
    if not m:
        return None, []
    dims = [int(d) for d in m.group(2).split(",")] if m.group(2) else []
    return m.group(1), dims


def _shape_bytes_all(text: str) -> int:
    total = 0
    for dtype, dims in _SHAPE.findall(text):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in (dims.split(",") if dims else []):
            n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


@dataclasses.dataclass
class _Op:
    name: str
    shape_text: str
    opcode: str
    line: str


def _parse_computations(hlo: str) -> dict:
    comps = {}
    cur = None
    for raw in hlo.splitlines():
        line = raw.rstrip()
        if cur is None:
            m = _COMP_HEADER.match(line.strip())
            if m and line.rstrip().endswith("{"):
                cur = m.group(2)
                comps[cur] = {"ops": [], "entry": bool(m.group(1))}
            continue
        if line.strip() == "}":
            cur = None
            continue
        m = _OP_LINE.match(line)
        if m:
            comps[cur]["ops"].append(
                _Op(m.group(1), m.group(2), m.group(3), line))
    return comps


def _callees(op: _Op):
    out = []
    for attr in _CALL_ATTRS:
        for m in re.finditer(attr + r"=%?([\w.\-]+)", op.line):
            out.append((attr, m.group(1)))
    m = re.search(r"branch_computations=\{([^}]*)\}", op.line)
    if m:
        for name in m.group(1).split(","):
            out.append(("branch", name.strip().lstrip("%")))
    return out


def _multipliers(comps: dict) -> tuple:
    """Execution multiplier per computation: topological propagation over
    the call DAG (HLO computations cannot recurse)."""
    unknown_trips = 0
    edges = {n: [] for n in comps}          # caller -> [(callee, weight)]
    for name, c in comps.items():
        for op in c["ops"]:
            if op.opcode == "while":
                t = _TRIP.search(op.line)
                trip = int(t.group(1)) if t else 1
                if not t:
                    unknown_trips += 1
                for attr, callee in _callees(op):
                    if callee not in comps:
                        continue
                    w = trip if attr == "body" else (
                        trip + 1 if attr == "condition" else 1)
                    edges[name].append((callee, float(w)))
            else:
                for attr, callee in _callees(op):
                    if callee in comps:
                        edges[name].append((callee, 1.0))

    indeg = {n: 0 for n in comps}
    for src, outs in edges.items():
        for callee, _ in outs:
            indeg[callee] += 1
    entry = next((n for n, c in comps.items() if c["entry"]), None)
    mult = {n: 0.0 for n in comps}
    if entry is not None:
        mult[entry] = 1.0
    else:                                   # no ENTRY marker: roots = indeg 0
        for n, d in indeg.items():
            if d == 0:
                mult[n] = 1.0
    ready = [n for n, d in indeg.items() if d == 0]
    while ready:
        n = ready.pop()
        for callee, w in edges[n]:
            mult[callee] += mult[n] * w
            indeg[callee] -= 1
            if indeg[callee] == 0:
                ready.append(callee)
    return mult, unknown_trips


def _dot_flops(op: _Op, shapes: dict) -> float:
    _, rdims = _shape_dims(op.shape_text)
    rprod = 1.0
    for d in rdims:
        rprod *= d
    # contracting dims from lhs shape
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", op.line)
    cdims = [int(x) for x in m.group(1).split(",")] if m and m.group(1) \
        else []
    ops_m = re.search(op.opcode + r"\(([^)]*)\)", op.line)
    contract = 1.0
    if ops_m and cdims:
        operands = ops_m.group(1)
        # Operands may be shape-prefixed ("f32[64,128]{1,0} %Arg_0.1") or
        # bare ("%Arg_0.1"); the lhs name is the first %token either way.
        lhs_text = None
        pm = re.search(r"%([\w.\-]+)", operands)
        if pm:
            lhs_text = shapes.get(pm.group(1))
        if lhs_text is None and _SHAPE.search(operands):
            lhs_text = operands       # fall back to the embedded lhs shape
        if lhs_text:
            _, ldims = _shape_dims(lhs_text)
            for c in cdims:
                if c < len(ldims):
                    contract *= ldims[c]
    return 2.0 * rprod * contract


def _conv_flops(op: _Op, shapes: dict) -> float:
    _, rdims = _shape_dims(op.shape_text)
    rprod = 1.0
    for d in rdims:
        rprod *= d
    m = re.search(r"window=\{size=([0-9x]+)", op.line)
    k = 1.0
    if m:
        for d in m.group(1).split("x"):
            k *= int(d)
    return 2.0 * rprod * k


def _collective_link_bytes(op: _Op) -> tuple:
    rbytes = _shape_bytes_all(op.shape_text)
    k = 1
    gm = _GROUPS_RE.search(op.line)
    if gm:
        k = len(gm.group(1).split(","))
    else:
        gi = _GROUPS_IOTA_RE.search(op.line)
        if gi:
            k = int(gi.group(2))
    base = op.opcode.replace("-start", "")
    if k <= 1 and base != "collective-permute":
        return base, rbytes, 0.0
    frac = (k - 1) / max(k, 1)
    if base == "all-reduce":
        link = 2.0 * rbytes * frac
    elif base == "all-gather":
        link = rbytes * frac
    elif base == "reduce-scatter":
        link = rbytes * k * frac
    elif base == "all-to-all":
        link = rbytes * frac
    else:
        link = float(rbytes)
    return base, rbytes, link


def analyze(hlo: str) -> dict:
    """Full scan-aware cost summary of an optimized HLO module."""
    comps = _parse_computations(hlo)
    mult, unknown_trips = _multipliers(comps)
    shapes = {}
    for name, c in comps.items():
        for op in c["ops"]:
            shapes[op.name] = op.shape_text

    dot_flops = 0.0
    conv_flops = 0.0
    colls = {c: {"count": 0.0, "result_bytes": 0.0, "link_bytes": 0.0}
             for c in _COLLECTIVES}
    for name, c in comps.items():
        m = mult.get(name, 0.0)
        if m == 0.0:
            continue
        for op in c["ops"]:
            if op.opcode == "dot":
                dot_flops += m * _dot_flops(op, shapes)
            elif op.opcode == "convolution":
                conv_flops += m * _conv_flops(op, shapes)
            else:
                base = op.opcode.replace("-start", "")
                if base in _COLLECTIVES and not op.opcode.endswith("-done"):
                    kind, rbytes, link = _collective_link_bytes(op)
                    colls[kind]["count"] += m
                    colls[kind]["result_bytes"] += m * rbytes
                    colls[kind]["link_bytes"] += m * link

    return {
        "dot_flops": dot_flops,
        "conv_flops": conv_flops,
        "flops": dot_flops + conv_flops,
        "collectives": colls,
        "link_bytes": sum(c["link_bytes"] for c in colls.values()),
        "unknown_trip_whiles": unknown_trips,
        "n_computations": len(comps),
    }
