"""Decoder-only transformer family: dense, gemma-style local/global, MoE.

Layer-stack structure: layers are grouped into repeating *pattern groups*
(e.g. gemma3's 5 local + 1 global); the stack is a ``lax.scan`` over
groups, so HLO size stays O(one group) regardless of depth -- essential
for compiling 64-layer models against a 512-device mesh.  Heterogeneous
members inside a group are unrolled (at most 6).

Modes:
  train    -- full-sequence forward, chunked CE loss
  prefill  -- full-sequence forward, returns KV caches + last logits
  decode   -- one token per call against the caches (ring buffers for
              sliding-window layers)
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp

from . import base
from .base import Param, constrain
from .attention import (flash_attention, decode_attention,
                        decode_attention_int8,
                        flash_attention_context_parallel)
from ..configs.base import ArchConfig


# ------------------------------------------------------------------ helpers

def _axis_size(mesh, name):
    if mesh is None or name not in mesh.axis_names:
        return 1
    return mesh.shape[name]


def constrain_act(x, mesh):
    return constrain(x, mesh, "batch", *([None] * (x.ndim - 1)))


def constrain_heads(x, mesh, fallback: str = "hd"):
    """(B, S, H, hd): shard heads on model if divisible; else fall back
    to head_dim ("hd") or replication ("replicate").

    The fallback matters: hd-sharding makes every QK^T contraction a
    psum (collective storm for kv=1 archs like gemma3/paligemma);
    replication trades that for one activation all-gather per layer
    (§Perf iteration)."""
    if mesh is None:
        return x
    m = _axis_size(mesh, "model")
    if x.shape[-2] % m == 0:
        return constrain(x, mesh, "batch", None, "model", None)
    if fallback == "hd" and x.shape[-1] % m == 0:
        return constrain(x, mesh, "batch", None, None, "model")
    return constrain_act(x, mesh)


def constrain_kv(x, mesh, fallback: str = "hd"):
    """(B, S, KV, hd): kv heads on model when divisible.  When they are
    not (GQA kv < model size), "hd" leaves the layout to XLA (it
    inherits wk's column sharding => per-score-block psum -- the
    gemma3/paligemma baseline), while "replicate" forces replication so
    the whole attention loop is collective-free (§Perf iteration)."""
    if mesh is None:
        return x
    m = _axis_size(mesh, "model")
    if x.shape[-2] % m == 0:
        return constrain(x, mesh, "batch", None, "model", None)
    if fallback in ("replicate", "seq"):
        return constrain_act(x, mesh)
    return x


def group_pattern(cfg: ArchConfig):
    """(k_local, has_global, n_groups, n_tail_local) for the layer stack."""
    if cfg.local_per_global is None:
        return 0, True, cfg.n_layers, 0
    size = cfg.local_per_global + 1
    return (cfg.local_per_global, True, cfg.n_layers // size,
            cfg.n_layers % size)


def layer_theta(cfg: ArchConfig, kind: str) -> float:
    if kind == "global" and cfg.rope_theta_global is not None:
        return cfg.rope_theta_global
    return cfg.rope_theta


# ------------------------------------------------------------------ templates

def attn_template(cfg: ArchConfig) -> dict:
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    t = {
        "norm": Param((d,), (None,), init="zeros"),
        "wq": Param((d, h * hd), ("fsdp", "model")),
        "wk": Param((d, kv * hd), ("fsdp", "model")),
        "wv": Param((d, kv * hd), ("fsdp", "model")),
        "wo": Param((h * hd, d), ("model", "fsdp"), init="scaled"),
    }
    if cfg.qk_norm:
        t["q_norm"] = Param((hd,), (None,), init="zeros")
        t["k_norm"] = Param((hd,), (None,), init="zeros")
    return t


def mlp_template(cfg: ArchConfig) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    return {
        "norm": Param((d,), (None,), init="zeros"),
        "w_gate": Param((d, f), ("fsdp", "model")),
        "w_up": Param((d, f), ("fsdp", "model")),
        "w_down": Param((f, d), ("model", "fsdp"), init="scaled"),
    }


def layer_template(cfg: ArchConfig) -> dict:
    from . import moe as moe_mod
    t = {"attn": attn_template(cfg)}
    if cfg.family == "moe":
        t["moe"] = moe_mod.moe_template(cfg)
    else:
        t["mlp"] = mlp_template(cfg)
    return t


def lm_templates(cfg: ArchConfig) -> dict:
    k_local, has_global, n_groups, n_tail = group_pattern(cfg)
    group = {}
    if k_local:
        group["local"] = base.stack(layer_template(cfg), k_local)
    if has_global:
        group["global"] = layer_template(cfg)
    tpl = {
        "embed": Param((cfg.padded_vocab, cfg.d_model), ("model", "fsdp")),
        "final_norm": Param((cfg.d_model,), (None,), init="zeros"),
        "groups": base.stack(group, n_groups, "layers"),
    }
    if n_tail:
        tpl["tail"] = base.stack(layer_template(cfg), n_tail, "layers")
    if not cfg.tie_embeddings:
        tpl["unembed"] = Param((cfg.d_model, cfg.padded_vocab),
                               ("fsdp", "model"))
    return tpl


# ------------------------------------------------------------------ caches

def attn_cache_spec(cfg: ArchConfig, batch: int, s_cap: int, kind: str):
    cap = min(cfg.window, s_cap) if kind == "local" else s_cap
    kv, hd = cfg.n_kv_heads, cfg.head_dim
    shp = (batch, cap, kv, hd)
    if cfg.kv_cache_dtype == "int8":
        # MCIM int8 KV cache (§Perf): halves the dominant decode HBM
        # traffic; per-(pos, head) f32 scales.
        return {"k": jax.ShapeDtypeStruct(shp, jnp.int8),
                "v": jax.ShapeDtypeStruct(shp, jnp.int8),
                "k_scale": jax.ShapeDtypeStruct(shp[:3], jnp.float32),
                "v_scale": jax.ShapeDtypeStruct(shp[:3], jnp.float32)}
    return {"k": jax.ShapeDtypeStruct(shp, jnp.bfloat16),
            "v": jax.ShapeDtypeStruct(shp, jnp.bfloat16)}


def _quant_kv(x):
    """Symmetric int8 over head_dim. x: (..., hd) -> (int8, f32 scale)."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1)
    scale = jnp.where(amax == 0, 1.0, amax / 127.0)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, scale


def _dequant_kv(q, scale):
    return (q.astype(jnp.float32) * scale[..., None]).astype(jnp.bfloat16)


def lm_cache_spec(cfg: ArchConfig, batch: int, s_cap: int):
    """ShapeDtypeStruct tree mirroring the layer-group structure."""
    k_local, has_global, n_groups, n_tail = group_pattern(cfg)

    def stack_spec(spec, n):
        return jax.tree_util.tree_map(
            lambda s: jax.ShapeDtypeStruct((n,) + s.shape, s.dtype), spec)

    group = {}
    if k_local:
        group["local"] = stack_spec(attn_cache_spec(cfg, batch, s_cap,
                                                    "local"), k_local)
    if has_global:
        group["global"] = attn_cache_spec(cfg, batch, s_cap, "global")
    tree = {"groups": stack_spec(group, n_groups)}
    if n_tail:
        tree["tail"] = stack_spec(attn_cache_spec(cfg, batch, s_cap,
                                                  "local"), n_tail)
    return tree


def init_cache(cfg: ArchConfig, batch: int, s_cap: int):
    return jax.tree_util.tree_map(lambda s: jnp.zeros(s.shape, s.dtype),
                                  lm_cache_spec(cfg, batch, s_cap))


# ------------------------------------------------------------------ layers

def attn_apply(p, x, cfg: ArchConfig, mesh, kind: str, mode: str,
               positions=None, pos=None, cache=None, prefix_len=None,
               mask_override=None):
    """Returns (y, new_cache).  Keys are roped before caching."""
    b, s, d = x.shape
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    theta = layer_theta(cfg, kind)
    xn = base.rms_norm(x, p["norm"], cfg.norm_eps)
    q = xn @ p["wq"]
    k = xn @ p["wk"]
    v = xn @ p["wv"]
    q = constrain_heads(q.reshape(b, s, h, hd), mesh, cfg.attn_fallback)
    k = constrain_kv(k.reshape(b, s, kv, hd), mesh, cfg.attn_fallback)
    v = constrain_kv(v.reshape(b, s, kv, hd), mesh, cfg.attn_fallback)
    if cfg.qk_norm:
        q = base.rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = base.rms_norm(k, p["k_norm"], cfg.norm_eps)

    if mode == "decode":
        q = base.rope(q, pos[:, None].astype(jnp.float32), theta)
        k = base.rope(k, pos[:, None].astype(jnp.float32), theta)
        cap = cache["k"].shape[1]
        slot = pos % cap if kind == "local" else pos
        bidx = jnp.arange(b)
        if kind == "local":
            valid = jnp.arange(cap)[None, :] < jnp.minimum(pos + 1, cap)[:, None]
        else:
            valid = jnp.arange(cap)[None, :] <= pos[:, None]
        if cfg.kv_cache_dtype == "int8":
            qk, sk = _quant_kv(k[:, 0])
            qv, sv = _quant_kv(v[:, 0])
            new_cache = {
                "k": cache["k"].at[bidx, slot].set(qk),
                "v": cache["v"].at[bidx, slot].set(qv),
                "k_scale": cache["k_scale"].at[bidx, slot].set(sk),
                "v_scale": cache["v_scale"].at[bidx, slot].set(sv),
            }
            # integer-domain attention: int8 reads end to end, scales
            # deferred to the end (PPM -> compressor -> final adder).
            o = decode_attention_int8(
                q, new_cache["k"], new_cache["k_scale"],
                new_cache["v"], new_cache["v_scale"], valid,
                logit_cap=cfg.attn_logit_cap)
        else:
            new_cache = {"k": cache["k"].at[bidx, slot].set(k[:, 0]),
                         "v": cache["v"].at[bidx, slot].set(v[:, 0])}
            o = decode_attention(q, new_cache["k"], new_cache["v"], valid,
                                 logit_cap=cfg.attn_logit_cap)
    else:
        q = base.rope(q, positions.astype(jnp.float32), theta)
        k = base.rope(k, positions.astype(jnp.float32), theta)
        # re-pin after rope: the (hd-sharded) prefill cache layout would
        # otherwise back-propagate into the roped k and turn every QK
        # score block into a psum over the model axis.
        q = constrain_heads(q, mesh, cfg.attn_fallback)
        k = constrain_kv(k, mesh, cfg.attn_fallback)
        mask_kind = ("local" if kind == "local"
                     else ("prefix" if prefix_len is not None else "causal"))
        if mask_override is not None:
            mask_kind = mask_override
        use_cp = (cfg.attn_fallback == "seq" and mesh is not None
                  and "model" in mesh.axis_names
                  and s % max(_axis_size(mesh, "model"), 1) == 0
                  and h % _axis_size(mesh, "model") != 0)
        if use_cp:
            o = flash_attention_context_parallel(
                q, k, v, mesh, mask_kind=mask_kind, window=cfg.window,
                prefix_len=prefix_len, logit_cap=cfg.attn_logit_cap,
                q_chunk=cfg.q_chunk, k_chunk=cfg.k_chunk)
        else:
            o = flash_attention(
                q, k, v, mask_kind=mask_kind, window=cfg.window,
                prefix_len=prefix_len, logit_cap=cfg.attn_logit_cap,
                q_chunk=cfg.q_chunk, k_chunk=cfg.k_chunk,
                schedule=cfg.attn_schedule)
        new_cache = None
        if mode == "prefill":
            cap = cache["k"].shape[1]
            if cfg.kv_cache_dtype == "int8":
                k_store, ks = _quant_kv(k)
                v_store, vs = _quant_kv(v)
            else:
                k_store, v_store, ks, vs = k, v, None, None

            def write(buf, val, slots=None):
                if slots is not None:
                    return buf.at[:, slots].set(val)
                return jax.lax.dynamic_update_slice_in_dim(buf, val, 0,
                                                           axis=1)

            slots = None
            if kind == "local" and s >= cap:
                slots = jnp.arange(s - cap, s) % cap
                k_store = k_store[:, s - cap:]
                v_store = v_store[:, s - cap:]
                if ks is not None:
                    ks, vs = ks[:, s - cap:], vs[:, s - cap:]
            new_cache = {"k": write(cache["k"], k_store, slots),
                         "v": write(cache["v"], v_store, slots)}
            if ks is not None:
                new_cache["k_scale"] = write(cache["k_scale"], ks, slots)
                new_cache["v_scale"] = write(cache["v_scale"], vs, slots)

    o = o.reshape(b, s, h * hd)
    if cfg.attn_fallback in ("replicate", "seq") and mesh is not None \
            and h % _axis_size(mesh, "model") != 0:
        # pin the attention output too: otherwise GSPMD back-propagates
        # wo's row sharding INTO the flash loop and re-shards the QK/PV
        # contractions (one psum per chunk pair -- the baseline storm).
        o = constrain_act(o, mesh)
    y = o @ p["wo"]
    return constrain_act(x + y, mesh), new_cache


def mlp_apply(p, x, cfg: ArchConfig, mesh):
    xn = base.rms_norm(x, p["norm"], cfg.norm_eps)
    y = base.swiglu(xn, p["w_gate"], p["w_up"], p["w_down"])
    return constrain_act(x + y, mesh)


def layer_apply(p, x, cfg: ArchConfig, mesh, kind, mode, **kw):
    from . import moe as moe_mod
    aux = jnp.float32(0.0)
    x, new_cache = attn_apply(p["attn"], x, cfg, mesh, kind, mode, **kw)
    if cfg.family == "moe":
        x, aux = moe_mod.moe_apply(p["moe"], x, cfg, mesh,
                                   decode=(mode == "decode"))
    else:
        x = mlp_apply(p["mlp"], x, cfg, mesh)
    return x, new_cache, aux


# ------------------------------------------------------------------ stack

def _tree_idx(tree, i):
    return jax.tree_util.tree_map(lambda a: a[i], tree)


def _tree_stack(trees):
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *trees)


def _group_apply(gp, x, cfg, mesh, mode, cache=None, **kw):
    """One pattern group: k_local local layers + optional global layer."""
    k_local = 0
    if "local" in gp:
        k_local = jax.tree_util.tree_leaves(gp["local"])[0].shape[0]
    new_cache = {}
    aux_total = jnp.float32(0.0)
    locals_new = []
    for i in range(k_local):
        c_i = _tree_idx(cache["local"], i) if cache is not None else None
        x, nc, aux = layer_apply(_tree_idx(gp["local"], i), x, cfg, mesh,
                                 "local", mode, cache=c_i, **kw)
        aux_total += aux
        if nc is not None:
            locals_new.append(nc)
    if locals_new:
        new_cache["local"] = _tree_stack(locals_new)
    if "global" in gp:
        c_g = cache["global"] if cache is not None else None
        x, nc, aux = layer_apply(gp["global"], x, cfg, mesh, "global", mode,
                                 cache=c_g, **kw)
        aux_total += aux
        if nc is not None:
            new_cache["global"] = nc
    return x, (new_cache or None), aux_total


def stack_apply(params, x, cfg: ArchConfig, mesh, mode, caches=None, **kw):
    """Scan the grouped layer stack. Returns (x, new_caches, aux_loss)."""
    use_cache = mode in ("prefill", "decode")

    def group_body(carry, xs):
        xc, aux = carry
        gp, c = xs if use_cache else (xs, None)
        xc, nc, a = _group_apply(gp, xc, cfg, mesh, mode, cache=c, **kw)
        return (xc, aux + a), nc

    body = group_body
    if cfg.remat and mode == "train":
        body = jax.checkpoint(group_body)

    xs = (params["groups"], caches["groups"]) if use_cache \
        else params["groups"]
    (x, aux), group_caches = jax.lax.scan(body, (x, jnp.float32(0.0)), xs)

    new_caches = {"groups": group_caches} if use_cache else None
    if "tail" in params:
        def tail_body(carry, xs):
            xc, aux = carry
            p, c = xs if use_cache else (xs, None)
            xc, nc, a = layer_apply(p, xc, cfg, mesh, "local", mode,
                                    cache=c, **kw)
            return (xc, aux + a), nc
        tb = tail_body
        if cfg.remat and mode == "train":
            tb = jax.checkpoint(tail_body)
        xs = (params["tail"], caches["tail"]) if use_cache else params["tail"]
        (x, aux), tail_caches = jax.lax.scan(tb, (x, aux), xs)
        if use_cache:
            new_caches["tail"] = tail_caches
    return x, new_caches, aux


# ------------------------------------------------------------------ LM API

def embed_tokens(params, tokens, cfg: ArchConfig, mesh, scale: bool):
    x = jnp.take(params["embed"], tokens, axis=0)
    if scale:
        x = x * jnp.sqrt(jnp.float32(cfg.d_model)).astype(x.dtype)
    return constrain_act(x, mesh)


def unembed_matrix(params, cfg: ArchConfig):
    if cfg.tie_embeddings:
        return params["embed"].T
    return params["unembed"]


_EMBED_SCALE_FAMILIES = ("gemma",)


def lm_train_loss(params, batch, cfg: ArchConfig, mesh=None,
                  embed_scale: bool = False, prefix_len=None):
    tokens, labels = batch["tokens"], batch["labels"]
    mask = batch.get("mask")
    if mask is None:
        mask = jnp.ones_like(labels, jnp.float32)
    b, s = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    x = embed_tokens(params, tokens, cfg, mesh, embed_scale)
    x, _, aux = stack_apply(params, x, cfg, mesh, "train",
                            positions=positions, prefix_len=prefix_len)
    x = base.rms_norm(x, params["final_norm"], cfg.norm_eps)
    w = unembed_matrix(params, cfg)
    ce = base.cross_entropy_chunked(
        lambda xs: xs @ w, x, labels, mask, cfg.padded_vocab,
        chunk=cfg.ce_chunk, final_cap=cfg.final_logit_cap, mesh=mesh)
    if cfg.family == "moe":
        ce = ce + cfg.router_aux_coef * aux / cfg.n_layers
    return ce


def lm_prefill(params, tokens, cfg: ArchConfig, mesh=None, s_cap=None,
               embed_scale: bool = False, prefix_len=None):
    """Returns (caches, last_token_logits)."""
    b, s = tokens.shape
    s_cap = s_cap or cfg.max_seq
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    caches = init_cache(cfg, b, s_cap)
    x = embed_tokens(params, tokens, cfg, mesh, embed_scale)
    x, caches, _ = stack_apply(params, x, cfg, mesh, "prefill",
                               caches=caches, positions=positions,
                               prefix_len=prefix_len)
    x = base.rms_norm(x[:, -1:], params["final_norm"], cfg.norm_eps)
    logits = base.softcap(x @ unembed_matrix(params, cfg),
                          cfg.final_logit_cap)
    return caches, logits[:, 0]


def lm_decode_step(params, caches, token, pos, cfg: ArchConfig, mesh=None,
                   embed_scale: bool = False):
    """token: (B,) int32, pos: (B,) int32. Returns (caches, logits (B,V))."""
    x = embed_tokens(params, token[:, None], cfg, mesh, embed_scale)
    x, caches, _ = stack_apply(params, x, cfg, mesh, "decode",
                               caches=caches, pos=pos)
    x = base.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = base.softcap(x @ unembed_matrix(params, cfg),
                          cfg.final_logit_cap)
    return caches, logits[:, 0]
