"""Mixture-of-Experts blocks (dbrx 16e top-4, llama4-scout 16e top-1).

Two dispatch paths:

  * training / prefill: **expert-choice** routing (Zhou et al. 2022) --
    each expert selects its top-C tokens (C = T * top_k / E), giving
    static shapes, perfect load balance, and no token-dropping
    pathologies on TPU.  (Deviation from the released models' token-
    choice routing, recorded in DESIGN.md §Arch-applicability.)
  * decode: dense token-choice top-k combine -- with one token per
    sequence the expert weights dominate the cost anyway, and the dense
    path preserves the released models' routing semantics exactly.

Expert weights are sharded expert-major on the model axis (EP); the
token gather/scatter across the data axis is the collective hot spot
analysed in EXPERIMENTS.md §Perf.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import base
from .base import Param, constrain
from ..configs.base import ArchConfig


def moe_template(cfg: ArchConfig) -> dict:
    d, e, fe = cfg.d_model, cfg.n_experts, cfg.d_ff_expert
    t = {
        "norm": Param((d,), (None,), init="zeros"),
        "router": Param((d, e), ("fsdp", None), dtype=jnp.float32),
        "w_gate": Param((e, d, fe), ("model", "fsdp", None)),
        "w_up": Param((e, d, fe), ("model", "fsdp", None)),
        "w_down": Param((e, fe, d), ("model", None, "fsdp"), init="scaled"),
    }
    if cfg.n_shared_experts:
        f = cfg.d_ff * cfg.n_shared_experts
        t["shared"] = {
            "w_gate": Param((d, f), ("fsdp", "model")),
            "w_up": Param((d, f), ("fsdp", "model")),
            "w_down": Param((f, d), ("model", "fsdp"), init="scaled"),
        }
    return t


def _expert_ffn(xg, p):
    """xg: (E, C, D) tokens grouped per expert -> (E, C, D)."""
    g = jnp.einsum("ecd,edf->ecf", xg, p["w_gate"])
    u = jnp.einsum("ecd,edf->ecf", xg, p["w_up"])
    h = jax.nn.silu(g.astype(jnp.float32)).astype(xg.dtype) * u
    return jnp.einsum("ecf,efd->ecd", h, p["w_down"])


def moe_apply(p, x, cfg: ArchConfig, mesh, decode: bool = False):
    """Returns (x + moe(x), router_z_loss)."""
    b, s, d = x.shape
    xn = base.rms_norm(x, p["norm"], cfg.norm_eps)
    logits = xn.astype(jnp.float32) @ p["router"]          # (B, S, E)
    zloss = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)

    if decode or b * s <= 4 * cfg.n_experts:
        y = _dense_token_choice(p, xn, logits, cfg)
    elif cfg.moe_local_dispatch and mesh is not None:
        y = _expert_choice_local(p, xn, logits, cfg, mesh)
    else:
        y = _expert_choice(p, xn, logits, cfg, mesh)

    if cfg.n_shared_experts:
        y = y + base.swiglu(xn, p["shared"]["w_gate"], p["shared"]["w_up"],
                            p["shared"]["w_down"])
    return constrain(x + y.astype(x.dtype), mesh,
                     "batch", None, None), zloss


def _dense_token_choice(p, xn, logits, cfg: ArchConfig):
    """All-experts compute + sparse top-k combine (decode path)."""
    topv, topi = jax.lax.top_k(logits, cfg.top_k)          # (B, S, K)
    if cfg.top_k == 1:
        gates = jax.nn.sigmoid(topv)                       # llama4-style
    else:
        gates = jax.nn.softmax(topv, axis=-1)              # dbrx-style
    # combine weights (B, S, E)
    onehot = jax.nn.one_hot(topi, cfg.n_experts, dtype=jnp.float32)
    w = jnp.einsum("bske,bsk->bse", onehot, gates)
    g = jnp.einsum("bsd,edf->bsef", xn, p["w_gate"])
    u = jnp.einsum("bsd,edf->bsef", xn, p["w_up"])
    h = jax.nn.silu(g.astype(jnp.float32)).astype(xn.dtype) * u
    y = jnp.einsum("bsef,efd->bsed", h, p["w_down"])
    return jnp.einsum("bsed,bse->bsd", y, w.astype(y.dtype))


def _expert_choice(p, xn, logits, cfg: ArchConfig, mesh):
    """Expert-choice dispatch: top-C tokens per expert, C = T*top_k/E."""
    b, s, d = xn.shape
    t = b * s
    e = cfg.n_experts
    c = max(1, (t * cfg.top_k) // e)
    xf = xn.reshape(t, d)
    affin = jax.nn.softmax(logits.reshape(t, e), axis=-1)  # (T, E)
    gate, idx = jax.lax.top_k(affin.T, c)                  # (E, C)
    xg = jnp.take(xf, idx, axis=0)                         # (E, C, D) gather
    xg = constrain(xg, mesh, "model", "fsdp", None)
    y = _expert_ffn(xg, p)
    y = constrain(y, mesh, "model", "fsdp", None)
    y = y * gate[..., None].astype(y.dtype)
    out = jnp.zeros((t, d), y.dtype).at[idx.reshape(-1)].add(
        y.reshape(e * c, d))
    return out.reshape(b, s, d)


def _expert_choice_local(p, xn, logits, cfg: ArchConfig, mesh):
    """Shard-local expert choice (§Perf iteration for collective-bound
    MoE training).

    The global-EC gather/scatter address the full token range, which
    GSPMD can only partition by all-gathering the (T, D) activations --
    the dominant collective in the dbrx/llama4 baselines.  Here routing
    is decided *within each data shard*: tokens reshape to
    (n_data_shards, T/shards) aligned with the batch sharding, each
    shard's experts pick top-C/shards of its own tokens, and the
    gather/scatter become batched ops that are parallel over the
    sharded group axis (no data movement).  Cross-device traffic reduces
    to resharding the picked (G, E, C_l, D) block from group-major to
    expert-major -- an all-to-all instead of repeated all-gathers.
    """
    from .transformer import _axis_size
    b, s, d = xn.shape
    t = b * s
    e = cfg.n_experts
    g = 1
    for ax in ("pod", "data"):
        g *= _axis_size(mesh, ax)
    if g <= 1 or t % g or b % g:
        return _expert_choice(p, xn, logits, cfg, mesh)
    tl = t // g
    cl = max(1, (tl * cfg.top_k) // e)
    xg = xn.reshape(g, tl, d)
    xg = constrain(xg, mesh, "batch", None, None)
    affin = jax.nn.softmax(logits.reshape(g, tl, e), axis=-1)
    gate, idx = jax.lax.top_k(jnp.swapaxes(affin, 1, 2), cl)   # (G, E, Cl)
    picked = jnp.take_along_axis(xg[:, None], idx[..., None], axis=2)
    picked = constrain(picked, mesh, "batch", "model", None, None)
    gq = jnp.einsum("gecd,edf->gecf", picked, p["w_gate"])
    up = jnp.einsum("gecd,edf->gecf", picked, p["w_up"])
    h = jax.nn.silu(gq.astype(jnp.float32)).astype(picked.dtype) * up
    y = jnp.einsum("gecf,efd->gecd", h, p["w_down"])
    y = constrain(y, mesh, "batch", "model", None, None)
    y = y * gate[..., None].astype(y.dtype)
    out = jnp.zeros((g, tl, d), y.dtype)
    gidx = jnp.arange(g)[:, None, None]
    out = out.at[gidx, idx].add(y)
    # D-sharded combine: the EP-combine reduction becomes a
    # reduce-scatter (each model rank keeps D/n) + a bf16 all-gather,
    # instead of a full f32 all-reduce of (T, D) -- ~25% less link
    # traffic (JAX promotes bf16 scatter-add to f32, doubling the AR).
    out = constrain(out, mesh, "batch", None, "model")
    out = constrain(out.astype(xn.dtype), mesh, "batch", None, None)
    return out.reshape(b, s, d)
