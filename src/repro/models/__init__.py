"""Model zoo: the 10 assigned architectures across 6 families."""
from . import base, attention, transformer, moe, ssm, hybrid, encoder, vlm
from .api import Model, build_model

__all__ = ["base", "attention", "transformer", "moe", "ssm", "hybrid",
           "encoder", "vlm", "Model", "build_model"]
