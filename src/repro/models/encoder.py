"""HuBERT-style encoder-only backbone (masked-prediction objective).

The conv/audio frontend is a STUB per the assignment: input_specs()
provides precomputed frame embeddings (B, T, d_frontend); a learned
projection lifts them to d_model.  The backbone is a bidirectional
transformer (mask_kind="none"); the loss is cross-entropy on masked
frames against a small codebook vocabulary (504 units).

Encoder-only => no KV cache and no decode step; the decode_* shapes are
skipped for this arch (DESIGN.md §Arch-applicability).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import base
from .base import Param
from . import transformer as tfm
from ..configs.base import ArchConfig

D_FRONTEND = 512          # conv-frontend output width (w2v2/HuBERT standard)


def encoder_templates(cfg: ArchConfig) -> dict:
    layer = {"attn": tfm.attn_template(cfg), "mlp": tfm.mlp_template(cfg)}
    return {
        "frame_proj": Param((D_FRONTEND, cfg.d_model), (None, "fsdp")),
        "mask_embed": Param((cfg.d_model,), (None,)),
        "layers": base.stack(layer, cfg.n_layers, "layers"),
        "final_norm": Param((cfg.d_model,), (None,), init="zeros"),
        "lm_head": Param((cfg.d_model, cfg.padded_vocab), ("fsdp", "model")),
    }


def _encode(params, frames, mask, cfg: ArchConfig, mesh):
    b, s, _ = frames.shape
    x = frames.astype(jnp.bfloat16) @ params["frame_proj"]
    if mask is not None:
        x = jnp.where(mask[..., None], params["mask_embed"], x)
    x = base.constrain(x, mesh, "batch", None, None)
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))

    def body(carry, p):
        xc, _ = carry
        xc, _, _ = tfm.layer_apply(p, xc, cfg, mesh, "global", "train",
                                   positions=positions,
                                   mask_override="none")
        return (xc, jnp.float32(0.0)), None

    fn = jax.checkpoint(body) if cfg.remat else body
    (x, _), _ = jax.lax.scan(fn, (x, jnp.float32(0.0)), params["layers"])
    return base.rms_norm(x, params["final_norm"], cfg.norm_eps)


def encoder_train_loss(params, batch, cfg: ArchConfig, mesh=None):
    """batch: frames (B,T,512) bf16, mask (B,T) bool, labels (B,T) int32."""
    frames, mask, labels = batch["frames"], batch["mask"], batch["labels"]
    x = _encode(params, frames, mask, cfg, mesh)
    loss_mask = mask.astype(jnp.float32)          # predict only masked frames
    return base.cross_entropy_chunked(
        lambda xs: xs @ params["lm_head"], x, labels, loss_mask,
        cfg.padded_vocab, chunk=cfg.ce_chunk, mesh=mesh)


def encoder_forward(params, frames, cfg: ArchConfig, mesh=None):
    """Serving path: full-sequence unit logits (B, T, V)."""
    x = _encode(params, frames, None, cfg, mesh)
    return x @ params["lm_head"]
