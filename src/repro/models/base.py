"""Parameter templates, sharding specs, and common NN primitives.

A model is described by a *template*: a nested dict whose leaves are
``Param`` descriptors carrying shape, dtype, logical sharding axes, and
an initializer.  From one template we derive, with guaranteed matching
tree structure:

  * init_params(template, key)        -> pytree of arrays
  * abstract_params(template)         -> pytree of ShapeDtypeStruct
  * spec_tree(template, mesh)         -> pytree of PartitionSpec

Logical axis names are resolved against the physical mesh by
``resolve_logical``: "batch" -> all data-parallel axes, "model" -> the
tensor-parallel axis, "fsdp" -> the data axis (parameter sharding), with
divisibility checks that silently fall back to replication where a dim
does not divide (e.g. 4 attention heads on a 16-way model axis).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class Param:
    shape: tuple
    logical: tuple               # logical axis name (or None) per dim
    dtype: Any = jnp.bfloat16
    init: str = "normal"         # normal | zeros | ones | scaled
    scale: float = 0.02

    def __post_init__(self):
        assert len(self.shape) == len(self.logical), (self.shape, self.logical)


def is_param(x) -> bool:
    return isinstance(x, Param)


def _tree_map(f, template):
    return jax.tree_util.tree_map(f, template, is_leaf=is_param)


def _initializer(p: Param, key):
    if p.init == "zeros":
        return jnp.zeros(p.shape, p.dtype)
    if p.init == "ones":
        return jnp.ones(p.shape, p.dtype)
    if p.init == "scaled":        # variance-scaled for output projections
        fan_in = p.shape[-2] if len(p.shape) >= 2 else p.shape[-1]
        std = 1.0 / math.sqrt(max(fan_in, 1))
        return (jax.random.normal(key, p.shape, jnp.float32) * std
                ).astype(p.dtype)
    return (jax.random.normal(key, p.shape, jnp.float32) * p.scale
            ).astype(p.dtype)


def init_params(template, key):
    leaves, treedef = jax.tree_util.tree_flatten(template, is_leaf=is_param)
    keys = jax.random.split(key, len(leaves))
    vals = [_initializer(p, k) for p, k in zip(leaves, keys)]
    return jax.tree_util.tree_unflatten(treedef, vals)


def abstract_params(template):
    return _tree_map(lambda p: jax.ShapeDtypeStruct(p.shape, p.dtype), template)


def stack(template, n: int, axis_name: str | None = None):
    """Prepend a stacking (layer) axis to every Param in the template."""
    return _tree_map(
        lambda p: Param((n,) + p.shape, (axis_name,) + p.logical,
                        p.dtype, p.init, p.scale),
        template)


def param_count(template) -> int:
    leaves = jax.tree_util.tree_leaves(template, is_leaf=is_param)
    return sum(math.prod(p.shape) for p in leaves)


# ------------------------------------------------------------------ sharding

def mesh_axes(mesh) -> dict:
    """Map logical axis names -> physical mesh axes for this mesh."""
    names = mesh.axis_names
    data_axes = tuple(a for a in ("pod", "data") if a in names)
    return {
        "batch": data_axes if len(data_axes) != 1 else data_axes[0],
        "fsdp": "data" if "data" in names else None,
        "model": "model" if "model" in names else None,
        "seq": None,            # overridden to "data" for long-ctx caches
        "seq_data": data_axes if len(data_axes) != 1 else data_axes[0],
        None: None,
    }


def _axis_size(mesh, phys) -> int:
    if phys is None:
        return 1
    if isinstance(phys, tuple):
        return math.prod(mesh.shape[a] for a in phys)
    return mesh.shape[phys]


def resolve_logical(logical: tuple, shape: tuple, mesh) -> P:
    """Logical axes -> PartitionSpec with divisibility fallback."""
    table = mesh_axes(mesh)
    out = []
    for dim, name in zip(shape, logical):
        phys = table.get(name)
        if phys is None or dim % _axis_size(mesh, phys) != 0:
            out.append(None)
        else:
            out.append(phys)
    return P(*out)


def spec_tree(template, mesh):
    return _tree_map(lambda p: resolve_logical(p.logical, p.shape, mesh),
                     template)


def shard_tree(tree, specs, mesh):
    """NamedSharding pytree for jit in_shardings / device_put."""
    from jax.sharding import NamedSharding
    return jax.tree_util.tree_map(lambda s: NamedSharding(mesh, s), specs)


def constrain(x, mesh, *logical):
    """with_sharding_constraint via logical axis names (no-op off-mesh)."""
    if mesh is None:
        return x
    spec = resolve_logical(tuple(logical), x.shape, mesh)
    return jax.lax.with_sharding_constraint(
        x, jax.sharding.NamedSharding(mesh, spec))


# ------------------------------------------------------------------ primitives

def rms_norm(x, scale, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * (1.0 + scale.astype(jnp.float32))
    return out.astype(x.dtype)


def softcap(x, cap: float | None):
    if cap is None:
        return x
    xf = x.astype(jnp.float32)
    return (jnp.tanh(xf / cap) * cap).astype(x.dtype)


def rope(x, positions, theta: float):
    """Rotary embedding. x: (..., S, H, D), positions: (..., S)."""
    d = x.shape[-1]
    half = d // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., :, None, None].astype(jnp.float32) * freq  # (...,S,1,half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate(
        [xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin], axis=-1
    ).astype(x.dtype)


def swiglu(x, w_gate, w_up, w_down):
    g = jnp.einsum("...d,df->...f", x, w_gate)
    u = jnp.einsum("...d,df->...f", x, w_up)
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    return jnp.einsum("...f,fd->...d", h, w_down)


def gelu_mlp(x, w_in, w_out):
    h = jnp.einsum("...d,df->...f", x, w_in)
    h = jax.nn.gelu(h.astype(jnp.float32)).astype(x.dtype)
    return jnp.einsum("...f,fd->...d", h, w_out)


def cross_entropy_chunked(logits_fn, x, labels, mask, vocab: int,
                          chunk: int = 512, final_cap: float | None = None,
                          mesh=None):
    """Streamed CE: materializes logits only chunk-by-chunk over sequence.

    logits_fn: (B, c, D) -> (B, c, V).  Bounds peak memory to B*c*V*4
    bytes instead of B*S*V*4 (decisive for 256k-vocab models).  The gold
    logit is extracted with a one-hot contraction, NOT take_along_axis:
    a gather along the model-sharded vocab axis would force GSPMD to
    all-gather the logits; the one-hot product stays sharded and reduces
    with a cheap psum.
    """
    b, s, _ = x.shape
    chunk = min(chunk, s)
    if s % chunk:
        chunk = s  # fallback: single chunk
    n = s // chunk

    def body(carry, idx):
        loss_sum, cnt = carry
        xs = jax.lax.dynamic_slice_in_dim(x, idx * chunk, chunk, axis=1)
        ls = jax.lax.dynamic_slice_in_dim(labels, idx * chunk, chunk, axis=1)
        ms = jax.lax.dynamic_slice_in_dim(mask, idx * chunk, chunk, axis=1)
        logits = logits_fn(xs)
        if mesh is not None:
            logits = constrain(logits, mesh, "batch", None, "model")
        logits = softcap(logits, final_cap).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        onehot = jax.nn.one_hot(ls, vocab, dtype=logits.dtype)
        gold = jnp.sum(logits * onehot, axis=-1)
        nll = (lse - gold) * ms
        return (loss_sum + nll.sum(), cnt + ms.sum()), None

    (loss_sum, cnt), _ = jax.lax.scan(
        jax.checkpoint(body), (jnp.float32(0.0), jnp.float32(0.0)),
        jnp.arange(n))
    return loss_sum / jnp.maximum(cnt, 1.0)
