"""Unified model API: one object per architecture family.

  model = build_model(cfg)
  params = model.init(key)
  loss   = model.train_loss(params, batch, mesh)
  caches, logits = model.prefill(params, inputs, mesh, s_cap)
  caches, logits = model.decode_step(params, caches, token, pos, mesh)
  batch  = model.train_input_specs(shape) / prefill_input_specs(shape)

Families: dense | moe (transformer.py), ssm | hybrid (hybrid.py),
encoder (encoder.py), vlm (vlm.py).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from . import base, transformer as tfm, hybrid, encoder, vlm
from ..configs.base import ArchConfig, ShapeCfg


def _gemma_like(cfg: ArchConfig) -> bool:
    return cfg.local_per_global is not None or cfg.final_logit_cap is not None


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ArchConfig

    # ---------------- params ----------------
    def template(self):
        f = self.cfg.family
        if f in ("dense", "moe"):
            return tfm.lm_templates(self.cfg)
        if f in ("ssm", "hybrid"):
            return hybrid.hybrid_templates(self.cfg)
        if f == "encoder":
            return encoder.encoder_templates(self.cfg)
        if f == "vlm":
            return vlm.vlm_templates(self.cfg)
        raise ValueError(f)

    def init(self, key):
        return base.init_params(self.template(), key)

    def abstract_params(self):
        return base.abstract_params(self.template())

    def param_specs(self, mesh):
        return base.spec_tree(self.template(), mesh)

    def param_count(self) -> int:
        return base.param_count(self.template())

    def active_param_count(self) -> int:
        """Params touched per token (MoE: routed experts count top_k/E)."""
        cfg = self.cfg
        total = self.param_count()
        if cfg.family != "moe" or not cfg.n_experts:
            return total
        expert_p = 3 * cfg.d_model * cfg.d_ff_expert * cfg.n_experts \
            * cfg.n_layers
        active = expert_p * cfg.top_k / cfg.n_experts
        return int(total - expert_p + active)

    # ---------------- steps ----------------
    def train_loss(self, params, batch, mesh=None):
        f = self.cfg.family
        if f in ("dense", "moe"):
            return tfm.lm_train_loss(params, batch, self.cfg, mesh,
                                     embed_scale=_gemma_like(self.cfg))
        if f in ("ssm", "hybrid"):
            return hybrid.lm_train_loss(params, batch, self.cfg, mesh)
        if f == "encoder":
            return encoder.encoder_train_loss(params, batch, self.cfg, mesh)
        if f == "vlm":
            return vlm.vlm_train_loss(params, batch, self.cfg, mesh)
        raise ValueError(f)

    def prefill(self, params, batch, mesh=None, s_cap=None):
        f = self.cfg.family
        if f in ("dense", "moe"):
            return tfm.lm_prefill(params, batch["tokens"], self.cfg, mesh,
                                  s_cap, embed_scale=_gemma_like(self.cfg))
        if f in ("ssm", "hybrid"):
            return hybrid.lm_prefill(params, batch["tokens"], self.cfg,
                                     mesh, s_cap)
        if f == "encoder":
            return None, encoder.encoder_forward(params, batch["frames"],
                                                 self.cfg, mesh)
        if f == "vlm":
            return vlm.vlm_prefill(params, batch["image_embeds"],
                                   batch["tokens"], self.cfg, mesh, s_cap)
        raise ValueError(f)

    def decode_step(self, params, caches, token, pos, mesh=None):
        f = self.cfg.family
        if f in ("dense", "moe"):
            return tfm.lm_decode_step(params, caches, token, pos, self.cfg,
                                      mesh, embed_scale=_gemma_like(self.cfg))
        if f in ("ssm", "hybrid"):
            return hybrid.lm_decode_step(params, caches, token, pos,
                                         self.cfg, mesh)
        if f == "vlm":
            return vlm.vlm_decode_step(params, caches, token, pos,
                                       self.cfg, mesh)
        raise ValueError(f"{f} has no decode step")

    def cache_spec(self, batch: int, s_cap: int):
        f = self.cfg.family
        if f in ("dense", "moe", "vlm"):
            return tfm.lm_cache_spec(self.cfg, batch, s_cap)
        if f in ("ssm", "hybrid"):
            return hybrid.hybrid_cache_spec(self.cfg, batch, s_cap)
        raise ValueError(f"{f} has no cache")

    # ---------------- abstract inputs (dry-run) ----------------
    def train_input_specs(self, shape: ShapeCfg) -> dict:
        b, s = shape.global_batch, shape.seq_len
        f = self.cfg.family
        i32 = jnp.int32
        if f == "encoder":
            return {
                "frames": jax.ShapeDtypeStruct((b, s, encoder.D_FRONTEND),
                                               jnp.bfloat16),
                "mask": jax.ShapeDtypeStruct((b, s), jnp.bool_),
                "labels": jax.ShapeDtypeStruct((b, s), i32),
            }
        if f == "vlm":
            nv, dv = self.cfg.n_vis_tokens, self.cfg.d_vis
            st = s - nv
            return {
                "image_embeds": jax.ShapeDtypeStruct((b, nv, dv),
                                                     jnp.bfloat16),
                "tokens": jax.ShapeDtypeStruct((b, st), i32),
                "labels": jax.ShapeDtypeStruct((b, st), i32),
                "mask": jax.ShapeDtypeStruct((b, st), jnp.float32),
            }
        return {
            "tokens": jax.ShapeDtypeStruct((b, s), i32),
            "labels": jax.ShapeDtypeStruct((b, s), i32),
            "mask": jax.ShapeDtypeStruct((b, s), jnp.float32),
        }

    def prefill_input_specs(self, shape: ShapeCfg) -> dict:
        b, s = shape.global_batch, shape.seq_len
        f = self.cfg.family
        if f == "encoder":
            return {"frames": jax.ShapeDtypeStruct(
                (b, s, encoder.D_FRONTEND), jnp.bfloat16)}
        if f == "vlm":
            nv, dv = self.cfg.n_vis_tokens, self.cfg.d_vis
            return {
                "image_embeds": jax.ShapeDtypeStruct((b, nv, dv),
                                                     jnp.bfloat16),
                "tokens": jax.ShapeDtypeStruct((b, s - nv), jnp.int32),
            }
        return {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32)}


def build_model(cfg: ArchConfig) -> Model:
    return Model(cfg)
