"""PaliGemma-style VLM: SigLIP vision stub + gemma-family decoder.

The vision tower is a STUB per the assignment: input_specs() provides
precomputed patch embeddings (B, n_vis_tokens, d_vis); a learned linear
projector lifts them into the LM embedding space.  The sequence is
[image tokens | text tokens] with a PaliGemma prefix-LM mask (image
prefix attends bidirectionally; text is causal); loss is CE on text
positions only.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import base
from .base import Param
from . import transformer as tfm
from ..configs.base import ArchConfig


def vlm_templates(cfg: ArchConfig) -> dict:
    tpl = tfm.lm_templates(cfg)
    tpl["vis_proj"] = Param((cfg.d_vis, cfg.d_model), (None, "fsdp"))
    return tpl


def _embed_multimodal(params, image_embeds, tokens, cfg, mesh):
    vis = image_embeds.astype(jnp.bfloat16) @ params["vis_proj"]
    txt = tfm.embed_tokens(params, tokens, cfg, mesh, scale=True)
    x = jnp.concatenate([vis.astype(txt.dtype), txt], axis=1)
    return base.constrain(x, mesh, "batch", None, None)


def vlm_train_loss(params, batch, cfg: ArchConfig, mesh=None):
    """batch: image_embeds (B,V,dv), tokens (B,St), labels (B,St), mask."""
    img, tokens, labels = (batch["image_embeds"], batch["tokens"],
                           batch["labels"])
    mask = batch.get("mask")
    if mask is None:
        mask = jnp.ones_like(labels, jnp.float32)
    b, st = tokens.shape
    nv = cfg.n_vis_tokens
    x = _embed_multimodal(params, img, tokens, cfg, mesh)
    s = nv + st
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    x, _, _ = tfm.stack_apply(params, x, cfg, mesh, "train",
                              positions=positions, prefix_len=nv)
    x = base.rms_norm(x, params["final_norm"], cfg.norm_eps)
    # full-length labels: image positions never scored
    full_labels = jnp.concatenate(
        [jnp.zeros((b, nv), labels.dtype), labels], axis=1)
    full_mask = jnp.concatenate(
        [jnp.zeros((b, nv), jnp.float32), mask.astype(jnp.float32)], axis=1)
    w = tfm.unembed_matrix(params, cfg)
    return base.cross_entropy_chunked(
        lambda xs: xs @ w, x, full_labels, full_mask, cfg.padded_vocab,
        chunk=cfg.ce_chunk, final_cap=cfg.final_logit_cap, mesh=mesh)


def vlm_prefill(params, image_embeds, tokens, cfg: ArchConfig, mesh=None,
                s_cap=None):
    b, st = tokens.shape
    nv = cfg.n_vis_tokens
    s = nv + st
    s_cap = s_cap or cfg.max_seq
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    caches = tfm.init_cache(cfg, b, s_cap)
    x = _embed_multimodal(params, image_embeds, tokens, cfg, mesh)
    x, caches, _ = tfm.stack_apply(params, x, cfg, mesh, "prefill",
                                   caches=caches, positions=positions,
                                   prefix_len=nv)
    x = base.rms_norm(x[:, -1:], params["final_norm"], cfg.norm_eps)
    logits = base.softcap(x @ tfm.unembed_matrix(params, cfg),
                          cfg.final_logit_cap)
    return caches, logits[:, 0]


def vlm_decode_step(params, caches, token, pos, cfg: ArchConfig, mesh=None):
    return tfm.lm_decode_step(params, caches, token, pos, cfg, mesh,
                              embed_scale=True)
