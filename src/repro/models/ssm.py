"""Mamba2 blocks via the SSD (state-space duality) chunked algorithm.

Implements Dao & Gu 2024 (arXiv:2405.21060): within chunks of length Q
the recurrence is computed as a masked quadratic form (MXU-friendly);
across chunks a short ``lax.scan`` carries the (H, N, P) state.  All
decay/cumsum math runs in f32; every exponent is <= 0, so exp() is
stable by construction.

Decode is the O(1) recurrent step on a carried (state, conv window)
cache -- this is what makes the ``long_500k`` shape tractable for the
ssm/hybrid archs where full attention is skipped.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import base
from .base import Param, constrain
from ..configs.base import ArchConfig


def ssm_template(cfg: ArchConfig) -> dict:
    d, di, n, h = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.n_ssm_heads
    conv_ch = di + 2 * n
    proj_out = 2 * di + 2 * n + h          # z, x, B, C, dt
    return {
        "norm": Param((d,), (None,), init="zeros"),
        "in_proj": Param((d, proj_out), ("fsdp", "model")),
        "conv_w": Param((cfg.ssm_conv_width, conv_ch), (None, "model"),
                        scale=0.1),
        "conv_b": Param((conv_ch,), ("model",), init="zeros"),
        "dt_bias": Param((h,), (None,), dtype=jnp.float32, init="zeros"),
        "A_log": Param((h,), (None,), dtype=jnp.float32, init="zeros"),
        "D": Param((h,), (None,), dtype=jnp.float32, init="ones"),
        "gate_norm": Param((di,), (None,), init="zeros"),
        "out_proj": Param((di, d), ("model", "fsdp"), init="scaled"),
    }


def ssm_cache_spec(cfg: ArchConfig, batch: int):
    di, n, h, pdim = (cfg.d_inner, cfg.ssm_state, cfg.n_ssm_heads,
                      cfg.ssm_head_dim)
    conv_ch = di + 2 * n
    return {
        "conv": jax.ShapeDtypeStruct((batch, cfg.ssm_conv_width - 1, conv_ch),
                                     jnp.bfloat16),
        "state": jax.ShapeDtypeStruct((batch, h, n, pdim), jnp.float32),
    }


def _split_proj(zxbcdt, cfg: ArchConfig):
    di, n, h = cfg.d_inner, cfg.ssm_state, cfg.n_ssm_heads
    z = zxbcdt[..., :di]
    xbc = zxbcdt[..., di:2 * di + 2 * n]
    dt_raw = zxbcdt[..., -h:]
    return z, xbc, dt_raw


def _causal_conv(xbc, w, b):
    """Depthwise causal conv via shifted adds. xbc: (B, S, CH)."""
    kw = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (kw - 1, 0), (0, 0)))
    s = xbc.shape[1]
    out = jnp.zeros_like(xbc, dtype=jnp.float32)
    for k in range(kw):
        out = out + pad[:, k:k + s].astype(jnp.float32) \
            * w[k].astype(jnp.float32)
    out = out + b.astype(jnp.float32)
    return jax.nn.silu(out).astype(xbc.dtype)


def _gated_out(p, y, z, u, cfg, mesh):
    y = base.rms_norm(
        y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype),
        p["gate_norm"], cfg.norm_eps)
    return constrain(u + y @ p["out_proj"], mesh, "batch", None, None)


def ssm_apply(p, u, cfg: ArchConfig, mesh, mode: str, cache=None):
    """Returns (y, new_cache).  u: (B, S, D)."""
    if mode == "decode":
        return _ssm_decode(p, u, cfg, mesh, cache)

    b, s_orig, d = u.shape
    di, n, h, pdim = (cfg.d_inner, cfg.ssm_state, cfg.n_ssm_heads,
                      cfg.ssm_head_dim)
    q = cfg.ssm_chunk

    xn = base.rms_norm(u, p["norm"], cfg.norm_eps)
    z, xbc_pre, dt_raw = _split_proj(xn @ p["in_proj"], cfg)
    xbc = _causal_conv(xbc_pre, p["conv_w"], p["conv_b"])
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])

    # pad to a chunk multiple; padded steps get dt=0 => identity decay
    # and zero state contribution (exactness preserved for any length).
    s = -(-s_orig // q) * q
    if s != s_orig:
        pad = s - s_orig
        xbc = jnp.pad(xbc, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
    nc = s // q

    xh = xbc[..., :di].reshape(b, s, h, pdim)
    xh = constrain(xh, mesh, "batch", None, "model", None)
    bm = xbc[..., di:di + n]                               # (B, S, N), G=1
    cm = xbc[..., di + n:]
    a = -jnp.exp(p["A_log"])                               # (H,) < 0

    # chunked views
    xc = xh.reshape(b, nc, q, h, pdim)
    bc = bm.reshape(b, nc, q, n).astype(jnp.float32)
    cc = cm.reshape(b, nc, q, n).astype(jnp.float32)
    dtc = dt.reshape(b, nc, q, h)

    da = dtc * a                                           # (B,nc,q,H) <= 0
    cum = jnp.cumsum(da, axis=2)

    # ---- intra-chunk (quadratic, MXU) ----
    cb = jnp.einsum("bcin,bcjn->bcij", cc, bc)             # (B,nc,q,q)
    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]    # (B,nc,i,j,H)
    tri = jnp.tril(jnp.ones((q, q), bool))
    lmat = jnp.where(tri[None, None, :, :, None], jnp.exp(seg), 0.0)
    w = cb[..., None] * lmat * dtc[:, :, None, :, :]       # (B,nc,i,j,H)
    y_diag = jnp.einsum("bcijh,bcjhp->bcihp", w.astype(xc.dtype), xc,
                        preferred_element_type=jnp.float32)

    # ---- chunk states + inter-chunk recurrence ----
    decay_out = jnp.exp(cum[:, :, -1:, :] - cum)           # (B,nc,q,H)
    states = jnp.einsum("bcln,bclh,bclhp->bchnp",
                        bc, (decay_out * dtc).astype(jnp.float32),
                        xc.astype(jnp.float32))            # (B,nc,H,N,P)
    chunk_decay = jnp.exp(cum[:, :, -1, :])                # (B,nc,H)

    def inter(carry, xs):
        s_c, dec = xs
        prev = carry
        new = prev * dec[..., None, None] + s_c
        return new, prev

    states_t = jnp.moveaxis(states, 1, 0)                  # (nc,B,H,N,P)
    decay_t = jnp.moveaxis(chunk_decay, 1, 0)
    s0 = jnp.zeros((b, h, n, pdim), jnp.float32)
    s_final, prev_t = jax.lax.scan(inter, s0, (states_t, decay_t))
    states_prev = jnp.moveaxis(prev_t, 0, 1)               # (B,nc,H,N,P)

    y_off = jnp.einsum("bcin,bchnp,bcih->bcihp",
                       cc, states_prev, jnp.exp(cum))
    y = (y_diag + y_off).astype(jnp.float32) \
        + p["D"][None, None, None, :, None] * xc.astype(jnp.float32)
    y = y.reshape(b, s, di)[:, :s_orig].astype(u.dtype)
    out = _gated_out(p, y, z, u, cfg, mesh)

    new_cache = None
    if mode == "prefill":
        kw = cfg.ssm_conv_width
        new_cache = {"conv": xbc_pre[:, s_orig - (kw - 1):s_orig, :],
                     "state": s_final}
    return out, new_cache


def _ssm_decode(p, u, cfg: ArchConfig, mesh, cache):
    """One-token recurrent step. u: (B, 1, D)."""
    b = u.shape[0]
    di, n, h, pdim = (cfg.d_inner, cfg.ssm_state, cfg.n_ssm_heads,
                      cfg.ssm_head_dim)
    xn = base.rms_norm(u, p["norm"], cfg.norm_eps)
    z, xbc_pre, dt_raw = _split_proj(xn @ p["in_proj"], cfg)

    window = jnp.concatenate([cache["conv"].astype(xbc_pre.dtype), xbc_pre],
                             axis=1)                       # (B, kw, CH)
    wconv = p["conv_w"].astype(jnp.float32)
    xbc = jnp.einsum("bkc,kc->bc", window.astype(jnp.float32), wconv) \
        + p["conv_b"].astype(jnp.float32)
    xbc = jax.nn.silu(xbc).astype(u.dtype)                 # (B, CH)
    new_conv = window[:, 1:].astype(jnp.bfloat16)

    xh = xbc[:, :di].reshape(b, h, pdim).astype(jnp.float32)
    bm = xbc[:, di:di + n].astype(jnp.float32)
    cm = xbc[:, di + n:].astype(jnp.float32)
    dt = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32) + p["dt_bias"])
    a = -jnp.exp(p["A_log"])
    da = jnp.exp(dt * a)                                   # (B, H)

    state = cache["state"] * da[..., None, None] \
        + jnp.einsum("bn,bh,bhp->bhnp", bm, dt, xh)
    y = jnp.einsum("bn,bhnp->bhp", cm, state) \
        + p["D"][None, :, None] * xh
    y = y.reshape(b, 1, di).astype(u.dtype)
    out = _gated_out(p, y, z, u, cfg, mesh)
    return out, {"conv": new_conv, "state": state}
