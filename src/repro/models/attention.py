"""Chunked (flash-style) attention in pure JAX, GQA-native.

Two schedules compute the same function:

  * "masked"  -- scan over all (q_chunk, kv_chunk) pairs, mask inside the
                 chunk.  Baseline: simple, but for causal masks ~2x the
                 useful FLOPs are spent on fully-masked pairs.
  * "banded"  -- scan only the chunk pairs that can contain unmasked
                 entries (triangular band for causal, diagonal band for
                 sliding-window).  The §Perf compute-term optimization.

Online-softmax statistics are carried in f32; QK^T and PV contractions
run in the compute dtype with f32 accumulation, mirroring the MXU.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _chunk_mask(qpos, kpos, kind: str, window, prefix_len):
    """Boolean mask (..., qc, kc): True = attend."""
    if kind == "none":
        return None
    q = qpos[..., :, None]
    k = kpos[..., None, :]
    causal = k <= q
    if kind == "causal":
        m = causal
    elif kind == "local":
        m = causal & (k > q - window)
    elif kind == "prefix":
        m = causal | (k < prefix_len)
    else:
        raise ValueError(kind)
    return m


def _score_block(q_blk, k_blk, scale, logit_cap):
    # q_blk: (B, qc, KV, G, D), k_blk: (B, kc, KV, D)
    s = jnp.einsum("bqkgd,bskd->bkgqs", q_blk, k_blk,
                   preferred_element_type=jnp.float32) * scale
    if logit_cap is not None:
        s = jnp.tanh(s / logit_cap) * logit_cap
    return s


def _pv_block(p, v_blk):
    # p: (B, KV, G, qc, kc) f32; v_blk: (B, kc, KV, D)
    return jnp.einsum("bkgqs,bskd->bkgqd", p.astype(v_blk.dtype), v_blk,
                      preferred_element_type=jnp.float32)


def _band_pairs(n_q: int, n_k: int, kind: str, window, k_chunk: int,
                prefix_len) -> list:
    """Chunk pairs that may contain unmasked entries (static)."""
    pairs = []
    band = None
    if kind == "local" and window is not None:
        band = -(-window // k_chunk)           # chunks back from diagonal
    prefix_chunks = 0
    if kind == "prefix" and prefix_len:
        prefix_chunks = -(-prefix_len // k_chunk)
    for qi in range(n_q):
        for ki in range(n_k):
            if kind == "none":
                pairs.append((qi, ki))
                continue
            diag = (qi * n_k) // n_q            # kv chunk containing diagonal
            if ki > diag and ki >= prefix_chunks:
                continue                        # fully in the future
            if band is not None and ki < diag - band and ki >= prefix_chunks:
                continue                        # fully outside the window
            pairs.append((qi, ki))
    return pairs


@functools.partial(
    jax.jit,
    static_argnames=("mask_kind", "window", "prefix_len", "logit_cap",
                     "q_chunk", "k_chunk", "schedule"))
def flash_attention(q, k, v, *, mask_kind: str = "causal",
                    window: int | None = None, prefix_len: int | None = None,
                    logit_cap: float | None = None,
                    q_chunk: int = 512, k_chunk: int = 512,
                    schedule: str = "masked", q_offset=0,
                    k_offset=0) -> jax.Array:
    """q: (B, Sq, H, D); k, v: (B, Sk, KV, D) -> (B, Sq, H, D).

    H must be a multiple of KV (GQA groups are never materialized).
    q_offset/k_offset shift the absolute positions of q/k rows -- used
    by the context-parallel path where each shard holds a sequence
    slice (may be traced values; "banded" requires static offsets = 0).
    """
    b, sq, h, d = q.shape
    sk, kv = k.shape[1], k.shape[2]
    g = h // kv
    scale = 1.0 / math.sqrt(d)
    q = q.reshape(b, sq, kv, g, d)

    q_chunk = min(q_chunk, sq)
    k_chunk = min(k_chunk, sk)
    if sq % q_chunk or sk % k_chunk:
        q_chunk, k_chunk = sq, sk               # fallback: single chunk
    n_q, n_k = sq // q_chunk, sk // k_chunk

    if schedule == "banded" and mask_kind != "none":
        return _banded(q, k, v, scale, mask_kind, window, prefix_len,
                       logit_cap, q_chunk, k_chunk, n_q, n_k
                       ).reshape(b, sq, h, d)

    def q_step(_, qi):
        q_blk = jax.lax.dynamic_slice_in_dim(q, qi * q_chunk, q_chunk, axis=1)
        qpos = q_offset + qi * q_chunk + jnp.arange(q_chunk)

        def kv_step(carry, ki):
            m, l, acc = carry
            k_blk = jax.lax.dynamic_slice_in_dim(k, ki * k_chunk, k_chunk, 1)
            v_blk = jax.lax.dynamic_slice_in_dim(v, ki * k_chunk, k_chunk, 1)
            kpos = k_offset + ki * k_chunk + jnp.arange(k_chunk)
            s = _score_block(q_blk, k_blk, scale, logit_cap)
            msk = _chunk_mask(qpos, kpos, mask_kind, window, prefix_len)
            if msk is not None:
                s = jnp.where(msk, s, NEG_INF)
            m_new = jnp.maximum(m, s.max(-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l = l * corr + p.sum(-1)
            acc = acc * corr[..., None] + _pv_block(p, v_blk)
            return (m_new, l, acc), None

        m0 = jnp.full((b, kv, g, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, kv, g, q_chunk), jnp.float32)
        a0 = jnp.zeros((b, kv, g, q_chunk, d), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0), jnp.arange(n_k))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        # (B, KV, G, qc, D) -> (B, qc, KV, G, D)
        return None, jnp.moveaxis(out, 3, 1)

    _, blocks = jax.lax.scan(q_step, None, jnp.arange(n_q))
    # blocks: (n_q, B, qc, KV, G, D)
    out = jnp.moveaxis(blocks, 0, 1).reshape(b, sq, kv, g, d)
    return out.reshape(b, sq, h, d).astype(v.dtype)


def _banded(q, k, v, scale, mask_kind, window, prefix_len, logit_cap,
            q_chunk, k_chunk, n_q, n_k):
    """Band-scheduled exact attention: skip fully-masked chunk pairs."""
    b, sq, kv, g, d = q.shape
    pairs = _band_pairs(n_q, n_k, mask_kind, window, k_chunk, prefix_len)
    qi_idx = jnp.asarray([p[0] for p in pairs])
    ki_idx = jnp.asarray([p[1] for p in pairs])

    m0 = jnp.full((n_q, b, kv, g, q_chunk), NEG_INF, jnp.float32)
    l0 = jnp.zeros((n_q, b, kv, g, q_chunk), jnp.float32)
    a0 = jnp.zeros((n_q, b, kv, g, q_chunk, d), jnp.float32)

    def step(carry, xs):
        m_all, l_all, acc_all = carry
        qi, ki = xs
        q_blk = jax.lax.dynamic_slice_in_dim(q, qi * q_chunk, q_chunk, 1)
        k_blk = jax.lax.dynamic_slice_in_dim(k, ki * k_chunk, k_chunk, 1)
        v_blk = jax.lax.dynamic_slice_in_dim(v, ki * k_chunk, k_chunk, 1)
        qpos = qi * q_chunk + jnp.arange(q_chunk)
        kpos = ki * k_chunk + jnp.arange(k_chunk)
        s = _score_block(q_blk, k_blk, scale, logit_cap)
        msk = _chunk_mask(qpos, kpos, mask_kind, window, prefix_len)
        if msk is not None:
            s = jnp.where(msk, s, NEG_INF)
        m = jax.lax.dynamic_index_in_dim(m_all, qi, 0, keepdims=False)
        l = jax.lax.dynamic_index_in_dim(l_all, qi, 0, keepdims=False)
        acc = jax.lax.dynamic_index_in_dim(acc_all, qi, 0, keepdims=False)
        m_new = jnp.maximum(m, s.max(-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l = l * corr + p.sum(-1)
        acc = acc * corr[..., None] + _pv_block(p, v_blk)
        m_all = jax.lax.dynamic_update_index_in_dim(m_all, m_new, qi, 0)
        l_all = jax.lax.dynamic_update_index_in_dim(l_all, l, qi, 0)
        acc_all = jax.lax.dynamic_update_index_in_dim(acc_all, acc, qi, 0)
        return (m_all, l_all, acc_all), None

    (m_all, l_all, acc_all), _ = jax.lax.scan(step, (m0, l0, a0),
                                              (qi_idx, ki_idx))
    out = acc_all / jnp.maximum(l_all, 1e-30)[..., None]
    # (n_q, B, KV, G, qc, D) -> (B, n_q*qc = Sq, KV, G, D)
    out = jnp.transpose(out, (1, 0, 4, 2, 3, 5)).reshape(b, sq, kv, g, d)
    return out.astype(v.dtype)


def flash_attention_context_parallel(
        q, k, v, mesh, *, mask_kind: str = "causal",
        window: int | None = None, prefix_len: int | None = None,
        logit_cap: float | None = None, q_chunk: int = 512,
        k_chunk: int = 512) -> jax.Array:
    """Context-parallel attention: Q sharded over sequence on the model
    axis via shard_map; K/V replicated over model (batch-sharded over
    data).  Each shard computes its own sequence slice with offset masks
    -- zero collectives inside the attention loop, per-device attention
    FLOPs divided by the model-axis size.  For sliding-window layers
    each shard slices only the (S/n + window) keys it can see, so local
    layers additionally drop ~S/(S/n+window)x of the K reads.

    The production layout for archs whose head count cannot use the
    model axis (gemma3/paligemma kv=1, 4-8 q heads).
    """
    from repro.compat import shard_map
    from jax.sharding import PartitionSpec as P

    axes = mesh.axis_names
    da = tuple(a for a in ("pod", "data") if a in axes)
    da_spec = (da if len(da) != 1 else da[0]) if da else None
    n = mesh.shape["model"] if "model" in axes else 1
    b, s, h, d = q.shape
    if n <= 1 or s % n or (s // n) < 1:
        return flash_attention(q, k, v, mask_kind=mask_kind, window=window,
                               prefix_len=prefix_len, logit_cap=logit_cap,
                               q_chunk=q_chunk, k_chunk=k_chunk)
    s_loc = s // n

    def local(qs, kf, vf):
        i = jax.lax.axis_index("model")
        off = i * s_loc
        k_off = 0
        kf_use, vf_use = kf, vf
        if mask_kind == "local" and window is not None and window < s:
            klen = min(s, s_loc + -(-window // k_chunk) * k_chunk)
            start = jnp.clip(off + s_loc - klen, 0, s - klen)
            kf_use = jax.lax.dynamic_slice_in_dim(kf, start, klen, 1)
            vf_use = jax.lax.dynamic_slice_in_dim(vf, start, klen, 1)
            k_off = start
        return flash_attention(
            qs, kf_use, vf_use, mask_kind=mask_kind, window=window,
            prefix_len=prefix_len, logit_cap=logit_cap,
            q_chunk=min(q_chunk, s_loc), k_chunk=k_chunk,
            schedule="masked", q_offset=off, k_offset=k_off)

    return shard_map(
        local, mesh=mesh,
        in_specs=(P(da_spec, "model", None, None),
                  P(da_spec, None, None, None),
                  P(da_spec, None, None, None)),
        out_specs=P(da_spec, "model", None, None),
        check_vma=False)(q, k, v)


@functools.partial(jax.jit, static_argnames=("logit_cap",))
def decode_attention_int8(q, k_q, k_scale, v_q, v_scale, valid, *,
                          logit_cap: float | None = None) -> jax.Array:
    """Integer-domain decode attention over an int8 KV cache.

    MCIM structure applied to attention: the int8 QK^T dot is the PPM
    (1-byte HBM reads, int8 MXU path), the int32 accumulator is the
    carry-free compressor, and the per-row scales applied after the dot
    are the final adder.  The P·V contraction folds V's per-position
    scales into the probabilities *before* quantizing them, so both
    large reads (K and V caches) stay int8 end to end.

    q: (B, 1, H, D) bf16;  k_q/v_q: (B, S, KV, D) int8;
    k_scale/v_scale: (B, S, KV) f32;  valid: (B, S) bool.
    """
    b, _, h, d = q.shape
    s, kv = k_q.shape[1], k_q.shape[2]
    g = h // kv
    scale = 1.0 / math.sqrt(d)
    qg = q.reshape(b, 1, kv, g, d)
    # quantize q per (b, kv, g) row
    qf = qg.astype(jnp.float32)
    qmax = jnp.max(jnp.abs(qf), axis=-1, keepdims=True)
    qs = jnp.where(qmax == 0, 1.0, qmax / 127.0)
    q8 = jnp.clip(jnp.round(qf / qs), -127, 127).astype(jnp.int8)

    scores_i = jnp.einsum("bqkgd,bskd->bkgqs", q8, k_q,
                          preferred_element_type=jnp.int32)
    qs_b = qs[:, 0][..., None]                             # (B,KV,G,1,1)
    ks_b = k_scale.transpose(0, 2, 1)[:, :, None, None, :]  # (B,KV,1,1,S)
    scores = scores_i.astype(jnp.float32) * qs_b * ks_b * scale
    if logit_cap is not None:
        scores = jnp.tanh(scores / logit_cap) * logit_cap
    scores = jnp.where(valid[:, None, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)                # (B,KV,G,1,S)
    # fold V scales into probs, then quantize probs
    pv = probs * v_scale.transpose(0, 2, 1)[:, :, None, None, :]
    pmax = jnp.max(pv, axis=-1, keepdims=True)
    ps = jnp.where(pmax == 0, 1.0, pmax / 127.0)
    p8 = jnp.clip(jnp.round(pv / ps), -127, 127).astype(jnp.int8)
    out_i = jnp.einsum("bkgqs,bskd->bqkgd", p8, v_q,
                       preferred_element_type=jnp.int32)
    out = out_i.astype(jnp.float32) \
        * jnp.moveaxis(ps, 4, 1).reshape(b, 1, kv, g, 1)
    return out.reshape(b, 1, h, d).astype(jnp.bfloat16)


@functools.partial(jax.jit, static_argnames=("logit_cap",))
def decode_attention(q, k_cache, v_cache, valid, *,
                     logit_cap: float | None = None) -> jax.Array:
    """Single-token attention over a (possibly ring) KV cache.

    q: (B, 1, H, D); k_cache/v_cache: (B, S, KV, D) with keys pre-roped;
    valid: (B, S) bool -- which cache slots hold live entries.
    """
    b, _, h, d = q.shape
    s, kv = k_cache.shape[1], k_cache.shape[2]
    g = h // kv
    scale = 1.0 / math.sqrt(d)
    qg = q.reshape(b, 1, kv, g, d)
    scores = jnp.einsum("bqkgd,bskd->bkgqs", qg, k_cache,
                        preferred_element_type=jnp.float32) * scale
    if logit_cap is not None:
        scores = jnp.tanh(scores / logit_cap) * logit_cap
    scores = jnp.where(valid[:, None, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs.astype(v_cache.dtype),
                     v_cache, preferred_element_type=jnp.float32)
    return out.reshape(b, 1, h, d).astype(v_cache.dtype)
