"""SSM and hybrid LMs: mamba2 (pure SSD stack) and zamba2 (Mamba2 +
shared attention blocks).

zamba2's defining trick: ONE physical transformer block (attention+MLP)
is re-used every ``shared_attn_every`` Mamba layers -- parameter reuse
over depth, the depth-wise cousin of the paper's temporal folding (one
PPM re-used over cycles).  Each *application* still needs its own KV
cache, so caches are stacked over groups while the weights are not.

mamba2 is the shared_attn_every == 0 special case (no attention at all).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import base
from .base import Param
from . import transformer as tfm
from .ssm import ssm_template, ssm_apply, ssm_cache_spec
from ..configs.base import ArchConfig


def _pattern(cfg: ArchConfig):
    every = cfg.shared_attn_every
    if every:
        return every, cfg.n_layers // every, cfg.n_layers % every
    return 1, cfg.n_layers, 0


def hybrid_templates(cfg: ArchConfig) -> dict:
    every, n_groups, n_tail = _pattern(cfg)
    group = {"mamba": base.stack(ssm_template(cfg), every)}
    tpl = {
        "embed": Param((cfg.padded_vocab, cfg.d_model), ("model", "fsdp")),
        "final_norm": Param((cfg.d_model,), (None,), init="zeros"),
        "groups": base.stack(group, n_groups, "layers"),
    }
    if n_tail:
        tpl["tail"] = base.stack(ssm_template(cfg), n_tail, "layers")
    if cfg.shared_attn_every:
        tpl["shared_attn"] = tfm.layer_template(cfg)   # ONE copy, reused
    if not cfg.tie_embeddings:
        tpl["unembed"] = Param((cfg.d_model, cfg.padded_vocab),
                               ("fsdp", "model"))
    return tpl


def hybrid_cache_spec(cfg: ArchConfig, batch: int, s_cap: int):
    every, n_groups, n_tail = _pattern(cfg)

    def stk(spec, n):
        return jax.tree_util.tree_map(
            lambda s: jax.ShapeDtypeStruct((n,) + s.shape, s.dtype), spec)

    group = {"mamba": stk(ssm_cache_spec(cfg, batch), every)}
    if cfg.shared_attn_every:
        group["shared"] = tfm.attn_cache_spec(cfg, batch, s_cap, "global")
    tree = {"groups": stk(group, n_groups)}
    if n_tail:
        tree["tail"] = stk(ssm_cache_spec(cfg, batch), n_tail)
    return tree


def init_cache(cfg: ArchConfig, batch: int, s_cap: int):
    return jax.tree_util.tree_map(lambda s: jnp.zeros(s.shape, s.dtype),
                                  hybrid_cache_spec(cfg, batch, s_cap))


def stack_apply(params, x, cfg: ArchConfig, mesh, mode, caches=None,
                positions=None, pos=None):
    every, n_groups, n_tail = _pattern(cfg)
    use_cache = mode in ("prefill", "decode")
    shared = params.get("shared_attn")

    def group_body(carry, xs):
        xc, aux = carry
        gp, c = xs if use_cache else (xs, None)
        nc = {}
        if shared is not None:
            c_att = c["shared"] if c is not None else None
            xc, nc_att, _ = tfm.layer_apply(
                shared, xc, cfg, mesh, "global", mode, cache=c_att,
                positions=positions, pos=pos)
            if nc_att is not None:
                nc["shared"] = nc_att
        mamba_new = []
        for i in range(every):
            c_i = tfm._tree_idx(c["mamba"], i) if c is not None else None
            xc, nci = ssm_apply(tfm._tree_idx(gp["mamba"], i), xc, cfg,
                                mesh, mode, cache=c_i)
            if nci is not None:
                mamba_new.append(nci)
        if mamba_new:
            nc["mamba"] = tfm._tree_stack(mamba_new)
        return (xc, aux), (nc or None)

    body = group_body
    if cfg.remat and mode == "train":
        body = jax.checkpoint(group_body)
    xs = (params["groups"], caches["groups"]) if use_cache \
        else params["groups"]
    (x, aux), group_caches = jax.lax.scan(body, (x, jnp.float32(0.0)), xs)
    new_caches = {"groups": group_caches} if use_cache else None

    if "tail" in params:
        def tail_body(carry, xs):
            xc, aux = carry
            p, c = xs if use_cache else (xs, None)
            xc, nci = ssm_apply(p, xc, cfg, mesh, mode, cache=c)
            return (xc, aux), nci
        tb = jax.checkpoint(tail_body) if (cfg.remat and mode == "train") \
            else tail_body
        xs = (params["tail"], caches["tail"]) if use_cache else params["tail"]
        (x, aux), tail_caches = jax.lax.scan(tb, (x, aux), xs)
        if use_cache:
            new_caches["tail"] = tail_caches
    return x, new_caches, aux


# ------------------------------------------------------------------ LM API

def lm_train_loss(params, batch, cfg: ArchConfig, mesh=None):
    tokens, labels = batch["tokens"], batch["labels"]
    mask = batch.get("mask")
    if mask is None:
        mask = jnp.ones_like(labels, jnp.float32)
    b, s = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    x = tfm.embed_tokens(params, tokens, cfg, mesh, False)
    x, _, _ = stack_apply(params, x, cfg, mesh, "train", positions=positions)
    x = base.rms_norm(x, params["final_norm"], cfg.norm_eps)
    w = tfm.unembed_matrix(params, cfg)
    return base.cross_entropy_chunked(
        lambda xs: xs @ w, x, labels, mask, cfg.padded_vocab,
        chunk=cfg.ce_chunk, final_cap=cfg.final_logit_cap, mesh=mesh)


def lm_prefill(params, tokens, cfg: ArchConfig, mesh=None, s_cap=None):
    b, s = tokens.shape
    s_cap = s_cap or cfg.max_seq
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    caches = init_cache(cfg, b, s_cap)
    x = tfm.embed_tokens(params, tokens, cfg, mesh, False)
    x, caches, _ = stack_apply(params, x, cfg, mesh, "prefill",
                               caches=caches, positions=positions)
    x = base.rms_norm(x[:, -1:], params["final_norm"], cfg.norm_eps)
    logits = x @ tfm.unembed_matrix(params, cfg)
    return caches, logits[:, 0]


def lm_decode_step(params, caches, token, pos, cfg: ArchConfig, mesh=None):
    x = tfm.embed_tokens(params, token[:, None], cfg, mesh, False)
    x, caches, _ = stack_apply(params, x, cfg, mesh, "decode",
                               caches=caches, pos=pos)
    x = base.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = x @ tfm.unembed_matrix(params, cfg)
    return caches, logits[:, 0]
