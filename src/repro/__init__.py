"""repro: Multi-Cycle folded Integer Multipliers (MCIM) as a TPU-native
JAX framework -- core arithmetic, Pallas kernels, a 10-arch model zoo,
and a multi-pod training/serving runtime.

The front door is :mod:`repro.designs`: a declarative ``DesignSpec``
(throughput / clock / latency / signedness / replication) compiled by
``designs.generate()`` into an executable ``CompiledDesign``.  The
underlying layers (``repro.core``, ``repro.kernels``, ``repro.launch``)
remain public.

Reproduction of: Houraniah, Ugurdag, Dedeagac, "Efficient Multi-Cycle
Folded Integer Multipliers" (2023), adapted from ASIC folding to TPU
temporal folding (see DESIGN.md).
"""
__version__ = "1.1.0"
