"""repro: Multi-Cycle folded Integer Multipliers (MCIM) as a TPU-native
JAX framework -- core arithmetic, Pallas kernels, a 10-arch model zoo,
and a multi-pod training/serving runtime.

Reproduction of: Houraniah, Ugurdag, Dedeagac, "Efficient Multi-Cycle
Folded Integer Multipliers" (2023), adapted from ASIC folding to TPU
temporal folding (see DESIGN.md).
"""
__version__ = "1.0.0"
