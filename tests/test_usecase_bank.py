"""Functional simulation of the paper's use case 1 (Sec. V-E):

an application needs TP = 3.5 multiplications/cycle.  The conventional
bank rounds up to 4 Star multipliers; the MCIM bank uses 3 Star + one
CT=2 folded multiplier.  We simulate both banks cycle by cycle over a
stream of multiplications and assert (a) identical results, (b) the
MCIM bank sustains the required throughput with the area the planner
claims (< conventional)."""
import numpy as np
import jax.numpy as jnp

from repro.core import limbs as L
from repro.core import planner, area_model
from repro.core.mcim import MCIMConfig
from repro.core.schoolbook import star_mul, feedback_mul

RNG = np.random.default_rng(33)
BITS = 32
N_LIMBS = L.n_limbs_for_bits(BITS)


def test_tp_3_5_bank_functional():
    n_ops = 7 * 8                       # 3.5 ops/cycle over 16 cycles
    a = L.random_limbs(RNG, (n_ops,), BITS)
    b = L.random_limbs(RNG, (n_ops,), BITS)
    expect = [L.from_limbs(x) * L.from_limbs(y) for x, y in zip(a, b)]

    # --- MCIM bank: 3 Star (1 op/cycle each) + 1 FB CT=2 (1 op / 2 cyc)
    results = {}
    cycles = 0
    i = 0
    fb_busy_until = -1
    fb_pending = None
    while len(results) < n_ops:
        # the three Star units issue one multiplication each cycle
        for _ in range(3):
            if i < n_ops:
                out = star_mul(jnp.asarray(a[i])[None],
                               jnp.asarray(b[i])[None])[0]
                results[i] = L.from_limbs(np.asarray(out))
                i += 1
        # the folded unit accepts a new op every 2 cycles
        if cycles >= fb_busy_until and i < n_ops:
            fb_pending = i
            out = feedback_mul(jnp.asarray(a[i])[None],
                               jnp.asarray(b[i])[None], ct=2)[0]
            results[i] = L.from_limbs(np.asarray(out))
            i += 1
            fb_busy_until = cycles + 2
        cycles += 1

    assert [results[j] for j in range(n_ops)] == expect
    # sustained throughput >= 3.5/cycle
    assert n_ops / cycles >= 3.5 - 1e-9, (n_ops, cycles)

    # --- area: MCIM bank beats the round-up-to-4-Star bank -------------
    plan = planner.plan_throughput(BITS, BITS, 3.5)
    conv = planner.star_bank_area(BITS, BITS, 3.5)
    assert plan.area < conv
    star_area = area_model.area_um2(BITS, BITS, MCIMConfig(arch="star",
                                                           ct=1))
    fb_area = area_model.area_um2(BITS, BITS, MCIMConfig(arch="fb", ct=2))
    assert abs(plan.area - (3 * star_area + fb_area)) < 1e-6


def test_tp_5_6_combination_bank():
    """Paper Sec. V-B: one CT=2 + one CT=3 -> TP 5/6 with area savings."""
    from fractions import Fraction
    plan = planner.plan_throughput(128, 128, Fraction(5, 6))
    assert plan.throughput == Fraction(5, 6)
    assert plan.area < planner.star_bank_area(128, 128, Fraction(5, 6))
    cts = sorted(cfg.ct for _, cfg in plan.configs)
    assert cts == [2, 3]
