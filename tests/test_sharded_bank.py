"""Sharded multi-bank execution on a 2-device placeholder mesh
(subprocess, like test_distributed_features): ``sharded_execute`` must
be bit-exact vs the Python-bigint oracle and vs the single-bank engine,
for core and kernel backends and both batch-available schedulers.  Also
pins the backend-registry acceptance: the kernel capability routes every
planner arch (star, fb, ff, karatsuba CT=3) through Pallas with no core
fallback."""
import os
import subprocess
import sys

import pytest

from repro.core import planner
from repro.core.bank import backends as B

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
from fractions import Fraction
import numpy as np
import jax, jax.numpy as jnp

from repro.core import limbs as L
from repro.core import planner, bank

assert len(jax.devices()) == 2
mesh = jax.make_mesh((2,), ("data",))
rng = np.random.default_rng(5)

# TP=7/2 (star+fb), TP=5/6 at 128b (fb+karatsuba), strict 1/2 (ff)
cases = [
    (planner.plan_throughput(32, 32, Fraction(7, 2)), 32),
    (planner.plan_throughput(128, 128, Fraction(5, 6)), 128),
    (planner.plan_throughput(64, 64, Fraction(1, 2), strict_timing=True),
     64),
]
for plan, bits in cases:
    a = jnp.asarray(L.random_limbs(rng, (28,), bits))
    b = jnp.asarray(L.random_limbs(rng, (28,), bits))
    expect = [L.from_limbs(np.asarray(x)) * L.from_limbs(np.asarray(y))
              for x, y in zip(a, b)]
    for backend in ("core", "kernel"):
        for sched in ("round_robin", "greedy"):
            out = bank.sharded_execute(plan, a, b, mesh, "data",
                                       backend=backend, scheduler=sched)
            assert L.batch_from_limbs(np.asarray(out)) == expect, \
                (plan.describe(), backend, sched)
            single = bank.execute(plan, a, b, backend=backend,
                                  scheduler=sched)
            assert np.array_equal(np.asarray(out), np.asarray(single))
print("OK sharded-exact")

# the output really is sharded along the axis
plan, bits = cases[0]
a = jnp.asarray(L.random_limbs(rng, (28,), bits))
b = jnp.asarray(L.random_limbs(rng, (28,), bits))
out = bank.sharded_execute(plan, a, b, mesh, "data")
[spec] = {s.spec for s in [out.sharding]}
assert spec[0] == "data", spec
print("OK sharded-layout")

# per-replica accounting: each bank replica sees B/N ops
rep = bank.sharded_report(plan, 28, bits, bits, mesh, "data")
assert rep.batch == 14
assert sum(ir.n_ops for ir in rep.instances) == 14
print("OK sharded-report")

# divisibility and axis guards
try:
    bank.sharded_execute(plan, a[:27], b[:27], mesh, "data")
    raise AssertionError("ragged batch accepted")
except ValueError:
    pass
try:
    bank.sharded_execute(plan, a, b, mesh, "model")
    raise AssertionError("unknown axis accepted")
except ValueError:
    pass
print("OK sharded-guards")
print("ALLOK")
"""


def test_sharded_bank_bit_exact_two_devices():
    env = dict(os.environ,
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-4000:]
    assert "ALLOK" in out.stdout, out.stdout


# ---------------------------------------------------- backend registry

def test_kernel_capability_has_no_core_fallback():
    """Every planner arch resolves to a Pallas big_mul partial under the
    kernel capability -- the PR-2 Karatsuba core fallback is gone."""
    from repro.kernels.mcim_fold.ops import big_mul
    from repro.core.mcim import MCIMConfig
    for arch, cfg in [
            ("star", MCIMConfig(arch="star", ct=1)),
            ("fb", MCIMConfig(arch="fb", ct=2)),
            ("ff", MCIMConfig(arch="ff", ct=2)),
            ("karatsuba", MCIMConfig(arch="karatsuba", ct=3))]:
        be = B.get_backend(arch, "kernel")
        mul = be.make_mul(cfg, 8, 8)
        assert getattr(mul, "func", None) is big_mul, (arch, mul)
    kw = B.get_backend("karatsuba", "kernel").make_mul(
        MCIMConfig(arch="karatsuba", ct=3), 8, 8).keywords
    assert kw == {"ct": 3, "schedule": "karatsuba"}


def test_every_planner_arch_has_both_capabilities():
    keys = B.registered_backends()
    for arch in ("star", "fb", "ff", "karatsuba"):
        for cap in B.CAPABILITIES:
            assert (arch, cap) in keys
    with pytest.raises(ValueError):
        B.get_backend("star", "fpga")


def test_unknown_backend_capability_rejected_by_bank():
    from repro.core.bank import Bank
    plan = planner.plan_throughput(32, 32, 1)
    with pytest.raises(ValueError):
        Bank(plan, 32, 32, backend="fpga")
