"""Karatsuba-PPM and prefix-adder kernels vs oracles (+ hypothesis)."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import limbs as L
from repro.kernels.karatsuba_ppm import karatsuba_ppm_mul, kara_mul
from repro.kernels.prefix_adder import (prefix_final_adder,
                                        prefix_final_adder_ref,
                                        fast_final_adder)

RNG = np.random.default_rng(21)


# ------------------------------------------------------------ karatsuba_ppm

@pytest.mark.parametrize("bits", [32, 64, 128, 256])
def test_kara_kernel_exact(bits):
    a = L.random_limbs(RNG, (32,), bits)
    b = L.random_limbs(RNG, (32,), bits)
    out = np.asarray(karatsuba_ppm_mul(jnp.asarray(a), jnp.asarray(b),
                                       tile_b=16, interpret=True))
    for ai, bi, oi in zip(a, b, out):
        assert L.from_limbs(oi) == L.from_limbs(ai) * L.from_limbs(bi)


def test_kara_kernel_edge_values():
    vals = [0, 1, 2**64 - 1, 2**63, 0xFFFF0000FFFF0000]
    a = jnp.asarray(L.batch_to_limbs(vals, 4))
    b = jnp.asarray(L.batch_to_limbs(list(reversed(vals)), 4))
    out = np.asarray(kara_mul(a, b))
    for va, vb, row in zip(vals, reversed(vals), out):
        assert L.from_limbs(row) == va * vb


@settings(max_examples=30, deadline=None)
@given(st.integers(0, 2**128 - 1), st.integers(0, 2**128 - 1))
def test_kara_kernel_property(x, y):
    a = jnp.asarray(L.to_limbs(x, 8))[None]
    b = jnp.asarray(L.to_limbs(y, 8))[None]
    out = np.asarray(karatsuba_ppm_mul(a, b, tile_b=1, interpret=True))[0]
    assert L.from_limbs(out) == x * y


# ------------------------------------------------------------ prefix adder

@pytest.mark.parametrize("width", [4, 8, 17, 32, 64])
def test_prefix_adder_matches_1ca(width):
    cols = jnp.asarray(RNG.integers(0, 2**24, (64, width), dtype=np.uint32))
    got = prefix_final_adder(cols, tile_b=32, interpret=True)
    want = prefix_final_adder_ref(cols)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_prefix_adder_worst_case_ripple():
    """All-MASK columns: the carry must ripple the full width."""
    width = 16
    cols = jnp.full((4, width), L.MASK, jnp.uint32).at[:, 0].add(1)
    got = np.asarray(fast_final_adder(cols))
    want = np.asarray(prefix_final_adder_ref(cols))
    np.testing.assert_array_equal(got, want)


@settings(max_examples=40, deadline=None)
@given(st.lists(st.integers(0, 2**31), min_size=2, max_size=24))
def test_prefix_adder_property(colvals):
    cols = jnp.asarray(np.array(colvals, np.uint32))[None]
    got = np.asarray(prefix_final_adder(cols, tile_b=1, interpret=True))[0]
    want = np.asarray(prefix_final_adder_ref(cols))[0]
    np.testing.assert_array_equal(got, want)


def test_prefix_adder_log_depth():
    """Structural claim: combine rounds = ceil(log2(width)), not width."""
    import math
    width = 64
    # rounds needed = ceil(log2(64)) = 6 shifts: 1,2,4,8,16,32
    shifts = []
    s = 1
    while s < width:
        shifts.append(s)
        s *= 2
    assert len(shifts) == math.ceil(math.log2(width))
