"""Correctness of the core MCIM multipliers vs Python's bigint oracle.

This is the analogue of the paper's VCS simulation with random inputs
(Sec. IV): every architecture x CT x width is checked bit-exactly.
"""
import numpy as np
import pytest
import jax
import jax.numpy as jnp
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import limbs as L
from repro.core import (MCIMConfig, mcim_mul, star_mul, feedback_mul,
                        feedforward_mul, karatsuba_mul, mul32x32_64)

RNG = np.random.default_rng(0)


def _rand_pair(bits_a, bits_b, batch=8):
    a = L.random_limbs(RNG, (batch,), bits_a)
    b = L.random_limbs(RNG, (batch,), bits_b)
    return a, b


def _check(fn, a, b, modulo_limbs=None):
    out = np.asarray(fn(jnp.asarray(a), jnp.asarray(b)))
    for ai, bi, oi in zip(a, b, out):
        expect = L.from_limbs(ai) * L.from_limbs(bi)
        if modulo_limbs:
            expect %= 1 << (16 * modulo_limbs)
        assert L.from_limbs(oi) == expect, (
            f"{L.from_limbs(ai)} * {L.from_limbs(bi)}: "
            f"got {L.from_limbs(oi)}, want {expect}")


# ---------------------------------------------------------------- star

@pytest.mark.parametrize("bits", [8, 16, 32, 64, 128, 256])
def test_star_exact(bits):
    a, b = _rand_pair(bits, bits)
    _check(star_mul, a, b)


def test_star_rectangular():
    a, b = _rand_pair(128, 64)
    _check(star_mul, a, b)


def test_star_3ca_adder():
    a, b = _rand_pair(48, 48)
    _check(lambda x, y: star_mul(x, y, adder="3ca"), a, b)


# ------------------------------------------------------------- feedback

@pytest.mark.parametrize("bits", [16, 32, 64, 128])
@pytest.mark.parametrize("ct", [2, 3, 4, 5, 8])
def test_feedback_exact(bits, ct):
    a, b = _rand_pair(bits, bits)
    _check(lambda x, y: feedback_mul(x, y, ct=ct), a, b)


def test_feedback_rectangular_128x64():
    """Paper Table IX case."""
    a, b = _rand_pair(128, 64)
    _check(lambda x, y: feedback_mul(x, y, ct=2), a, b)


def test_feedback_chunk_padding():
    # LB not divisible by CT exercises the padding path: 80 bits / CT 3.
    a, b = _rand_pair(80, 80)
    _check(lambda x, y: feedback_mul(x, y, ct=3), a, b)


# ----------------------------------------------------------- feedforward

@pytest.mark.parametrize("bits", [16, 32, 64, 128])
@pytest.mark.parametrize("ct", [2, 3, 4])
def test_feedforward_exact(bits, ct):
    a, b = _rand_pair(bits, bits)
    _check(lambda x, y: feedforward_mul(x, y, ct=ct), a, b)


def test_feedforward_3ca():
    a, b = _rand_pair(64, 64)
    _check(lambda x, y: feedforward_mul(x, y, ct=3, adder="3ca"), a, b)


# -------------------------------------------------------------- karatsuba

@pytest.mark.parametrize("bits", [32, 64, 128, 256])
@pytest.mark.parametrize("levels", [1, 2, 3])
def test_karatsuba_exact(bits, levels):
    a, b = _rand_pair(bits, bits)
    _check(lambda x, y: karatsuba_mul(x, y, levels=levels), a, b)


def test_karatsuba_odd_limbs():
    a, b = _rand_pair(48, 48)   # 3 limbs -> internal pad to 4
    _check(lambda x, y: karatsuba_mul(x, y, levels=1), a, b)


def test_karatsuba_3ca():
    a, b = _rand_pair(128, 128)
    _check(lambda x, y: karatsuba_mul(x, y, levels=2, adder="3ca"), a, b)


# ---------------------------------------------------------------- signed

@pytest.mark.parametrize("arch,ct", [("star", 1), ("fb", 2), ("ff", 2),
                                     ("karatsuba", 3)])
def test_signed_mul(arch, ct):
    bits = 64
    a, b = _rand_pair(bits, bits)
    cfg = MCIMConfig(arch=arch, ct=ct, signed=True)
    out = np.asarray(mcim_mul(jnp.asarray(a), jnp.asarray(b), cfg))
    width = 2 * bits
    for ai, bi, oi in zip(a, b, out):
        ua, ub = L.from_limbs(ai), L.from_limbs(bi)
        sa = ua - (1 << bits) if ua >> (bits - 1) else ua
        sb = ub - (1 << bits) if ub >> (bits - 1) else ub
        expect = (sa * sb) % (1 << width)
        assert L.from_limbs(oi) == expect


# ---------------------------------------------------------- property-based

@settings(max_examples=60, deadline=None)
@given(st.integers(0, 2**128 - 1), st.integers(0, 2**128 - 1),
       st.sampled_from([("fb", 2, 1), ("fb", 5, 1), ("ff", 2, 1),
                        ("ff", 3, 1), ("karatsuba", 3, 1),
                        ("karatsuba", 3, 2), ("star", 1, 1)]))
def test_property_all_archs_match_oracle(x, y, spec):
    arch, ct, levels = spec
    a = jnp.asarray(L.to_limbs(x, 8))[None]
    b = jnp.asarray(L.to_limbs(y, 8))[None]
    cfg = MCIMConfig(arch=arch, ct=ct, levels=levels)
    out = np.asarray(mcim_mul(a, b, cfg))[0]
    assert L.from_limbs(out) == x * y


@settings(max_examples=40, deadline=None)
@given(st.integers(0, 2**64 - 1), st.integers(2, 8))
def test_property_edge_operands(x, ct):
    """Edge cases: 0, 1, all-ones against a random operand."""
    for y in (0, 1, 2**64 - 1, 2**63):
        a = jnp.asarray(L.to_limbs(x, 4))[None]
        b = jnp.asarray(L.to_limbs(y, 4))[None]
        out = np.asarray(feedback_mul(a, b, ct=ct))[0]
        assert L.from_limbs(out) == x * y


@settings(max_examples=40, deadline=None)
@given(st.integers(0, 2**32 - 1), st.integers(0, 2**32 - 1))
def test_property_mul32x32(x, y):
    lo, hi = mul32x32_64(jnp.uint32(x), jnp.uint32(y))
    got = (int(hi) << 32) | int(lo)
    assert got == x * y


# ------------------------------------------------------------ limb helpers

@settings(max_examples=50, deadline=None)
@given(st.integers(0, 2**256 - 1))
def test_limb_roundtrip(x):
    assert L.from_limbs(L.to_limbs(x, 16)) == x


def test_final_adders_agree():
    cols = jnp.asarray(RNG.integers(0, 2**20, size=(4, 11), dtype=np.uint32))
    a1 = np.asarray(L.final_adder_1ca(cols))
    a3 = np.asarray(L.final_adder_3ca(cols))
    np.testing.assert_array_equal(a1, a3)


def test_ppm_column_bound():
    """Column sums stay far below uint32 overflow for supported widths."""
    a, b = _rand_pair(512, 512, batch=2)
    cols = np.asarray(L.ppm(jnp.asarray(a), jnp.asarray(b)))
    assert cols.max() < 2**28  # 2*32 limbs * 2^16 ~ 2^22


def test_vmap_and_jit_compose():
    mul = jax.jit(lambda a, b: feedback_mul(a, b, ct=4))
    a, b = _rand_pair(64, 64, batch=16)
    out = np.asarray(jax.vmap(mul)(jnp.asarray(a), jnp.asarray(b)))
    _check(lambda x, y: feedback_mul(x, y, ct=4), a, b)
    ref = np.asarray(mul(jnp.asarray(a), jnp.asarray(b)))
    np.testing.assert_array_equal(out, ref)
