"""Decode-path consistency: incremental decode == full-forward prefill.

For each cache-bearing architecture family: prefill a prefix, then
decode teacher-forced tokens one at a time; after each step, the decode
logits must match the last-position logits of a *fresh full prefill*
over the extended sequence.  This validates KV caches (incl. gemma3
ring buffers), Mamba2 SSD chunked<->recurrent equivalence, zamba2's
shared-attention cache stacking, and the int8 KV cache (looser tol).
"""
import dataclasses

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import build_model

P0, STEPS, B = 64, 3, 2
S_CAP = 128


def _tokens(cfg, n):
    rng = np.random.default_rng(5)
    return jnp.asarray(rng.integers(0, cfg.vocab_size, (B, n)), jnp.int32)


def _check(arch, atol_scale=0.05, **overrides):
    cfg = get_config(arch, smoke=True)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    toks = _tokens(cfg, P0 + STEPS)

    caches, logits = model.prefill(params, {"tokens": toks[:, :P0]},
                                   s_cap=S_CAP)
    for j in range(STEPS):
        tok = toks[:, P0 + j - 1] if j > 0 else jnp.argmax(logits, -1)
        # teacher-force with the true next token for comparability
        tok = toks[:, P0 + j]
        pos = jnp.full((B,), P0 + j, jnp.int32)
        caches, dec_logits = model.decode_step(params, caches, tok, pos)
        _, ref_logits = model.prefill(
            params, {"tokens": toks[:, :P0 + j + 1]}, s_cap=S_CAP)
        d = np.asarray(dec_logits, np.float32)
        r = np.asarray(ref_logits, np.float32)
        scale = max(np.std(r), 1e-3)
        err = np.abs(d - r).max() / scale
        assert err < atol_scale, (arch, j, err)


@pytest.mark.parametrize("arch", ["qwen3-32b", "minitron-8b"])
def test_dense_decode_matches_prefill(arch):
    _check(arch)


def test_gemma3_ring_cache_decode():
    """Sliding-window ring buffers + dual-theta local/global pattern."""
    _check("gemma3-1b")


def test_gemma2_softcap_decode():
    # gemma2's smoke-config logit std is ~0.25, so a single bf16 ulp at
    # logit magnitude ~2.5 (= 2**-6 = 0.0156) already reads as 6% of std.
    # Observed decode-vs-prefill gap is exactly 1 ulp on one vocab entry
    # (everything else <= 0.004); structural cache bugs show up as
    # O(1-10x), so 0.1 still catches them.
    _check("gemma2-9b", atol_scale=0.1)


def test_moe_decode_token_choice():
    """Decode uses exact token-choice routing; prefix uses EC -- the
    routing paths must still agree on cached-attention logits."""
    _check("dbrx-132b", atol_scale=0.08)


def test_mamba2_ssd_chunked_equals_recurrent():
    """The SSD identity: chunked (train/prefill) == recurrent (decode)."""
    _check("mamba2-370m")


def test_zamba2_hybrid_decode():
    # chunked-vs-recurrent SSD orderings through 5 mixed (attn+ssm)
    # layers accumulate ~6% of logit std in bf16; structural cache bugs
    # show up as O(1-10x) here.
    _check("zamba2-1.2b", atol_scale=0.12)


def test_int8_kv_cache_close_to_bf16():
    """MCIM int8 KV cache: small, bounded degradation vs bf16 cache."""
    _check("qwen3-32b", atol_scale=0.25, kv_cache_dtype="int8")


def test_int8_kv_cache_argmax_agreement():
    cfg8 = get_config("qwen3-32b", smoke=True, kv_cache_dtype="int8")
    cfg16 = get_config("qwen3-32b", smoke=True)
    m8, m16 = build_model(cfg8), build_model(cfg16)
    params = m16.init(jax.random.PRNGKey(0))
    toks = _tokens(cfg16, P0)
    c8, l8 = m8.prefill(params, {"tokens": toks}, s_cap=S_CAP)
    c16, l16 = m16.prefill(params, {"tokens": toks}, s_cap=S_CAP)
    agree = (np.argmax(np.asarray(l8), -1)
             == np.argmax(np.asarray(l16), -1)).mean()
    assert agree >= 0.5, agree


def test_moe_local_dispatch_close_to_global():
    """§Perf knob: shard-local EC must stay close to global EC on a
    single shard (identical when G=1 by construction)."""
    cfg_l = get_config("dbrx-132b", smoke=True, moe_local_dispatch=True)
    cfg_g = get_config("dbrx-132b", smoke=True)
    ml, mg = build_model(cfg_l), build_model(cfg_g)
    params = mg.init(jax.random.PRNGKey(0))
    batch = {"tokens": _tokens(cfg_g, 64),
             "labels": _tokens(cfg_g, 64),
             "mask": jnp.ones((B, 64), jnp.float32)}
    ll = float(ml.train_loss(params, batch))
    lg = float(mg.train_loss(params, batch))
    assert abs(ll - lg) < 1e-3, (ll, lg)   # mesh=None -> same code path
