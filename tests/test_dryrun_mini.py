"""Mini dry-run: the full lower+compile+analyze pipeline on an 8-device
placeholder mesh (subprocess so the 1-device main process is untouched).

This is the cheap gate in front of the 256/512-device production runs:
if sharding specs, cache scatter, collective parsing, or roofline math
are broken, it surfaces here in seconds.
"""
import json
import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax
from repro.configs.base import ShapeCfg
from repro.launch.dryrun import run_cell

mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
shape = ShapeCfg("mini_{kind}", {seq}, {batch}, "{kind}")
res = run_cell("{arch}", None, "mini", mesh=mesh, shape_cfg=shape,
               smoke=True)
print("RESULT" + json.dumps(res))
"""


def _run(arch, kind, seq, batch):
    code = SCRIPT.format(arch=arch, kind=kind, seq=seq, batch=batch)
    env = dict(os.environ,
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-4000:]
    line = [l for l in out.stdout.splitlines() if l.startswith("RESULT")][-1]
    return json.loads(line[len("RESULT"):])


@pytest.mark.parametrize("arch,kind", [
    ("qwen3-32b", "train"),
    ("gemma3-1b", "train"),        # local/global groups + tail
    ("dbrx-132b", "train"),        # MoE expert-choice + EP sharding
    ("mamba2-370m", "train"),      # SSD scan
    ("zamba2-1.2b", "decode"),     # hybrid caches (ring + state)
    ("qwen3-32b", "decode"),
    ("gemma2-9b", "prefill"),
    ("hubert-xlarge", "prefill"),  # encoder forward
    ("paligemma-3b", "train"),     # vlm prefix-lm
])
def test_mini_dryrun_cell(arch, kind):
    seq, batch = (256, 8) if kind != "decode" else (256, 8)
    res = _run(arch, kind, seq, batch)
    assert res["n_devices"] == 8
    assert res["flops_per_device"] > 0
    assert res["roofline"]["dominant"] in ("compute", "memory", "collective")
    # a distributed step must actually communicate
    total_coll = sum(c["count"] for c in res["collectives"].values())
    assert total_coll > 0, res["collectives"]
