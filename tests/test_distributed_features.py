"""Multi-device features on a 4-device placeholder mesh (subprocess):
elastic checkpoint re-shard, shard_map exact psum, int8 compressed psum,
and sharded train-step integration."""
import os
import subprocess
import sys

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import numpy as np
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

# ---------------- elastic checkpoint re-shard ----------------
from repro.checkpoint import CheckpointManager
import tempfile

tmp = tempfile.mkdtemp()
mgr = CheckpointManager(tmp)
tree = {"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8)}
mgr.save(3, tree)                      # written from replicated layout

mesh = jax.make_mesh((2, 2), ("data", "model"))
sh = {"w": NamedSharding(mesh, P("data", "model"))}
out = mgr.restore(3, tree, shardings=sh)
assert out["w"].sharding == sh["w"], out["w"].sharding
np.testing.assert_array_equal(np.asarray(out["w"]), np.asarray(tree["w"]))
print("OK elastic-reshard")

# ---------------- exact psum inside shard_map ----------------
from repro.compat import shard_map
from repro.exact import exact_psum

dmesh = jax.make_mesh((4,), ("data",))
x = jnp.asarray(np.random.default_rng(0).standard_normal((4, 16)),
                jnp.float32)

def f(xs):
    return exact_psum(xs[0], "data")

got = shard_map(f, mesh=dmesh, in_specs=P("data", None),
                out_specs=P(), check_vma=False)(x)
# exact sum must be permutation-invariant: compare against a permuted
# device order by rolling shards
got2 = shard_map(f, mesh=dmesh, in_specs=P("data", None),
                 out_specs=P(), check_vma=False)(jnp.roll(x, 1, axis=0))
np.testing.assert_array_equal(np.asarray(got), np.asarray(got2))
ref = np.sum(np.asarray(x, np.float64), axis=0)
np.testing.assert_allclose(np.asarray(got), ref, rtol=1e-5, atol=1e-5)
print("OK exact-psum")

# ---------------- int8 compressed psum w/ error feedback ----------------
from repro.optim.compress import compressed_psum, init_error

g = jnp.asarray(np.random.default_rng(1).standard_normal((4, 8, 32)),
                jnp.float32)

def step(gs):
    grads = {"g": gs[0]}
    err = init_error(grads)
    out, new_err = compressed_psum(grads, err, "data")
    return out["g"], new_err["g"][None]     # restore leading shard axis

avg, err = shard_map(step, mesh=dmesh, in_specs=P("data", None, None),
                     out_specs=(P(), P("data", None, None)),
                     check_vma=False)(g)
true_avg = np.mean(np.asarray(g, np.float64), axis=0)
rel = np.linalg.norm(np.asarray(avg) - true_avg) / np.linalg.norm(true_avg)
assert rel < 0.05, rel
assert float(jnp.abs(err).max()) > 0       # residual captured
print("OK compressed-psum", rel)

# ---------------- sharded end-to-end train step ----------------
from repro.configs import get_config
from repro.models import build_model
from repro.optim import AdamWConfig, init_state
from repro.runtime import make_train_step
from repro.data import DataConfig, PatternLM, device_batch

cfg = get_config("qwen3-32b", smoke=True)
model = build_model(cfg)
step_fn = make_train_step(model, AdamWConfig(lr=1e-3, warmup_steps=0),
                          mesh)
params = model.init(jax.random.PRNGKey(0))
pspecs = model.param_specs(mesh)
params = jax.tree_util.tree_map(
    lambda a, s: jax.device_put(a, NamedSharding(mesh, s)), params, pspecs)
opt = init_state(params)
src = PatternLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=64,
                           global_batch=4, source="pattern"))
losses = []
for i in range(4):
    batch = device_batch(src.batch_at(i), mesh)
    params, opt, stats = step_fn(params, opt, batch)
    losses.append(float(stats["loss"]))
assert all(np.isfinite(losses)), losses
assert losses[-1] < losses[0]
print("OK sharded-train", [round(l, 3) for l in losses])
print("ALLOK")
"""


def test_distributed_features():
    env = dict(os.environ,
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-4000:]
    assert "ALLOK" in out.stdout, out.stdout
