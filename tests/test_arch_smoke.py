"""Per-architecture smoke tests on reduced configs (CPU, 1 device).

Each assigned arch: instantiate the reduced config, run one forward /
train-loss step, assert output shapes and finiteness.  Decode-capable
archs additionally run prefill + 2 decode steps.
"""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import ARCH_NAMES, get_config
from repro.models import build_model

KEY = jax.random.PRNGKey(0)
B, S = 2, 128


def _batch(model, cfg):
    rng = np.random.default_rng(1)
    f = cfg.family
    if f == "encoder":
        from repro.models.encoder import D_FRONTEND
        return {
            "frames": jnp.asarray(
                rng.standard_normal((B, S, D_FRONTEND)), jnp.bfloat16),
            "mask": jnp.asarray(rng.random((B, S)) < 0.2),
            "labels": jnp.asarray(
                rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32),
        }
    if f == "vlm":
        nv = cfg.n_vis_tokens
        st = S - nv
        return {
            "image_embeds": jnp.asarray(
                rng.standard_normal((B, nv, cfg.d_vis)), jnp.bfloat16),
            "tokens": jnp.asarray(
                rng.integers(0, cfg.vocab_size, (B, st)), jnp.int32),
            "labels": jnp.asarray(
                rng.integers(0, cfg.vocab_size, (B, st)), jnp.int32),
            "mask": jnp.ones((B, st), jnp.float32),
        }
    return {
        "tokens": jnp.asarray(
            rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32),
        "labels": jnp.asarray(
            rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32),
        "mask": jnp.ones((B, S), jnp.float32),
    }


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_smoke_train_step(arch):
    cfg = get_config(arch, smoke=True)
    model = build_model(cfg)
    params = model.init(KEY)
    batch = _batch(model, cfg)

    loss, grads = jax.jit(jax.value_and_grad(
        lambda p: model.train_loss(p, batch)))(params)
    assert np.isfinite(float(loss)), (arch, float(loss))
    gnorms = [float(jnp.linalg.norm(g.astype(jnp.float32)))
              for g in jax.tree_util.tree_leaves(grads)]
    assert all(np.isfinite(g) for g in gnorms), arch
    assert any(g > 0 for g in gnorms), f"{arch}: all-zero grads"


@pytest.mark.parametrize("arch", [a for a in ARCH_NAMES
                                  if a != "hubert-xlarge"])
def test_smoke_prefill_decode(arch):
    cfg = get_config(arch, smoke=True)
    model = build_model(cfg)
    params = model.init(KEY)
    batch = _batch(model, cfg)
    s_cap = 256

    prefill_batch = dict(batch)
    caches, logits = model.prefill(params, prefill_batch, s_cap=s_cap)
    vocab = cfg.padded_vocab
    assert logits.shape == (B, vocab), (arch, logits.shape)
    assert np.isfinite(np.asarray(logits, np.float32)).all(), arch

    pos = jnp.full((B,), S, jnp.int32)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    for step in range(2):
        caches, logits = model.decode_step(params, caches, tok,
                                           pos + step)
        assert logits.shape == (B, vocab)
        assert np.isfinite(np.asarray(logits, np.float32)).all(), arch
        tok = jnp.argmax(logits, -1).astype(jnp.int32)


def test_smoke_encoder_forward():
    cfg = get_config("hubert-xlarge", smoke=True)
    model = build_model(cfg)
    params = model.init(KEY)
    batch = _batch(model, cfg)
    _, logits = model.prefill(params, batch)
    assert logits.shape == (B, S, cfg.padded_vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_param_spec_tree_matches_params(arch):
    """Spec tree and param tree must have identical structure."""
    cfg = get_config(arch, smoke=True)
    model = build_model(cfg)
    params = model.abstract_params()
    from jax.sharding import Mesh
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1),
                ("data", "model"))
    specs = model.param_specs(mesh)
    s1 = jax.tree_util.tree_structure(params)
    s2 = jax.tree_util.tree_structure(specs)
    assert s1 == s2, arch


def test_param_counts_roughly_match_names():
    """Full configs should land near their advertised sizes."""
    expect = {
        "qwen3-32b": (28e9, 36e9),
        "minitron-8b": (7e9, 10e9),
        "gemma3-1b": (0.8e9, 1.6e9),
        "gemma2-9b": (8e9, 11e9),
        "dbrx-132b": (110e9, 140e9),
        "mamba2-370m": (0.3e9, 0.5e9),
        "zamba2-1.2b": (0.9e9, 1.6e9),
        "paligemma-3b": (2e9, 3.5e9),   # LM part (vision stubbed)
        "hubert-xlarge": (0.8e9, 1.3e9),
    }
    for arch, (lo, hi) in expect.items():
        n = build_model(get_config(arch)).param_count()
        assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B not in [{lo/1e9}, {hi/1e9}]"
