"""Tests for repro.verify: interval analysis, contracts, lint, CLI gate."""
import dataclasses
import json
import subprocess
import sys
from fractions import Fraction
from pathlib import Path

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import limbs as L
from repro.core import planner
from repro.core.mcim import MCIMConfig
from repro import verify
from repro.verify import contracts, intervals, lint
from repro.designs import DesignSpec, generate, registry
from repro.kernels.mcim_fold import fold_geometry

SRC_ROOT = Path(__file__).resolve().parent.parent / "src" / "repro"


# ------------------------------------------------------------ acceptance

@pytest.mark.parametrize("name", registry.names())
def test_analyzer_accepts_every_registered_design(name):
    """Every named design must prove safe -- generate() would raise
    otherwise, since the gate runs at plan time."""
    design = generate(name)
    assert verify.verify_design(design) == ()


@pytest.mark.parametrize("arch,ct,levels,adder", [
    ("star", 1, 1, "1ca"),
    ("fb", 2, 1, "1ca"), ("fb", 12, 1, "1ca"),
    ("ff", 2, 1, "1ca"), ("ff", 6, 1, "1ca"),
    ("karatsuba", 3, 1, "1ca"), ("karatsuba", 3, 3, "3ca"),
])
@pytest.mark.parametrize("bits", [8, 32, 128])
def test_vocabulary_proves_safe_on_both_substrates(arch, ct, levels,
                                                   adder, bits):
    cfg = MCIMConfig(arch=arch, ct=ct, levels=levels, adder=adder)
    for substrate in ("core", "kernel"):
        rep = intervals.analyze(bits, bits, cfg, substrate=substrate)
        assert rep.ok, rep.violations
        assert rep.headroom_bits > 0
        assert rep.max_column <= L.U32_MAX


def test_signed_wrapper_proves_safe():
    cfg = MCIMConfig(arch="fb", ct=2, signed=True)
    assert verify.verify_instance(32, 32, cfg) == ()


# --------------------------------------------- seeded counterexamples

def test_rejects_scratch_one_column_too_narrow():
    """Counterexample 1: a kernel declaring one fewer scratch column
    than the interval analysis requires must be rejected."""
    cfg = MCIMConfig(arch="fb", ct=2)
    required = intervals.required_scratch_width(32, 32, cfg)
    ok = contracts.check_widths(32, 32, cfg)
    assert ok == []
    bad = contracts.check_widths(32, 32, cfg, scratch_width=required - 1)
    assert any(v.rule == "scratch-too-narrow" for v in bad)


def test_rejects_double_covering_schedule():
    """Counterexample 2: a schedule whose cycle windows overlap
    accumulates a partial product twice."""
    la = lb = L.n_limbs_for_bits(32)
    geo = fold_geometry(la, lb, 2, "fb")
    assert contracts.check_coverage(32, 32, MCIMConfig(arch="fb",
                                                       ct=2)) == []
    # corrupt: second window re-reads the first window's last limb
    bad_windows = (geo.b_windows[0],
                   (geo.b_windows[1][0] - 1, geo.b_windows[1][1]))
    bad = contracts.check_coverage(32, 32, MCIMConfig(arch="fb", ct=2),
                                   windows=bad_windows)
    assert any(v.rule == "double-cover" for v in bad)


def test_rejects_undercovering_schedule():
    la = lb = L.n_limbs_for_bits(64)
    geo = fold_geometry(la, lb, 2, "fb")
    bad_windows = geo.b_windows[:-1]            # last chunk never runs
    bad = contracts.check_coverage(64, 64, MCIMConfig(arch="fb", ct=2),
                                   windows=bad_windows)
    assert any(v.rule == "missing-product" for v in bad)


def test_interval_analyzer_detects_overflowing_design():
    """A pathological design point the analyzer must refute: compress
    bounds past uint32 are reported, not silently accepted."""
    ctx = intervals._Ctx()
    huge = [L.U32_MAX] * 4
    intervals.compress_bounds([(huge, 0), (huge, 0)], 4, ctx, "seeded")
    assert ctx.violations
    assert all(v.rule == "u32-overflow" for v in ctx.violations)


def test_throughput_sum_mismatch_detected():
    configs = ((1, MCIMConfig(arch="star", ct=1)),
               (1, MCIMConfig(arch="fb", ct=2)))
    assert contracts.check_throughput(configs, Fraction(3, 2)) == []
    bad = contracts.check_throughput(configs, Fraction(7, 4))
    assert any(v.rule == "throughput-sum" for v in bad)


def test_assert_plan_raises_with_structured_violations():
    configs = ((1, MCIMConfig(arch="fb", ct=2)),)
    with pytest.raises(verify.VerificationError) as e:
        verify.assert_plan(32, 32, configs, Fraction(1, 3))
    assert e.value.violations
    assert any(v.rule == "throughput-sum" for v in e.value.violations)


# -------------------------------------------------- plan-time gating

def test_generate_calls_the_verifier(monkeypatch):
    """generate() must route every plan through verify.assert_plan."""
    calls = []
    real = verify.assert_plan

    def spy(bits_a, bits_b, configs, throughput=None):
        calls.append((bits_a, bits_b, tuple(configs), throughput))
        return real(bits_a, bits_b, configs, throughput)

    monkeypatch.setattr(verify, "assert_plan", spy)
    design = generate(DesignSpec(32, 32, Fraction(1, 2)))
    assert calls, "generate() never invoked the static verifier"
    bits_a, bits_b, configs, tp = calls[0]
    assert (bits_a, bits_b) == (32, 32)
    assert configs == design.plan.configs
    assert tp == design.plan.throughput


def test_autotune_score_calls_the_verifier(monkeypatch):
    import importlib
    search_mod = importlib.import_module("repro.autotune.search")
    calls = []
    monkeypatch.setattr(verify, "assert_plan",
                        lambda *a, **k: calls.append(a))
    spec = DesignSpec(16, 16, Fraction(1, 2))
    search_mod.score(spec, ((1, MCIMConfig(arch="fb", ct=2)),))
    assert calls


# -------------------------------------------------- interval soundness

def _concrete_ppm_columns(a_int, b_int, bits):
    n = L.n_limbs_for_bits(bits)
    a = L.to_limbs(a_int, n)
    b = L.to_limbs(b_int, n)
    cols = [0] * (2 * n)
    for i in range(n):
        for j in range(n):
            p = int(a[i]) * int(b[j])
            cols[i + j] += p & L.MASK
            cols[i + j + 1] += p >> L.RADIX_BITS
    return cols


@pytest.mark.parametrize("bits", [8, 16, 32, 128])
def test_ppm_bounds_dominate_random_batches(bits):
    """The abstract PPM bounds dominate concrete column sums for random
    operand batches (the soundness property, numpy edition)."""
    bound = intervals.ppm_bounds(intervals.operand_bounds(bits),
                                 intervals.operand_bounds(bits))
    rng = np.random.default_rng(7)
    hi = (1 << bits) - 1
    for _ in range(50):
        a = int(rng.integers(0, hi, dtype=np.uint64)) if bits <= 63 \
            else int.from_bytes(rng.bytes(bits // 8), "little")
        b = int(rng.integers(0, hi, dtype=np.uint64)) if bits <= 63 \
            else int.from_bytes(rng.bytes(bits // 8), "little")
        cols = _concrete_ppm_columns(a % (1 << bits), b % (1 << bits),
                                     bits)
        assert all(c <= m for c, m in zip(cols, bound))
    # the bound is tight in column 0: all-ones narrow operands reach
    # min(p_max, MASK) directly; wider ones via a full limb times 1
    if bits < L.RADIX_BITS:
        cols = _concrete_ppm_columns((1 << bits) - 1, (1 << bits) - 1,
                                     bits)
    else:
        cols = _concrete_ppm_columns(L.MASK, 1, bits)
    assert cols[0] == bound[0]


def test_hypothesis_property_no_batch_exceeds_bounds():
    """Hypothesis edition of the soundness property (skipped when the
    container lacks hypothesis)."""
    hyp = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")

    @hyp.given(st.integers(min_value=0, max_value=(1 << 32) - 1),
               st.integers(min_value=0, max_value=(1 << 32) - 1))
    @hyp.settings(max_examples=200, deadline=None)
    def prop(a, b):
        bound = intervals.ppm_bounds(intervals.operand_bounds(32),
                                     intervals.operand_bounds(32))
        cols = _concrete_ppm_columns(a, b, 32)
        assert all(c <= m for c, m in zip(cols, bound))

    prop()


def test_required_width_matches_kernel_geometry():
    """The analyzer's required width never exceeds what the kernels
    declare -- for every (arch, ct, width) the kernels implement."""
    for bits in (8, 32, 64, 128):
        la = lb = L.n_limbs_for_bits(bits)
        for ct in (2, 3, 4, 6, 8, 12):
            for arch in ("fb", "ff"):
                cfg = MCIMConfig(arch=arch, ct=ct)
                req = intervals.required_scratch_width(bits, bits, cfg)
                geo = fold_geometry(la, lb, ct, arch)
                assert req <= geo.scratch_width
        cfg = MCIMConfig(arch="karatsuba", ct=3)
        req = intervals.required_scratch_width(bits, bits, cfg)
        geo = fold_geometry(la, lb, 3, "karatsuba")
        assert req <= geo.scratch_width


def test_max_safe_column_terms_helper():
    """The exported budget helper: 16x16 full-width terms cap at
    2*min(la, lb) per column, which every repo width respects."""
    # full 16-bit limbs: the worst term is a lo half capped at MASK
    assert L.MAX_SAFE_COLUMN_TERMS(16, 16) == L.U32_MAX // L.MASK
    # 128x128b (8x8 limbs): 16 terms/column needed, budget must cover it
    assert 2 * 8 <= L.MAX_SAFE_COLUMN_TERMS(128, 128)
    # narrow operands leave a far larger budget
    assert L.MAX_SAFE_COLUMN_TERMS(4, 4) > L.MAX_SAFE_COLUMN_TERMS(16, 16)


# --------------------------------------------------------------- lint

def test_lint_clean_on_repo_tree():
    violations = lint.lint_tree(SRC_ROOT)
    assert violations == [], "\n".join(v.describe() for v in violations)


def test_lint_flags_traced_branch_and_cast():
    bad = (
        "import jax\n"
        "@jax.jit\n"
        "def f(x, y):\n"
        "    if x > 0:\n"
        "        return y\n"
        "    return y * int(x)\n")
    rules = {v.rule for v in lint.lint_source(bad, "bad.py")}
    assert "traced-branch" in rules
    assert "python-int-cast" in rules


def test_lint_flags_annotated_array_loop_and_ternary():
    bad = (
        "import jax\n"
        "def f(x: jax.Array, n: int):\n"
        "    for v in x:\n"
        "        pass\n"
        "    return 1 if x else 0\n")
    rules = {v.rule for v in lint.lint_source(bad, "bad.py")}
    assert "traced-loop" in rules
    assert "traced-ternary" in rules


def test_lint_static_attrs_launder_taint():
    good = (
        "import jax\n"
        "@jax.jit\n"
        "def f(x):\n"
        "    if x.ndim == 1:\n"
        "        return x\n"
        "    n = int(x.shape[0])\n"
        "    m = len(x)\n"
        "    if n > m:\n"
        "        return x\n"
        "    return x\n")
    assert lint.lint_source(good, "good.py") == []


def test_lint_respects_static_argnames():
    good = (
        "import functools, jax\n"
        "@functools.partial(jax.jit, static_argnames=('ct',))\n"
        "def f(x, ct):\n"
        "    if ct > 2:\n"
        "        return x\n"
        "    return x + 1\n")
    assert lint.lint_source(good, "good.py") == []


def test_lint_flags_scheduler_state():
    bad = (
        "class CountingScheduler:\n"
        "    def schedule(self, cts, n_ops):\n"
        "        self.calls = getattr(self, 'calls', 0) + 1\n"
        "        return ((), 0)\n")
    rules = {v.rule for v in lint.lint_source(bad, "bad.py")}
    assert "scheduler-state" in rules


# --------------------------------------------------- scheduler contracts

def test_all_registered_schedulers_meet_contracts():
    assert contracts.check_all_schedulers() == []


def test_scheduler_contract_rejects_incomplete_assignment():
    @dataclasses.dataclass(frozen=True)
    class DropsLastOp:
        name: str = "drops_last"

        def schedule(self, cts, n_ops):
            ops = tuple(range(max(n_ops - 1, 0)))    # drops op n-1
            return (ops,) + ((),) * (len(cts) - 1), len(ops) * cts[0]

    bad = contracts.check_scheduler(DropsLastOp(), (1, 2), 5)
    assert any(v.rule == "scheduler-coverage" for v in bad)


def test_bank_dispatch_is_static():
    plan = planner.plan_throughput(32, 32, Fraction(7, 2))
    assert contracts.check_bank_static(plan, 32, 32) == []


# ----------------------------------------------------------------- CLI

def test_cli_smoke_writes_report_and_exits_zero(tmp_path):
    out = tmp_path / "VERIFY_report.json"
    env_src = str(Path(__file__).resolve().parent.parent / "src")
    proc = subprocess.run(
        [sys.executable, "-m", "repro.verify", "--smoke",
         "--out", str(out)],
        capture_output=True, text=True, timeout=560,
        env={"PYTHONPATH": env_src, "PATH": "/usr/bin:/bin",
             "HOME": "/tmp"})
    assert proc.returncode == 0, proc.stdout + proc.stderr
    report = json.loads(out.read_text())
    assert report["summary"]["ok"] is True
    assert report["summary"]["violations"] == 0
    # Other test modules may register throwaway "_"-prefixed designs in
    # this process; the subprocess sees only the shipped registry.
    shipped = {n for n in registry.names() if not n.startswith("_")}
    assert {r["design"] for r in report["registry"]} >= shipped
    assert all(r["ok"] for r in report["vocabulary"])


def test_kernel_agrees_with_analyzer_required_width():
    """End-to-end cross-check: a design the analyzer proves safe
    multiplies bit-exactly through the kernel substrate."""
    from repro.kernels.mcim_fold import big_mul
    rng = np.random.default_rng(11)
    bits = 64
    n = L.n_limbs_for_bits(bits)
    a = L.random_limbs(rng, (8,), bits)
    b = L.random_limbs(rng, (8,), bits)
    cfg = MCIMConfig(arch="fb", ct=4)
    assert verify.verify_instance(bits, bits, cfg) == ()
    out = np.asarray(big_mul(jnp.asarray(a), jnp.asarray(b), ct=4))
    for k in range(8):
        assert L.from_limbs(out[k]) == \
            L.from_limbs(a[k]) * L.from_limbs(b[k])
