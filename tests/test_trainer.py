"""Integration tests for the fault-tolerant trainer."""
import os
import signal

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import build_model
from repro.optim import AdamWConfig
from repro.runtime import TrainerConfig, train, make_train_step
from repro.data import DataConfig, SyntheticLM, PatternLM


def _setup(tmp_path, steps=8, **tkw):
    cfg = get_config("qwen3-32b", smoke=True)
    model = build_model(cfg)
    data = DataConfig(vocab_size=cfg.vocab_size, seq_len=64, global_batch=4,
                      source="pattern")
    src = PatternLM(data)
    opt = AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=steps)
    tcfg = TrainerConfig(steps=steps, checkpoint_every=4,
                         checkpoint_dir=str(tmp_path), log_every=0, **tkw)
    return model, src, opt, tcfg


def test_train_loss_decreases(tmp_path):
    model, src, opt, tcfg = _setup(tmp_path, steps=10)
    res = train(model, src, opt, tcfg, resume=False)
    assert res.final_step == 10
    assert res.skipped_steps == 0
    assert np.mean(res.losses[-3:]) < np.mean(res.losses[:3])


def test_resume_from_checkpoint(tmp_path):
    model, src, opt, tcfg = _setup(tmp_path, steps=4)
    res1 = train(model, src, opt, tcfg, resume=False)
    assert res1.final_step == 4
    # second run continues to step 8 from the saved step-4 state
    tcfg2 = TrainerConfig(steps=8, checkpoint_every=4,
                          checkpoint_dir=str(tmp_path), log_every=0)
    res2 = train(model, src, opt, tcfg2, resume=True)
    assert res2.final_step == 8
    assert len(res2.losses) == 4            # only steps 4..7 executed


def test_nonfinite_grad_guard():
    cfg = get_config("qwen3-32b", smoke=True)
    model = build_model(cfg)
    opt = AdamWConfig(lr=1e-3, warmup_steps=0)
    step_fn = make_train_step(model, opt)
    params = model.init(jax.random.PRNGKey(0))
    from repro.optim import init_state
    state = init_state(params)
    rng = np.random.default_rng(0)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 64)),
                              jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 64)),
                              jnp.int32),
        "mask": jnp.full((2, 64), jnp.inf, jnp.float32),  # poison -> inf loss
    }
    p0 = jax.tree_util.tree_leaves(params)[0].copy()
    new_params, new_state, stats = step_fn(params, state, batch)
    assert not bool(stats["finite"])
    # params unchanged on the poisoned step
    np.testing.assert_array_equal(
        np.asarray(jax.tree_util.tree_leaves(new_params)[0]),
        np.asarray(p0))
    assert int(new_state["step"]) == 1


def test_exact_accum_microbatches_match_order(tmp_path):
    """MCIM fixed-point accumulation: microbatch order cannot matter."""
    cfg = get_config("mamba2-370m", smoke=True)
    model = build_model(cfg)
    opt = AdamWConfig(lr=1e-3, warmup_steps=0)
    params = model.init(jax.random.PRNGKey(1))
    rng = np.random.default_rng(1)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (4, 64)),
                              jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (4, 64)),
                              jnp.int32),
        "mask": jnp.ones((4, 64), jnp.float32),
    }
    from repro.optim import init_state
    fn = make_train_step(model, opt, microbatches=2, exact_accum=True)
    copy = lambda t: jax.tree_util.tree_map(jnp.copy, t)
    p1, _, s1 = fn(copy(params), init_state(params), batch)
    # permuted microbatch order (swap halves of the batch)
    perm = jnp.asarray([2, 3, 0, 1])
    batch2 = jax.tree_util.tree_map(lambda x: x[perm], batch)
    p2, _, s2 = fn(copy(params), init_state(params), batch2)
    for a, b in zip(jax.tree_util.tree_leaves(p1),
                    jax.tree_util.tree_leaves(p2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_sigterm_triggers_checkpoint(tmp_path):
    """Preemption handling: SIGTERM mid-training checkpoints and stops."""
    import threading

    model, src, opt, tcfg = _setup(tmp_path, steps=200)

    def send_sigterm():
        os.kill(os.getpid(), signal.SIGTERM)

    timer = threading.Timer(6.0, send_sigterm)
    timer.start()
    try:
        res = train(model, src, opt, tcfg, resume=False)
    finally:
        timer.cancel()
    # stopped early and left a restorable checkpoint at the stop step
    assert res.final_step < 200
    from repro.checkpoint import CheckpointManager
    mgr = CheckpointManager(str(tmp_path))
    assert mgr.latest_step() == res.final_step
