"""Validate the scan-aware HLO cost analyzer against known-FLOP programs."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.launch import hlo_cost


def _compiled_text(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


def test_plain_matmul_flops():
    m, k, n = 64, 128, 32
    a = jax.ShapeDtypeStruct((m, k), jnp.float32)
    b = jax.ShapeDtypeStruct((k, n), jnp.float32)
    txt = _compiled_text(lambda x, y: x @ y, a, b)
    res = hlo_cost.analyze(txt)
    assert res["flops"] == 2 * m * k * n


def test_scan_matmul_flops_counts_trips():
    """The whole point: scan body flops x trip count."""
    m = 32
    trips = 7
    a = jax.ShapeDtypeStruct((m, m), jnp.float32)
    ws = jax.ShapeDtypeStruct((trips, m, m), jnp.float32)

    def fn(x, stack):
        def body(c, w):
            return c @ w, None
        out, _ = jax.lax.scan(body, x, stack)
        return out

    txt = _compiled_text(fn, a, ws)
    res = hlo_cost.analyze(txt)
    assert res["flops"] == trips * 2 * m * m * m, res["flops"]


def test_nested_scan_multiplies():
    m, outer, inner = 16, 3, 5
    a = jax.ShapeDtypeStruct((m, m), jnp.float32)
    ws = jax.ShapeDtypeStruct((outer, inner, m, m), jnp.float32)

    def fn(x, stack):
        def obody(c, group):
            def ibody(ci, w):
                return ci @ w, None
            c2, _ = jax.lax.scan(ibody, c, group)
            return c2, None
        out, _ = jax.lax.scan(obody, x, stack)
        return out

    txt = _compiled_text(fn, a, ws)
    res = hlo_cost.analyze(txt)
    assert res["flops"] == outer * inner * 2 * m ** 3, res["flops"]


def test_batched_dot_with_batch_dims():
    b, m, k, n = 4, 8, 16, 8
    x = jax.ShapeDtypeStruct((b, m, k), jnp.float32)
    y = jax.ShapeDtypeStruct((b, k, n), jnp.float32)
    txt = _compiled_text(lambda a, c: jnp.einsum("bmk,bkn->bmn", a, c), x, y)
    res = hlo_cost.analyze(txt)
    assert res["flops"] == 2 * b * m * k * n


def test_grad_roughly_triples_flops():
    m = 32
    x = jax.ShapeDtypeStruct((m, m), jnp.float32)
    w = jax.ShapeDtypeStruct((m, m), jnp.float32)
    fwd = _compiled_text(lambda a, b: jnp.sum(a @ b), x, w)
    bwd = _compiled_text(
        lambda a, b: jax.grad(lambda u, v: jnp.sum(u @ v), argnums=(0, 1))(
            a, b), x, w)
    f1 = hlo_cost.analyze(fwd)["flops"]
    f2 = hlo_cost.analyze(bwd)["flops"]
    assert f1 == 2 * m ** 3
    assert f2 >= 2 * f1          # two grad matmuls (fwd dot may be DCE'd)
