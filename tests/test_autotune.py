"""repro.autotune: enumeration, Pareto front, cache, compile-back.

The autotuner's contract: ``search(spec_space)`` returns the
non-dominated set over EVERY decomposition realizing the spec, each
point compiles to a working ``CompiledDesign`` through the same timing
gate ``generate()`` uses, and a cached re-run re-scores nothing.
"""
import json
from fractions import Fraction

import pytest

from repro import autotune, designs
from repro.autotune import (Candidate, ParetoFront, ct_decompositions,
                            enumerate_configs, pareto_front)
from repro.core import power_model as pm
from repro.core.mcim import MCIMConfig


def _spec(bits=32, tp=Fraction(1, 3), **kw):
    return designs.DesignSpec(bits, bits, tp, **kw)


# ------------------------------------------------------------- enumeration

def test_ct_decompositions_exact_cover():
    for frac in (Fraction(1, 2), Fraction(1, 3), Fraction(5, 6),
                 Fraction(11, 12)):
        decs = ct_decompositions(frac)
        assert decs, f"no decomposition for {frac}"
        for cts in decs:
            assert sum(Fraction(1, ct) for ct in cts) == frac
            assert tuple(sorted(cts)) == cts  # canonical: non-decreasing


def test_ct_decompositions_include_paper_combination():
    # Sec. V-B: 5/6 = 1/2 + 1/3
    assert (2, 3) in ct_decompositions(Fraction(5, 6))


def test_enumerate_mixed_bank_has_star_base():
    # TP=7/2 -> 3x Star + one folded 1/2 slot, like the paper's use case
    for configs in enumerate_configs(_spec(tp=Fraction(7, 2))):
        (n, star), *rest = configs
        assert star.arch == "star" and n == 3
        assert sum(Fraction(c, cfg.ct) for c, cfg in rest) == Fraction(1, 2)


def test_enumerate_deduplicates_multisets():
    configs = enumerate_configs(_spec(tp=Fraction(2, 3)))
    keys = [tuple(sorted((c, cfg.arch, cfg.ct, cfg.levels, cfg.adder)
                         for c, cfg in cs)) for cs in configs]
    assert len(keys) == len(set(keys))


def test_enumerate_respects_clock_gate():
    # 0.31 ns: fb cannot meet timing at 32b (paper Table IV), so no
    # candidate may contain an fb instance
    for configs in enumerate_configs(_spec(clock_ns=0.31)):
        assert all(cfg.arch != "fb" for _, cfg in configs)
    # relaxed: fb candidates exist
    assert any(cfg.arch == "fb"
               for configs in enumerate_configs(_spec())
               for _, cfg in configs)


def test_enumerate_strict_gate_matches_pipelineable():
    from repro.core import timing_model
    for configs in enumerate_configs(_spec(strict_timing=True,
                                           clock_ns=0.31)):
        for _, cfg in configs:
            assert timing_model.pipelineable(cfg.arch, cfg.adder)


# ------------------------------------------------------------ pareto logic

def _mk(key_tag, area, lat, fmax, e, p):
    return Candidate(spec=_spec(tp=Fraction(1, key_tag)), configs=(
        (1, MCIMConfig(arch="fb", ct=key_tag)),),
        area_um2=area, latency_cycles=lat, fmax_ghz=fmax,
        energy_per_op_pj=e, peak_power_mw=p, slack_ns=(0.0,))


def test_pareto_front_no_dominated_point():
    front = autotune.search(_spec(), use_cache=False)
    assert len(front) >= 2
    for a in front:
        for b in front:
            assert not a.dominates(b)


def test_pareto_dominated_have_provenance():
    front = autotune.search(_spec(), use_cache=False)
    assert front.dominated, "expected some dominated candidates"
    front_keys = {c.key for c in front}
    all_keys = front_keys | {c.key for c in front.dominated}
    for c in front.dominated:
        assert c.dominated_by in all_keys
        assert c.dominated_by != c.key


def test_pareto_order_invariance():
    scored = [autotune.score(_spec(), cfgs)
              for cfgs in enumerate_configs(_spec())]
    f1, d1 = pareto_front(scored)
    f2, d2 = pareto_front(list(reversed(scored)))
    assert [c.key for c in f1] == [c.key for c in f2]
    assert [(c.key, c.dominated_by) for c in d1] == \
        [(c.key, c.dominated_by) for c in d2]


def test_domination_is_strict():
    a = _mk(2, 100, 2, 1.0, 1.0, 1.0)
    b = _mk(3, 100, 2, 1.0, 1.0, 1.0)
    assert not a.dominates(b) and not b.dominates(a)  # equal: no domination
    c = _mk(4, 90, 2, 1.0, 1.0, 1.0)
    assert c.dominates(a) and not a.dominates(c)


def test_best_per_objective():
    front = autotune.search(_spec(), use_cache=False)
    for obj, (attr, maximize) in autotune.OBJECTIVES.items():
        best = front.best(obj)
        vals = [getattr(c, attr) for c in front]
        want = max(vals) if maximize else min(vals)
        assert getattr(best, attr) == want
    with pytest.raises(ValueError):
        front.best("beauty")


# ----------------------------------------------------------------- scoring

def test_scores_match_compiled_design():
    # a candidate's metrics must equal the compiled design's properties
    front = autotune.search(_spec(), use_cache=False)
    c = front.best("energy")
    d = c.compile()
    assert d.energy_per_op_pj == pytest.approx(c.energy_per_op_pj)
    assert d.peak_power_mw == pytest.approx(c.peak_power_mw)
    assert d.latency_cycles == c.latency_cycles
    assert d.area == pytest.approx(c.area_um2)


def test_candidate_compiles_bit_exact():
    front = autotune.search(_spec(bits=16), use_cache=False)
    for c in list(front)[:3]:
        d = c.compile()
        assert d.mul(0xBEEF, 0xF00D) == 0xBEEF * 0xF00D


def test_slack_nonnegative_at_scoring_period():
    front = autotune.search(_spec(), use_cache=False)
    for c in list(front) + list(front.dominated):
        assert len(c.slack_ns) == len(c.configs)
        assert all(s >= 0 for s in c.slack_ns)
        assert min(c.slack_ns) == pytest.approx(0.0, abs=1e-5)


def test_tp_half_energy_savings_sign_all_widths():
    # acceptance criterion: correct sign at every Table-VIII width
    for bits in (8, 16, 32, 64, 128):
        front = autotune.search(_spec(bits=bits, tp=Fraction(1, 2)),
                                use_cache=False)
        best = front.best("energy")
        star_e = pm.energy_per_op_pj(bits, bits, MCIMConfig(arch="star",
                                                            ct=1))
        assert best.energy_per_op_pj < star_e * 0.9, bits


# ------------------------------------------------------------------- cache

def test_cache_zero_rescores(tmp_path):
    spec = _spec()
    first = autotune.search(spec, cache_dir=str(tmp_path))
    assert not first.from_cache and first.n_scored > 0
    second = autotune.search(spec, cache_dir=str(tmp_path))
    assert second.from_cache and second.n_scored == 0
    assert [c.key for c in second] == [c.key for c in first]
    assert [c.to_dict() for c in second.front] == \
        [c.to_dict() for c in first.front]


def test_cache_key_depends_on_spec_and_model(tmp_path):
    k1 = autotune.space_key([_spec()])
    k2 = autotune.space_key([_spec(tp=Fraction(1, 2))])
    assert k1 != k2
    # order-insensitive over the space
    a, b = _spec(), _spec(tp=Fraction(1, 2))
    assert autotune.space_key([a, b]) == autotune.space_key([b, a])


def test_cache_corrupt_file_is_miss(tmp_path):
    spec = _spec()
    first = autotune.search(spec, cache_dir=str(tmp_path))
    for f in tmp_path.iterdir():
        f.write_text("{not json")
    again = autotune.search(spec, cache_dir=str(tmp_path))
    assert not again.from_cache and again.n_scored == first.n_scored


def test_front_serialization_round_trip():
    front = autotune.search(_spec(), use_cache=False)
    again = ParetoFront.from_json(front.to_json())
    assert [c.to_dict() for c in again.front] == \
        [c.to_dict() for c in front.front]
    assert [c.to_dict() for c in again.dominated] == \
        [c.to_dict() for c in front.dominated]
    assert json.loads(front.to_json())["space_key"] == front.space_key


# ---------------------------------------------------------- designs facade

def test_generate_best_compiles(tmp_path):
    d = autotune.generate_best(_spec(bits=16, tp=Fraction(1, 2)),
                               objective="energy",
                               cache_dir=str(tmp_path))
    assert d.mul(1234, 5678) == 1234 * 5678


def test_registry_name_resolves(tmp_path):
    front = autotune.search("tbl8_w16_lowpower", cache_dir=str(tmp_path))
    assert len(front) >= 1


def test_objective_energy_spec_changes_pick():
    # the registered low-power points plan with objective='energy';
    # generate() must stay the single-plan path and still work
    lp = designs.generate("tbl8_w32_lowpower")
    assert lp.spec.objective == "energy"
    assert lp.mul(0xCAFE, 0xBABE) == 0xCAFE * 0xBABE
    # default objective unchanged for existing names
    assert designs.generate("tbl8_w32_relaxed").spec.objective == "area"


def test_spec_objective_round_trips():
    s = _spec(objective="energy")
    assert designs.DesignSpec.from_json(s.to_json()) == s
    with pytest.raises(Exception):
        _spec(objective="speed")
