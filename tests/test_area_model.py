"""Area/timing model regression vs the paper's published numbers.

Calibration uses exactly ONE area (Star 16x16 = 1348 um^2) and two Star
stress anchors; everything asserted here is a model *prediction*.
Tolerances reflect the documented model error (DESIGN.md §Area-model).
"""
import pytest

from repro.core import area_model as am
from repro.core import timing_model as tm
from repro.core.mcim import MCIMConfig
from repro.core import planner


def sav(bits, cfg):
    return am.savings_vs_star(bits, bits, cfg)


# ---------------------------------------------------- absolute area checks

@pytest.mark.parametrize("bits,paper,tol", [
    (16, 1348, 0.01),     # calibration point (exact by construction)
    (32, 4349, 0.10),
    (128, 66319, 0.10),
])
def test_star_areas(bits, paper, tol):
    got = am.area_um2(bits, bits, MCIMConfig(arch="star", ct=1))
    assert abs(got - paper) / paper <= tol, (got, paper)


# --------------------------------------------------- Table VII (CT sweep)

@pytest.mark.parametrize("ct,paper_savings", [
    (2, 0.40), (3, 0.50), (4, 0.57), (5, 0.60),
    (6, 0.64), (7, 0.68), (8, 0.72)])
def test_table7_ct_sweep_within_7pp(ct, paper_savings):
    got = sav(32, MCIMConfig(arch="fb", ct=ct))
    assert abs(got - paper_savings) <= 0.07, (ct, got, paper_savings)


def test_ct_sweep_monotone():
    vals = [sav(32, MCIMConfig(arch="fb", ct=ct)) for ct in range(2, 9)]
    assert all(b > a for a, b in zip(vals, vals[1:]))


# ------------------------------------------------ Tables II/III (relaxed)

@pytest.mark.parametrize("bits,arch,ct,levels,adder,paper,tol", [
    (16, "fb", 2, 1, "1ca", 1 - 942 / 1348, 0.05),
    (16, "fb", 3, 1, "1ca", 1 - 748 / 1348, 0.05),
    (128, "ff", 2, 1, "1ca", 1 - 37042 / 66319, 0.05),
    (128, "fb", 2, 1, "1ca", 1 - 42913 / 66319, 0.13),
    (128, "fb", 3, 1, "1ca", 1 - 30217 / 66319, 0.10),
    (128, "karatsuba", 3, 1, "3ca", 1 - 27929 / 66319, 0.11),
    (128, "karatsuba", 3, 2, "3ca", 1 - 27463 / 66319, 0.10),
])
def test_relaxed_savings(bits, arch, ct, levels, adder, paper, tol):
    got = sav(bits, MCIMConfig(arch=arch, ct=ct, levels=levels, adder=adder))
    assert abs(got - paper) <= tol, (got, paper)


# -------------------------------------------------- strict timing (IV/VI)

def test_fb_misses_strict_16b_target():
    """Table IV: the feedback loop cannot meet 0.31 ns."""
    assert not tm.meets_timing("fb", 16, 0.31)
    assert tm.meets_timing("ff", 16, 0.31)      # pipelineable
    assert tm.meets_timing("star", 16, 0.31)


def test_table6_strict_savings():
    t = 0.8
    star = am.area_um2(128, 128, MCIMConfig(arch="star", ct=1)) \
        * tm.stress("star", 128, t)
    karat = am.area_um2(128, 128, MCIMConfig(arch="karatsuba", ct=3)) \
        * tm.stress("karatsuba", 128, t)
    got = 1 - karat / star
    assert abs(got - 0.63) <= 0.05, got          # paper: 63%


def test_max_freq_model_matches_table5():
    assert abs(tm.t_comb("fb", 128) - 0.80) <= 0.08
    assert abs(tm.t_comb("karatsuba", 128) - 0.54) <= 0.08


# ----------------------------------------------------------- planner

def test_planner_agrees_with_paper_table8():
    rows = [(8, False, "fb"), (16, True, "ff"), (16, False, "fb"),
            (32, True, "ff"), (32, False, "fb")]
    for bits, strict, expect in rows:
        pick = planner.best_single(bits, bits, 2, strict_timing=strict)
        assert pick.arch == expect, (bits, strict, pick)
    pick = planner.best_single(128, 128, 3, strict_timing=False)
    assert pick.arch in ("karatsuba", "fb")


def test_planner_fractional_tp_beats_star_bank():
    """Sec V-E use case: TP=3.5 via 3xStar + 1 CT-2 MCIM saves area."""
    plan = planner.plan_throughput(32, 32, 3.5)
    conv = planner.star_bank_area(32, 32, 3.5)
    assert plan.area < conv
    assert float(plan.throughput) == 3.5


def test_karatsuba_beats_schoolbook_only_at_large_widths():
    """Paper Sec. V-A: Karatsuba wins only for >=128 bits."""
    small = sav(32, MCIMConfig(arch="karatsuba", ct=3)) \
        < sav(32, MCIMConfig(arch="fb", ct=3))
    large = sav(256, MCIMConfig(arch="karatsuba", ct=3, levels=2)) \
        > sav(256, MCIMConfig(arch="fb", ct=3)) - 0.10
    assert small and large
