"""Bank execution engine: bit-exactness vs the Python-int oracle and
cycle accounting vs Plan.throughput, for every plan the planner emits
at the paper's fractional design points -- under every scheduler policy
and backend capability.  Also covers the generalized mcim_fold kernel
(FB + FF schedules for CT in {2, 3, 4, 6}, the folded Karatsuba CT=3
schedule, and awkward-batch tile padding)."""
from fractions import Fraction

import numpy as np
import pytest
import jax.numpy as jnp

from repro.core import limbs as L
from repro.core import planner, bank
from repro.kernels.mcim_fold import big_mul

RNG = np.random.default_rng(41)

TPS = (Fraction(1, 2), Fraction(7, 2), Fraction(5, 6))
BITS = (32, 64, 128)


def _operands(batch, bits):
    a = jnp.asarray(L.random_limbs(RNG, (batch,), bits))
    b = jnp.asarray(L.random_limbs(RNG, (batch,), bits))
    expect = [L.from_limbs(np.asarray(x)) * L.from_limbs(np.asarray(y))
              for x, y in zip(a, b)]
    return a, b, expect


# --------------------------------------------------------------- bit-exact

@pytest.mark.parametrize("bits", BITS)
@pytest.mark.parametrize("tp", TPS, ids=str)
def test_bank_bit_exact_core(tp, bits):
    plan = planner.plan_throughput(bits, bits, tp)
    a, b, expect = _operands(3 * max(tp.numerator, 1), bits)
    out = bank.execute(plan, a, b)
    assert L.batch_from_limbs(np.asarray(out)) == expect


@pytest.mark.parametrize("bits", BITS)
@pytest.mark.parametrize("tp", TPS, ids=str)
def test_bank_bit_exact_kernel(tp, bits):
    plan = planner.plan_throughput(bits, bits, tp)
    a, b, expect = _operands(2 * max(tp.numerator, 1), bits)
    out = bank.execute(plan, a, b, backend="kernel")
    assert L.batch_from_limbs(np.asarray(out)) == expect


@pytest.mark.parametrize("scheduler", ("greedy", "streaming"))
@pytest.mark.parametrize("tp", TPS, ids=str)
def test_bank_bit_exact_any_scheduler(tp, scheduler):
    """The dispatch policy must never change the products, only the
    cycle accounting."""
    plan = planner.plan_throughput(64, 64, tp)
    a, b, expect = _operands(3 * max(tp.numerator, 1), 64)
    out = bank.execute(plan, a, b, scheduler=scheduler)
    assert L.batch_from_limbs(np.asarray(out)) == expect
    assert np.array_equal(np.asarray(out),
                          np.asarray(bank.execute(plan, a, b)))


def test_bank_kernel_backend_karatsuba_arch():
    """A karatsuba-bearing plan (128b, CT=3) runs entirely through the
    Pallas path: the registry has no core fallback to hide in."""
    plan = planner.plan_throughput(128, 128, Fraction(1, 3))
    assert any(cfg.arch == "karatsuba" for _, cfg in plan.configs)
    a, b, expect = _operands(6, 128)
    out = bank.execute(plan, a, b, backend="kernel")
    assert L.batch_from_limbs(np.asarray(out)) == expect


def test_bank_single_op_and_width_check():
    plan = planner.plan_throughput(32, 32, Fraction(1, 2))
    a, b, expect = _operands(1, 32)
    out = bank.execute(plan, a[0], b[0])            # 1-D convenience
    assert L.from_limbs(np.asarray(out)) == expect[0]
    bk = bank.Bank(plan, 32, 32)
    with pytest.raises(ValueError):
        bk.execute(jnp.zeros((4, 8), jnp.uint32), jnp.zeros((4, 2),
                                                            jnp.uint32))
    with pytest.raises(ValueError):       # gather would clamp silently
        bk.execute(jnp.zeros((8, 2), jnp.uint32), jnp.zeros((4, 2),
                                                            jnp.uint32))


# --------------------------------------------------------- cycle accounting

@pytest.mark.parametrize("bits", (32, 128))
@pytest.mark.parametrize("tp", TPS, ids=str)
def test_bank_throughput_matches_plan(tp, bits):
    """Over whole hyperperiods the round-robin schedule must sustain
    exactly the plan's claimed ops/cycle."""
    plan = planner.plan_throughput(bits, bits, tp)
    bk = bank.Bank(plan, bits, bits)
    batch = 4 * tp.numerator
    rep = bk.report(batch)
    assert rep.measured_throughput == plan.throughput, rep
    assert rep.utilization == 1.0
    # per-instance busy cycles never exceed the makespan
    assert all(ir.busy_cycles <= rep.cycles for ir in rep.instances)
    # every op is assigned exactly once
    assert sum(ir.n_ops for ir in rep.instances) == batch


def test_bank_report_attached_after_execute():
    plan = planner.plan_throughput(32, 32, Fraction(7, 2))
    bk = bank.Bank(plan, 32, 32)
    a, b, _ = _operands(14, 32)
    bk.execute(a, b)
    assert bk.last_report is not None
    assert bk.last_report.batch == 14
    assert bk.last_report.measured_throughput <= plan.throughput


def test_round_robin_schedule_is_work_conserving():
    assign, cycles = bank.round_robin_schedule((1, 1, 1, 2), 56)
    # 3 stars take 16 each, the CT=2 unit 8; last retirement at cycle 16
    assert [len(x) for x in assign] == [16, 16, 16, 8]
    assert cycles == 16


def test_greedy_beats_round_robin_on_heterogeneous_tail():
    """cts=(1,3), 2 ops: round-robin parks op 1 on the slow unit
    (makespan 3); greedy keeps both on the fast unit (makespan 2)."""
    _, rr = bank.round_robin_schedule((1, 3), 2)
    _, greedy = bank.greedy_schedule((1, 3), 2)
    assert (rr, greedy) == (3, 2)


def test_streaming_scheduler_respects_arrivals_in_bank():
    """A Bank with an arrival-rate streaming policy still multiplies
    bit-exactly, and its makespan stretches to cover the arrival tail."""
    plan = planner.plan_throughput(32, 32, Fraction(7, 2))
    batch = 14
    sched = bank.StreamingScheduler(arrival_rate=2)   # 2 ops arrive/cycle
    bk = bank.Bank(plan, 32, 32, scheduler=sched)
    a, b, expect = _operands(batch, 32)
    out = bk.execute(a, b)
    assert L.batch_from_limbs(np.asarray(out)) == expect
    rep = bk.last_report
    assert rep.scheduler == "streaming"
    eager = bank.Bank(plan, 32, 32).report(batch)
    assert rep.cycles >= eager.cycles
    assert rep.cycles >= bank.uniform_arrivals(batch, 2)[-1] + 1


# ------------------------------------------------------- generalized kernel

@pytest.mark.parametrize("ct", (2, 3, 4, 6))
@pytest.mark.parametrize("schedule", ("fb", "ff"))
def test_mcim_fold_kernel_schedules(schedule, ct):
    a, b, expect = _operands(16, 64)
    out = big_mul(a, b, ct=ct, schedule=schedule)
    assert L.batch_from_limbs(np.asarray(out)) == expect
    ref = big_mul(a, b, ct=ct, schedule=schedule, use_kernel=False)
    assert np.array_equal(np.asarray(out), np.asarray(ref))


def test_mcim_fold_kernel_ct_exceeds_limbs():
    """CT larger than the B-limb count: trailing cycles are idle, the
    product must still be exact (32 bits = 2 limbs, CT=6)."""
    a, b, expect = _operands(8, 32)
    out = big_mul(a, b, ct=6, schedule="fb")
    assert L.batch_from_limbs(np.asarray(out)) == expect


def test_ff_kernel_rejects_single_cycle():
    a, b, _ = _operands(4, 32)
    with pytest.raises(ValueError):
        big_mul(a, b, ct=1, schedule="ff")


# --------------------------------------------------- folded Karatsuba kernel

@pytest.mark.parametrize("bits", (16, 32, 48, 64, 128))
def test_kara_fold_kernel_bit_exact(bits):
    a, b, expect = _operands(16, bits)
    out = big_mul(a, b, ct=3, schedule="karatsuba")
    assert L.batch_from_limbs(np.asarray(out)) == expect
    ref = big_mul(a, b, ct=3, schedule="karatsuba", use_kernel=False)
    assert np.array_equal(np.asarray(out), np.asarray(ref))


def test_kara_fold_kernel_rectangular_operands():
    """Unequal widths pad to a common even split inside the kernel --
    the old equal-width-only restriction (and its silent core fallback)
    is gone."""
    a = jnp.asarray(L.random_limbs(RNG, (8,), 64))
    b = jnp.asarray(L.random_limbs(RNG, (8,), 32))
    expect = [L.from_limbs(np.asarray(x)) * L.from_limbs(np.asarray(y))
              for x, y in zip(a, b)]
    out = big_mul(a, b, ct=3, schedule="karatsuba")
    assert L.batch_from_limbs(np.asarray(out)) == expect


def test_kara_fold_kernel_requires_ct3():
    a, b, _ = _operands(4, 32)
    with pytest.raises(ValueError):
        big_mul(a, b, ct=2, schedule="karatsuba")


# ----------------------------------------------------- batch-tile selection

def test_batch_tile_prefers_exact_divisors():
    from repro.kernels.mcim_fold import batch_tile
    assert batch_tile(512) == (512, 0)
    assert batch_tile(48) == (16, 0)
    assert batch_tile(3) == (3, 0)           # tiny batch: one short tile
    assert batch_tile(9) == (9, 0)           # padding 9 -> 16 would waste 78%


def test_batch_tile_pads_awkward_batches():
    """A large prime batch must not degenerate into 1-row tiles (the old
    VMEM-estimate blowup): pad to a near tile multiple instead."""
    from repro.kernels.mcim_fold import batch_tile
    tile, pad = batch_tile(509)
    assert tile >= 64 and (509 + pad) % tile == 0
    assert pad * 8 <= 512                      # bounded waste
    tile, pad = batch_tile(1030)               # 2*5*103: divisor 2 only
    assert tile >= 64 and (1030 + pad) % tile == 0


@pytest.mark.parametrize("batch", (7, 13, 509))
def test_big_mul_awkward_batches_bit_exact(batch):
    a, b, expect = _operands(batch, 32)
    out = big_mul(a, b, ct=2, schedule="fb")
    assert out.shape == (batch, 4)
    assert L.batch_from_limbs(np.asarray(out)) == expect
