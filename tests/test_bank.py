"""Bank execution engine: bit-exactness vs the Python-int oracle and
cycle accounting vs Plan.throughput, for every plan the planner emits
at the paper's fractional design points.  Also covers the generalized
mcim_fold kernel (FB + FF schedules, CT in {2, 3, 4, 6})."""
from fractions import Fraction

import numpy as np
import pytest
import jax.numpy as jnp

from repro.core import limbs as L
from repro.core import planner, bank
from repro.kernels.mcim_fold import big_mul

RNG = np.random.default_rng(41)

TPS = (Fraction(1, 2), Fraction(7, 2), Fraction(5, 6))
BITS = (32, 64, 128)


def _operands(batch, bits):
    a = jnp.asarray(L.random_limbs(RNG, (batch,), bits))
    b = jnp.asarray(L.random_limbs(RNG, (batch,), bits))
    expect = [L.from_limbs(np.asarray(x)) * L.from_limbs(np.asarray(y))
              for x, y in zip(a, b)]
    return a, b, expect


# --------------------------------------------------------------- bit-exact

@pytest.mark.parametrize("bits", BITS)
@pytest.mark.parametrize("tp", TPS, ids=str)
def test_bank_bit_exact_core(tp, bits):
    plan = planner.plan_throughput(bits, bits, tp)
    a, b, expect = _operands(3 * max(tp.numerator, 1), bits)
    out = bank.execute(plan, a, b)
    assert L.batch_from_limbs(np.asarray(out)) == expect


@pytest.mark.parametrize("bits", BITS)
@pytest.mark.parametrize("tp", TPS, ids=str)
def test_bank_bit_exact_kernel(tp, bits):
    plan = planner.plan_throughput(bits, bits, tp)
    a, b, expect = _operands(2 * max(tp.numerator, 1), bits)
    out = bank.execute(plan, a, b, backend="kernel")
    assert L.batch_from_limbs(np.asarray(out)) == expect


def test_bank_single_op_and_width_check():
    plan = planner.plan_throughput(32, 32, Fraction(1, 2))
    a, b, expect = _operands(1, 32)
    out = bank.execute(plan, a[0], b[0])            # 1-D convenience
    assert L.from_limbs(np.asarray(out)) == expect[0]
    bk = bank.Bank(plan, 32, 32)
    with pytest.raises(ValueError):
        bk.execute(jnp.zeros((4, 8), jnp.uint32), jnp.zeros((4, 2),
                                                            jnp.uint32))
    with pytest.raises(ValueError):       # gather would clamp silently
        bk.execute(jnp.zeros((8, 2), jnp.uint32), jnp.zeros((4, 2),
                                                            jnp.uint32))


# --------------------------------------------------------- cycle accounting

@pytest.mark.parametrize("bits", (32, 128))
@pytest.mark.parametrize("tp", TPS, ids=str)
def test_bank_throughput_matches_plan(tp, bits):
    """Over whole hyperperiods the round-robin schedule must sustain
    exactly the plan's claimed ops/cycle."""
    plan = planner.plan_throughput(bits, bits, tp)
    bk = bank.Bank(plan, bits, bits)
    batch = 4 * tp.numerator
    rep = bk.report(batch)
    assert rep.measured_throughput == plan.throughput, rep
    assert rep.utilization == 1.0
    # per-instance busy cycles never exceed the makespan
    assert all(ir.busy_cycles <= rep.cycles for ir in rep.instances)
    # every op is assigned exactly once
    assert sum(ir.n_ops for ir in rep.instances) == batch


def test_bank_report_attached_after_execute():
    plan = planner.plan_throughput(32, 32, Fraction(7, 2))
    bk = bank.Bank(plan, 32, 32)
    a, b, _ = _operands(14, 32)
    bk.execute(a, b)
    assert bk.last_report is not None
    assert bk.last_report.batch == 14
    assert bk.last_report.measured_throughput <= plan.throughput


def test_round_robin_schedule_is_work_conserving():
    assign, cycles = bank.round_robin_schedule((1, 1, 1, 2), 56)
    # 3 stars take 16 each, the CT=2 unit 8; last retirement at cycle 16
    assert [len(x) for x in assign] == [16, 16, 16, 8]
    assert cycles == 16


# ------------------------------------------------------- generalized kernel

@pytest.mark.parametrize("ct", (2, 3, 4, 6))
@pytest.mark.parametrize("schedule", ("fb", "ff"))
def test_mcim_fold_kernel_schedules(schedule, ct):
    a, b, expect = _operands(16, 64)
    out = big_mul(a, b, ct=ct, schedule=schedule)
    assert L.batch_from_limbs(np.asarray(out)) == expect
    ref = big_mul(a, b, ct=ct, schedule=schedule, use_kernel=False)
    assert np.array_equal(np.asarray(out), np.asarray(ref))


def test_mcim_fold_kernel_ct_exceeds_limbs():
    """CT larger than the B-limb count: trailing cycles are idle, the
    product must still be exact (32 bits = 2 limbs, CT=6)."""
    a, b, expect = _operands(8, 32)
    out = big_mul(a, b, ct=6, schedule="fb")
    assert L.batch_from_limbs(np.asarray(out)) == expect


def test_ff_kernel_rejects_single_cycle():
    a, b, _ = _operands(4, 32)
    with pytest.raises(ValueError):
        big_mul(a, b, ct=1, schedule="ff")
