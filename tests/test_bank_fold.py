"""Fused bank megakernel: bit-exactness vs the Python-int oracle across
every registry design point, the single-launch jaxpr contract, ragged
and signed batches, the fused verifier rules (including seeded
corruptions and the generate()-time refusal), and the centralized
interpret-mode runtime flag."""
import dataclasses
from fractions import Fraction

import numpy as np
import pytest
import jax.numpy as jnp

from repro import designs, verify
from repro.core import limbs as L
from repro.core import planner
from repro.core.bank import Bank
from repro.core.bank.backends import cached_mul
from repro.core.mcim import MCIMConfig
from repro.designs import registry
from repro.kernels import runtime
from repro.kernels.bank_fold import (fused_ct, fused_windows,
                                     super_geometry)
from repro.launch.roofline import count_pallas_launches

RNG = np.random.default_rng(47)


def _operands(batch, bits):
    a = jnp.asarray(L.random_limbs(RNG, (batch,), bits))
    b = jnp.asarray(L.random_limbs(RNG, (batch,), bits))
    expect = [L.from_limbs(np.asarray(x)) * L.from_limbs(np.asarray(y))
              for x, y in zip(a, b)]
    return a, b, expect


# ----------------------------------------------- registry-wide bit-exact

@pytest.mark.parametrize("name", registry.names())
def test_fused_bit_exact_every_registry_point(name):
    """Every named design -- Table VIII strict/relaxed, the TP=3.5 and
    TP=5/6 use-case banks, the _lowpower points -- through the fused
    megakernel, vs the bigint oracle."""
    spec = dataclasses.replace(registry.get(name), backend="fused")
    design = designs.generate(spec)
    assert design.bank.backend == "fused"
    batch = 2 * max(spec.throughput.numerator, 1)
    a, b, expect = _operands(batch, spec.bits_a)
    out = design.mul(a, b)
    assert L.batch_from_limbs(np.asarray(out)) == expect


def test_fused_matches_per_instance_paths():
    """Same plan, same operands: fused == kernel == core, bitwise."""
    plan = planner.plan_throughput(32, 32, Fraction(7, 2))
    a, b, expect = _operands(11, 32)
    outs = {}
    for backend in ("core", "kernel", "fused"):
        bk = Bank(plan, 32, 32, backend=backend)
        outs[backend] = np.asarray(bk.execute(a, b))
        assert L.batch_from_limbs(outs[backend]) == expect
    assert np.array_equal(outs["fused"], outs["kernel"])
    assert np.array_equal(outs["fused"], outs["core"])


# --------------------------------------------------------- ragged batches

@pytest.mark.parametrize("batch", (1, 7, 13, 29))
def test_fused_ragged_prime_batches(batch):
    """Prime/ragged batch sizes force padded gather rows; the padding
    must never leak into the scattered products."""
    plan = planner.plan_throughput(32, 32, Fraction(7, 2))
    bk = Bank(plan, 32, 32, backend="fused")
    a, b, expect = _operands(batch, 32)
    out = bk.execute(a, b)
    assert L.batch_from_limbs(np.asarray(out)) == expect


# ----------------------------------------------------------------- signed

def test_fused_signed_bit_exact():
    """Signed designs run the fused unsigned kernel plus the shared
    two's-complement correction pass -- still bit-exact, still one
    launch."""
    spec = designs.DesignSpec(32, 32, Fraction(7, 2), signed=True,
                              backend="fused")
    design = designs.generate(spec)
    vals = [int(v) for v in RNG.integers(-2**31, 2**31, 9)]
    for x, y in zip(vals, reversed(vals)):
        assert design.mul(x, y) == x * y
    assert design.bank.launch_count(9) == 1


def test_kernel_backend_still_refuses_signed():
    spec = designs.DesignSpec(32, 32, Fraction(1, 2), signed=True,
                              backend="kernel")
    with pytest.raises(designs.DesignError):
        designs.generate(spec)


# ------------------------------------------------------------ launch count

def test_fused_single_launch_per_round():
    """The tentpole contract: a fused bank round traces to EXACTLY one
    pallas_call, vs one per busy instance on the per-instance path."""
    plan = planner.plan_throughput(32, 32, Fraction(7, 2))
    batch = 14
    fused = Bank(plan, 32, 32, backend="fused")
    per = Bank(plan, 32, 32, backend="kernel")
    assert fused.launch_count(batch) == 1
    assert per.launch_count(batch) == len(per.instances) == 4
    core = Bank(plan, 32, 32, backend="core")
    assert core.launch_count(batch) == 0


def test_count_pallas_launches_sees_nested_jits():
    import jax
    from repro.kernels.mcim_fold import big_mul
    a = jnp.asarray(L.random_limbs(RNG, (8,), 32))
    b = jnp.asarray(L.random_limbs(RNG, (8,), 32))

    def two_rounds(x, y):
        return big_mul(x, y, ct=2) + jax.jit(lambda u, v: big_mul(
            u, v, ct=1, schedule="fb"))(x, y)

    assert count_pallas_launches(two_rounds, a, b) == 2


# -------------------------------------------------------- fused geometry

def test_fused_ct_mapping():
    assert fused_ct(MCIMConfig(arch="star", ct=1)) == 1
    assert fused_ct(MCIMConfig(arch="fb", ct=4)) == 4
    assert fused_ct(MCIMConfig(arch="ff", ct=6)) == 6
    assert fused_ct(MCIMConfig(arch="karatsuba", ct=3)) == 3


def test_super_geometry_idle_padding():
    cfgs = (MCIMConfig(arch="star", ct=1), MCIMConfig(arch="fb", ct=4))
    sg = super_geometry(cfgs, 8, 8)
    assert sg.max_steps == 4
    star_wins = sg.windows(0)
    assert star_wins[0] == (0, 8)
    assert star_wins[1:] == ((0, 0),) * 3        # idle steps masked
    tbl = sg.table()
    assert tbl.shape == (2, 4, 2)
    assert tuple(tbl[0, 0]) == (0, 8)
    assert tuple(tbl[0, 3]) == (0, 0)


def test_super_geometry_refuses_empty_bank():
    with pytest.raises(ValueError):
        super_geometry((), 4, 4)


# ---------------------------------------------------------- verifier rules

def test_fused_verifier_proves_registry():
    """verify_plan sweeps the fused substrate + super-geometry contracts
    for every registry plan with zero violations."""
    for name in registry.names():
        spec = registry.get(name)
        design = designs.generate(name)
        violations = verify.verify_plan(spec.bits_a, spec.bits_b,
                                        design.plan.configs,
                                        design.plan.throughput)
        assert not violations, (name, violations)


def test_fused_seeded_window_corruption_caught():
    cfg = MCIMConfig(arch="fb", ct=2)
    good = verify.check_fused_schedule(32, 32, cfg)
    assert not good
    # drop a limb from the second window: missing-product
    bad = verify.check_fused_schedule(
        32, 32, cfg, windows=((0, 1), (1, 1)))
    assert any(v.rule == "missing-product" for v in bad)
    # overlap the windows: double-cover
    bad = verify.check_fused_schedule(
        32, 32, cfg, windows=((0, 2), (1, 2)))
    assert any(v.rule == "double-cover" for v in bad)
    # a window past the last real limb is clipped to empty, so the
    # damage surfaces as the limbs it no longer covers
    bad = verify.check_fused_schedule(
        32, 32, cfg, windows=((0, 1), (2, 3)))
    assert any(v.rule == "missing-product" for v in bad)


def test_fused_seeded_scratch_corruption_caught():
    cfg = MCIMConfig(arch="ff", ct=4)
    assert not verify.check_fused_widths(64, 64, cfg)
    bad = verify.check_fused_widths(64, 64, cfg, scratch_width=7)
    assert any(v.rule == "scratch-too-narrow" for v in bad)
    bad = verify.check_fused_widths(64, 64, cfg, out_width=6)
    assert any(v.rule == "out-width" for v in bad)


def test_generate_refuses_unprovable_fused_plan(monkeypatch):
    """The plan-time gate: when the fused contracts report a violation,
    generate() raises before any bank is built."""
    boom = verify.Violation("contracts", "fused-idle-mask", "seeded",
                            "test-injected violation")
    monkeypatch.setattr(verify.contracts, "check_fused_plan",
                        lambda *a, **k: [boom])
    spec = designs.DesignSpec(32, 32, Fraction(7, 2), backend="fused")
    with pytest.raises(verify.VerificationError):
        designs.generate(spec)


def test_fused_interval_walk_matches_windows():
    """The fused interval substrate exists and its required width is the
    full product width (the shared accumulator contract)."""
    rep = verify.analyze(128, 128, MCIMConfig(arch="fb", ct=8),
                         substrate="fused")
    assert rep.ok
    assert rep.required_width == 16
    wins = fused_windows(MCIMConfig(arch="fb", ct=8), 8, 8)
    assert wins[-1][1] == 8                     # clipped to real limbs


# ----------------------------------------------------- engine integration

def test_fused_working_set_is_max_not_sum():
    """Fused instances time-share one datapath: the bank working set is
    the largest instance footprint, not the per-instance sum."""
    plan = planner.plan_throughput(32, 32, Fraction(7, 2))
    fused = Bank(plan, 32, 32, backend="fused")
    per = Bank(plan, 32, 32, backend="kernel")
    rf = fused.report(14)
    rp = per.report(14)
    assert rf.working_set_bytes < rp.working_set_bytes


def test_fused_refuses_mixed_signedness():
    star = MCIMConfig(arch="star", ct=1)
    fb_signed = MCIMConfig(arch="fb", ct=2, signed=True)
    plan = planner.Plan(configs=((1, star), (1, fb_signed)),
                        throughput=Fraction(3, 2), area=1.0)
    with pytest.raises(ValueError, match="signedness"):
        Bank(plan, 32, 32, backend="fused")


def test_dispatch_mul_cached_across_banks():
    """The satellite: two Banks over the same plan share the SAME
    multiplier callables (jax's jit cache keys on function identity, so
    identity sharing is what stops re-tracing)."""
    plan = planner.plan_throughput(32, 32, Fraction(7, 2))
    b1 = Bank(plan, 32, 32, backend="kernel")
    b2 = Bank(plan, 32, 32, backend="kernel")
    assert all(m1 is m2 for m1, m2 in zip(b1._muls, b2._muls))
    cfg = plan.configs[0][1]
    assert cached_mul(cfg.arch, "kernel", cfg, 2, 2) is \
        cached_mul(cfg.arch, "kernel", cfg, 2, 2)


def test_auto_backend_resolves_core_on_cpu():
    """The CPU container must not silently pay interpret-mode kernels:
    auto stays on the pure-jnp core path off-TPU."""
    design = designs.generate(designs.DesignSpec(32, 32, Fraction(1, 2)))
    assert design.bank.backend == "core"


# ------------------------------------------------------------ runtime flag

def test_runtime_flag_env_override(monkeypatch):
    monkeypatch.setenv("REPRO_INTERPRET", "0")
    runtime.reset()
    assert runtime.interpret_mode() is False
    monkeypatch.setenv("REPRO_INTERPRET", "1")
    runtime.reset()
    assert runtime.interpret_mode() is True
    # legacy name still honored when the new one is unset
    monkeypatch.delenv("REPRO_INTERPRET")
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "off")
    runtime.reset()
    assert runtime.interpret_mode() is False
    monkeypatch.delenv("REPRO_PALLAS_INTERPRET")
    runtime.reset()
    # auto: interpret on the CPU container
    assert runtime.interpret_mode() is True
    runtime.reset()


def test_no_per_module_interpret_constants():
    """The dedup satellite: no kernel ops module owns its own INTERPRET
    flag anymore; runtime.interpret_mode is the single policy."""
    import pathlib
    import repro.kernels as K
    root = pathlib.Path(K.__file__).parent
    for ops in root.glob("*/ops.py"):
        text = ops.read_text()
        assert "INTERPRET =" not in text, f"{ops} still owns a flag"
        assert "runtime.interpret_mode" in text or "interpret" not in \
            text.lower(), f"{ops} bypasses repro.kernels.runtime"
