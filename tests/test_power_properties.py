"""Property tests (hypothesis) for the power model + Pareto front.

Three invariants the ISSUE pins:

  1. energy strictly decreases as CT grows at fixed width (folding can
     only reduce switching -- glitch depth shrinks, adders shorten,
     leakage tracks the smaller area);
  2. peak power never exceeds Star for a folded design (Star commits
     all its switching in one cycle; folding spreads it);
  3. the Pareto front contains no dominated point and is invariant to
     the enumeration order (it is a set property of the pool).
"""
from fractions import Fraction

import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro import autotune, designs
from repro.autotune import pareto_front
from repro.core import power_model as pm
from repro.core.mcim import MCIMConfig

STAR = MCIMConfig(arch="star", ct=1)

bits_st = st.sampled_from([4, 8, 12, 16, 24, 32, 48, 64, 96, 128])
arch_st = st.sampled_from(["fb", "ff"])


# ---------------------------------------------------- 1. CT monotonicity

@given(bits=bits_st, arch=arch_st)
@settings(max_examples=40, deadline=None)
def test_energy_strictly_decreases_with_ct(bits, arch):
    cts = list(range(2, min(12, bits) + 1))
    es = [pm.mcim_energy(bits, bits, MCIMConfig(arch=arch, ct=ct)).total
          for ct in cts]
    assert all(a > b for a, b in zip(es, es[1:])), \
        f"{arch}@{bits}b energy not strictly decreasing over ct: {es}"


# ------------------------------------------------- 2. peak power <= Star

@given(bits=bits_st, arch=arch_st,
       ct=st.sampled_from([2, 3, 4, 6, 8, 12]))
@settings(max_examples=60, deadline=None)
def test_folded_peak_below_star(bits, arch, ct):
    cfg = MCIMConfig(arch=arch, ct=ct)
    assert pm.peak_switched(bits, bits, cfg) < \
        pm.peak_switched(bits, bits, STAR)


@given(bits=st.sampled_from([16, 24, 32, 48, 64, 96, 128, 256]),
       levels=st.sampled_from([1, 2, 3]),
       adder=st.sampled_from(["1ca", "3ca"]))
@settings(max_examples=40, deadline=None)
def test_karatsuba_peak_below_star(bits, levels, adder):
    # karatsuba's recursion overhead dominates below ~16b (the planner
    # never picks it there); from 16b up the invariant must hold
    cfg = MCIMConfig(arch="karatsuba", ct=3, levels=levels, adder=adder)
    assert pm.peak_switched(bits, bits, cfg) < \
        pm.peak_switched(bits, bits, STAR)


# ------------------------------------- 3. Pareto front set-property-ness

def _pool():
    spec = designs.DesignSpec(32, 32, Fraction(1, 3))
    return [autotune.score(spec, cfgs)
            for cfgs in autotune.enumerate_configs(spec)]


_POOL = _pool()


@given(perm=st.permutations(range(len(_POOL))))
@settings(max_examples=25, deadline=None)
def test_front_invariant_to_enumeration_order(perm):
    front, dominated = pareto_front([_POOL[i] for i in perm])
    base_front, base_dom = pareto_front(_POOL)
    assert [c.key for c in front] == [c.key for c in base_front]
    assert [(c.key, c.dominated_by) for c in dominated] == \
        [(c.key, c.dominated_by) for c in base_dom]


@given(perm=st.permutations(range(len(_POOL))))
@settings(max_examples=10, deadline=None)
def test_front_has_no_dominated_point(perm):
    front, dominated = pareto_front([_POOL[i] for i in perm])
    for a in front:
        for b in front:
            assert not a.dominates(b)
    # and every dominated candidate really is dominated by its dominator
    by_key = {c.key: c for c in list(front) + list(dominated)}
    for c in dominated:
        assert by_key[c.dominated_by].dominates(c)
