"""Property tests for the bank scheduler layer (pure Python, no jax).

Every policy must honour the same static contract -- a complete,
duplicate-free assignment of all ops -- and greedy's
earliest-completion-time dispatch must never lose to round-robin on
makespan (it is provably optimal for identical ops: the k-th op on an
instance of cycle time ct can finish no earlier than k*ct, and greedy
consumes exactly the n smallest such completion slots)."""
import pytest

hyp = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.bank import schedule as S

CTS = st.lists(st.integers(min_value=1, max_value=8),
               min_size=1, max_size=6).map(tuple)
N_OPS = st.integers(min_value=0, max_value=80)


def _check_contract(assign, makespan, cts, n_ops):
    assert len(assign) == len(cts)
    flat = [op for ops in assign for op in ops]
    assert sorted(flat) == list(range(n_ops)), "incomplete or duplicated"
    assert makespan >= 0
    if n_ops:
        # no instance can beat its own issue interval over its ops
        assert makespan >= max(
            (len(ops) - 1) * ct + ct
            for ops, ct in zip(assign, cts) if ops)
    else:
        assert makespan == 0


@settings(max_examples=200, deadline=None)
@given(cts=CTS, n_ops=N_OPS)
def test_all_schedulers_complete_and_duplicate_free(cts, n_ops):
    for name in ("round_robin", "greedy", "streaming"):
        assign, makespan = S.get_scheduler(name).schedule(cts, n_ops)
        _check_contract(assign, makespan, cts, n_ops)


@settings(max_examples=200, deadline=None)
@given(cts=CTS, n_ops=N_OPS)
def test_greedy_makespan_never_worse_than_round_robin(cts, n_ops):
    _, rr = S.round_robin_schedule(cts, n_ops)
    _, greedy = S.greedy_schedule(cts, n_ops)
    assert greedy <= rr, (cts, n_ops, greedy, rr)


@settings(max_examples=100, deadline=None)
@given(cts=CTS, n_ops=N_OPS)
def test_streaming_with_zero_arrivals_is_round_robin(cts, n_ops):
    trace = (0,) * n_ops
    assert S.streaming_schedule(cts, n_ops, trace) == \
        S.round_robin_schedule(cts, n_ops)


@settings(max_examples=100, deadline=None)
@given(cts=CTS, n_ops=st.integers(min_value=1, max_value=60),
       rate=st.integers(min_value=1, max_value=8))
def test_streaming_respects_arrival_trace(cts, n_ops, rate):
    """No op may issue before it arrives: with ops trickling in at
    ``rate``/cycle the makespan is at least the last arrival + its CT."""
    trace = S.uniform_arrivals(n_ops, rate)
    assign, makespan = S.streaming_schedule(cts, n_ops, trace)
    _check_contract(assign, makespan, cts, n_ops)
    assert makespan >= trace[-1] + min(cts)


def test_streaming_rejects_bad_traces():
    with pytest.raises(ValueError):
        S.streaming_schedule((1, 2), 3, (0, 1))        # wrong length
    with pytest.raises(ValueError):
        S.streaming_schedule((1, 2), 3, (2, 1, 0))     # decreasing


def test_registry_round_trip():
    assert S.get_scheduler("greedy") is S.SCHEDULERS["greedy"]
    custom = S.StreamingScheduler(arrival_rate=2)
    assert S.get_scheduler(custom) is custom
    with pytest.raises(ValueError):
        S.get_scheduler("nope")
    with pytest.raises(TypeError):
        S.get_scheduler(42)
