"""Substrate tests: optimizer, RNG, exact accumulation, data, checkpoint."""
import os

import numpy as np
import pytest
import jax
import jax.numpy as jnp
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.optim import (AdamWConfig, init_state, apply_updates,
                         schedule_lr, global_norm)
from repro.optim.compress import compress_grads, decompress_grads, init_error
from repro.rng import philox4x32, random_uniform, random_tokens
from repro.exact import f32_to_fixed, fixed_to_f32, exact_sum, exact_tree_sum
from repro.data import DataConfig, SyntheticLM, BinTokenFile, make_source
from repro.checkpoint import CheckpointManager

RNG = np.random.default_rng(3)


# ------------------------------------------------------------------ optim

def _toy_params():
    return {"w": jnp.asarray(RNG.standard_normal((4, 8)), jnp.float32),
            "norm": jnp.zeros((8,), jnp.float32)}


def test_adamw_reduces_quadratic_loss():
    params = _toy_params()
    cfg = AdamWConfig(lr=0.05, warmup_steps=0, total_steps=200,
                      weight_decay=0.0)
    state = init_state(params)
    target = jax.tree_util.tree_map(lambda p: jnp.ones_like(p), params)

    def loss(p):
        return sum(jnp.sum((a - b) ** 2) for a, b in
                   zip(jax.tree_util.tree_leaves(p),
                       jax.tree_util.tree_leaves(target)))

    l0 = float(loss(params))
    for _ in range(100):
        grads = jax.grad(loss)(params)
        params, state, _ = apply_updates(params, grads, state, cfg)
    assert float(loss(params)) < 0.05 * l0


def test_adamw_weight_decay_mask():
    """Norm-like params must not be decayed."""
    params = _toy_params()
    cfg = AdamWConfig(lr=0.1, warmup_steps=0, weight_decay=1.0,
                      clip_norm=None)
    state = init_state(params)
    zero_grads = jax.tree_util.tree_map(jnp.zeros_like, params)
    new, _, _ = apply_updates(params, zero_grads, state, cfg)
    # w decays toward zero; norm untouched
    assert float(jnp.abs(new["w"]).sum()) < float(jnp.abs(params["w"]).sum())
    np.testing.assert_array_equal(np.asarray(new["norm"]),
                                  np.asarray(params["norm"]))


def test_grad_clip_bounds_update():
    params = _toy_params()
    cfg = AdamWConfig(lr=1e-3, clip_norm=1.0, warmup_steps=0)
    state = init_state(params)
    huge = jax.tree_util.tree_map(lambda p: 1e6 * jnp.ones_like(p), params)
    _, _, stats = apply_updates(params, huge, state, cfg)
    assert float(stats["grad_norm"]) > 1e5      # reported pre-clip


def test_schedule_shapes():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=110,
                      min_lr_ratio=0.1)
    lrs = [float(schedule_lr(cfg, jnp.int32(s))) for s in (0, 5, 10, 60, 110)]
    assert lrs[0] == 0.0
    assert abs(lrs[2] - 1.0) < 1e-6
    assert lrs[3] < lrs[2]
    assert abs(lrs[4] - 0.1) < 1e-2


# --------------------------------------------------------------- compress

def test_compress_roundtrip_error_feedback():
    grads = {"a": jnp.asarray(RNG.standard_normal((16, 32)), jnp.float32)}
    err = init_error(grads)
    qs, ss, err2 = compress_grads(grads, err)
    assert qs["a"].dtype == jnp.int8
    back = decompress_grads(qs, ss, grads)
    rel = (np.linalg.norm(np.asarray(back["a"] - grads["a"]))
           / np.linalg.norm(np.asarray(grads["a"])))
    assert rel < 0.02
    # error feedback holds exactly the residual
    np.testing.assert_allclose(np.asarray(err2["a"]),
                               np.asarray(grads["a"] - back["a"]),
                               rtol=0, atol=1e-6)


def test_error_feedback_debiases_over_steps():
    """Mean of dequantized grads converges to the true constant grad."""
    g = jnp.full((8, 64), 0.003, jnp.float32) \
        + jnp.asarray(RNG.standard_normal((8, 64)) * 1e-5, jnp.float32)
    grads = {"g": g}
    err = init_error(grads)
    acc = np.zeros((8, 64), np.float32)
    n = 20
    for _ in range(n):
        qs, ss, err = compress_grads(grads, err)
        acc += np.asarray(decompress_grads(qs, ss, grads)["g"])
    np.testing.assert_allclose(acc / n, np.asarray(g), rtol=0.02, atol=2e-4)


# -------------------------------------------------------------------- rng

def test_philox_known_vector():
    """Philox4x32-10 reference vector (Random123): counter=0, key=0."""
    ctr = jnp.zeros((1, 4), jnp.uint32)
    key = jnp.zeros((1, 2), jnp.uint32)
    out = np.asarray(philox4x32(ctr, key))[0]
    expect = np.array([0x6627E8D5, 0xE169C58D, 0xBC57AC4C, 0x9B00DBD8],
                      dtype=np.uint32)
    np.testing.assert_array_equal(out, expect)


def test_philox_determinism_and_uniformity():
    offs = jnp.arange(0, 4096, dtype=jnp.uint32)
    u1 = np.asarray(random_uniform(42, 7, offs))
    u2 = np.asarray(random_uniform(42, 7, offs))
    np.testing.assert_array_equal(u1, u2)
    assert 0.45 < u1.mean() < 0.55
    assert u1.min() >= 0 and u1.max() < 1
    u3 = np.asarray(random_uniform(43, 7, offs))
    assert not np.array_equal(u1, u3)


# ------------------------------------------------------------------ exact

@settings(max_examples=30, deadline=None)
@given(st.lists(st.floats(-1e4, 1e4, allow_nan=False, width=32),
                min_size=2, max_size=50))
def test_exact_sum_order_invariant(vals):
    x = jnp.asarray(np.array(vals, np.float32))
    s1 = float(exact_sum(x))
    perm = np.array(vals, np.float32)
    rng = np.random.default_rng(0)
    for _ in range(4):
        rng.shuffle(perm)
        s2 = float(exact_sum(jnp.asarray(perm)))
        assert s1 == s2            # BIT-exact, not approx


def test_exact_sum_accuracy():
    x = np.asarray(RNG.standard_normal(1000), np.float32)
    got = float(exact_sum(jnp.asarray(x)))
    want = float(np.sum(x.astype(np.float64)))
    assert abs(got - want) < 1e-4


def test_fixed_roundtrip():
    x = jnp.asarray(np.array([0.0, 1.0, -1.0, 3.14159, -2.5e-7, 1e6],
                             np.float32))
    back = np.asarray(fixed_to_f32(f32_to_fixed(x)))
    np.testing.assert_allclose(back, np.asarray(x), rtol=1e-6, atol=2e-12)


def test_exact_tree_sum_matches_float():
    trees = [{"a": jnp.asarray(RNG.standard_normal((4, 4)), jnp.float32)}
             for _ in range(8)]
    got = np.asarray(exact_tree_sum(trees)["a"])
    want = sum(np.asarray(t["a"], np.float64) for t in trees)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


# ------------------------------------------------------------------- data

def test_synthetic_deterministic_and_disjoint():
    cfg = DataConfig(vocab_size=1000, seq_len=16, global_batch=8)
    src = SyntheticLM(cfg)
    b1, b2 = src.batch_at(3), src.batch_at(3)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    b3 = src.batch_at(4)
    assert not np.array_equal(b1["tokens"], b3["tokens"])
    # next-token alignment
    np.testing.assert_array_equal(b1["tokens"][:, 1:], b1["labels"][:, :-1])


def test_synthetic_host_sharding_disjoint():
    cfg = DataConfig(vocab_size=1000, seq_len=16, global_batch=8)
    h0 = SyntheticLM(cfg, host_index=0, host_count=2)
    h1 = SyntheticLM(cfg, host_index=1, host_count=2)
    full = SyntheticLM(cfg)
    b = full.batch_at(5)
    np.testing.assert_array_equal(
        np.concatenate([h0.batch_at(5)["tokens"], h1.batch_at(5)["tokens"]]),
        b["tokens"])


def test_binfile_source(tmp_path):
    data = RNG.integers(0, 60000, 10_000, dtype=np.uint16)
    path = tmp_path / "corpus.bin"
    data.tofile(path)
    cfg = DataConfig(vocab_size=60000, seq_len=64, global_batch=4,
                     source="binfile", path=str(path))
    src = make_source(cfg)
    b1, b2 = src.batch_at(0), src.batch_at(0)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert b1["tokens"].shape == (4, 64)
    np.testing.assert_array_equal(b1["tokens"][:, 1:], b1["labels"][:, :-1])


# -------------------------------------------------------------- checkpoint

def test_checkpoint_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    tree = {"params": {"w": jnp.arange(12, dtype=jnp.float32).reshape(3, 4)},
            "opt": {"step": jnp.int32(7)}}
    mgr.save(7, tree)
    like = jax.tree_util.tree_map(jnp.zeros_like, tree)
    out = mgr.restore(7, like)
    np.testing.assert_array_equal(np.asarray(out["params"]["w"]),
                                  np.asarray(tree["params"]["w"]))
    assert int(out["opt"]["step"]) == 7


def test_checkpoint_retention_and_latest(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    tree = {"x": jnp.zeros((2,))}
    for s in (1, 2, 3, 4):
        mgr.save(s, tree)
    assert mgr.all_steps() == [3, 4]
    assert mgr.latest_step() == 4


def test_checkpoint_crc_detects_corruption(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    tree = {"x": jnp.arange(16, dtype=jnp.float32)}
    path = mgr.save(1, tree)
    fn = os.path.join(path, "arr_000000.npy")
    arr = np.load(fn)
    arr[0] += 1
    np.save(fn, arr)
    with pytest.raises(IOError):
        mgr.restore(1, tree)


def test_checkpoint_async(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    tree = {"x": jnp.ones((128, 128))}
    mgr.save_async(10, tree)
    mgr.wait()
    assert mgr.latest_step() == 10
    out = mgr.restore(10, tree)
    np.testing.assert_array_equal(np.asarray(out["x"]),
                                  np.asarray(tree["x"]))
