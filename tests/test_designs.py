"""The designs facade: DesignSpec -> generate() -> CompiledDesign.

Covers the timing path (a tight clock target must reject
non-pipelineable FB designs and fall back per timing_model.meets_timing;
a latency budget must reject designs whose pipeline depth at the target
exceeds it) and provenance (spec -> json -> spec -> generate is
bit-exact vs the original design's mul on random operands, for every
registered Table-VIII design point)."""
import dataclasses
from fractions import Fraction

import numpy as np
import pytest
import jax.numpy as jnp

from repro import designs
from repro.core import limbs as L
from repro.core import timing_model as tm

RNG = np.random.default_rng(23)


def _operands(batch, bits_a, bits_b=None):
    bits_b = bits_b or bits_a
    a = jnp.asarray(L.random_limbs(RNG, (batch,), bits_a))
    b = jnp.asarray(L.random_limbs(RNG, (batch,), bits_b))
    expect = [L.from_limbs(np.asarray(x)) * L.from_limbs(np.asarray(y))
              for x, y in zip(a, b)]
    return a, b, expect


# ------------------------------------------------------------- timing path

def test_tight_clock_rejects_fb_and_falls_back():
    """Relaxed planning picks the FB feedback loop; a 0.31 ns target must
    reject it (FB cannot pipeline) and re-plan per meets_timing."""
    relaxed = designs.generate(designs.DesignSpec(32, 32, Fraction(1, 3)))
    assert any(cfg.arch == "fb" for _, cfg in relaxed.plan.configs)

    tight = designs.generate(
        designs.DesignSpec(32, 32, Fraction(1, 3), clock_ns=0.31))
    assert tight.timing_fallback
    assert all(cfg.arch != "fb" for _, cfg in tight.plan.configs)
    assert all(tm.meets_timing(cfg.arch, 32, 0.31, cfg.adder)
               for _, cfg in tight.plan.configs)
    # the clock customization costs area (synthesis stress) but the
    # compiled design still multiplies bit-exactly
    assert tight.area > tight.plan.area
    a, b, expect = _operands(6, 32)
    assert L.batch_from_limbs(np.asarray(tight.mul(a, b))) == expect


def test_strict_spec_never_plans_feedback_loops():
    d = designs.generate(
        designs.DesignSpec(16, 16, Fraction(1, 2), strict_timing=True))
    assert all(tm.pipelineable(cfg.arch, cfg.adder)
               for _, cfg in d.plan.configs)


def test_latency_budget_rejects_deep_pipelines():
    # 128b Karatsuba at 0.2 ns needs retiming stages beyond CT=3
    with pytest.raises(designs.LatencyError):
        designs.generate(designs.DesignSpec(128, 128, Fraction(1, 3),
                                            clock_ns=0.2, latency_budget=3))
    # the same design fits a looser budget
    d = designs.generate(designs.DesignSpec(128, 128, Fraction(1, 3),
                                            clock_ns=0.2, latency_budget=8))
    assert d.latency_cycles <= 8


def test_timing_properties_are_consistent():
    d = designs.generate(
        designs.DesignSpec(32, 32, Fraction(1, 2), clock_ns=0.31))
    # a met clock target bounds the achievable period from above
    assert d.fmax_estimate >= 1.0 / 0.31 - 1e-9
    assert d.latency_cycles >= 2            # CT=2 base plus any retiming
    relaxed = designs.generate(designs.DesignSpec(32, 32, Fraction(1, 2)))
    assert relaxed.area == pytest.approx(relaxed.plan.area)
    assert relaxed.latency_cycles == 2


# ------------------------------------------------------------- provenance

def test_spec_json_round_trip_is_lossless():
    spec = designs.DesignSpec(32, 32, 3.5, clock_ns=0.8, latency_budget=6,
                              strict_timing=True, signed=False,
                              scheduler="greedy", backend="core",
                              replicas=1)
    assert designs.DesignSpec.from_json(spec.to_json()) == spec
    # fractional TP survives exactly (no float round-trip)
    assert designs.DesignSpec.from_json(spec.to_json()).throughput \
        == Fraction(7, 2)


@pytest.mark.parametrize("name", sorted(designs.TABLE_VIII) + ["tp3p5_w32"])
def test_registered_point_round_trip_bit_exact(name):
    """Acceptance: DesignSpec.from_json(spec.to_json()) compiles to a
    design whose mul output is bit-exact equal to the original's."""
    spec = designs.get(name)
    spec2 = designs.DesignSpec.from_json(spec.to_json())
    assert spec2 == spec
    d1 = designs.generate(spec)
    d2 = designs.generate(spec2)
    batch = 2 * max(spec.throughput.numerator, 1)
    a, b, expect = _operands(batch, spec.bits_a, spec.bits_b)
    out1, out2 = d1.mul(a, b), d2.mul(a, b)
    assert np.array_equal(np.asarray(out1), np.asarray(out2))
    assert L.batch_from_limbs(np.asarray(out1)) == expect


def test_non_decomposable_throughput_raises():
    """plan_throughput silently under-provisions TPs its CT set cannot
    reach (3/10 -> a 1/4 bank); the facade must refuse instead."""
    with pytest.raises(designs.DesignError):
        designs.generate(designs.DesignSpec(32, 32, Fraction(3, 10)))
    # a decomposable neighbour still compiles, at its exact rate
    d = designs.generate(designs.DesignSpec(32, 32, Fraction(5, 12)))
    assert d.throughput == Fraction(5, 12)
    assert d.report(10).measured_throughput == Fraction(5, 12)


def test_generate_accepts_registered_names():
    d = designs.generate("tp3p5_w32")
    assert d.throughput == Fraction(7, 2)
    with pytest.raises(ValueError):
        designs.generate("no_such_design")


def test_registry_refuses_silent_redefinition():
    spec = designs.get("tp3p5_w32")
    designs.register("tp3p5_w32", spec)            # same spec: fine
    other = dataclasses.replace(spec, scheduler="greedy")
    with pytest.raises(ValueError):
        designs.register("tp3p5_w32", other)
    designs.register("_test_tmp", other, overwrite=True)
    assert designs.get("_test_tmp") == other


def test_at_fmax_builder():
    spec = designs.DesignSpec.at_fmax(32, 32, Fraction(1, 2), fmax_ghz=2.0)
    assert spec.clock_ns == pytest.approx(0.5)


# ------------------------------------------------------- execution surface

def test_int_convenience_and_signed_mul():
    d = designs.generate(designs.DesignSpec(32, 32, 1))
    assert d.mul(0xDEADBEEF, 0xCAFEBABE) == 0xDEADBEEF * 0xCAFEBABE
    with pytest.raises(ValueError):
        d.mul(1 << 32, 1)                           # out of range

    ds = designs.generate(designs.DesignSpec(32, 32, 1, signed=True))
    assert ds.mul(-5, 7) == -35
    assert ds.mul(-(2 ** 31), 2 ** 31 - 1) == -(2 ** 31) * (2 ** 31 - 1)
    # signed plans carry the flag down to the instance configs
    assert all(cfg.signed for _, cfg in ds.plan.configs)


def test_signed_rejects_kernel_backend():
    with pytest.raises(designs.DesignError):
        designs.generate(
            designs.DesignSpec(32, 32, 1, signed=True, backend="kernel"))


def test_scheduler_flows_from_spec_to_reports():
    d = designs.generate(
        designs.DesignSpec(32, 32, Fraction(7, 2), scheduler="greedy"))
    rep = d.report(14)
    assert rep.scheduler == "greedy"
    assert rep.measured_throughput == Fraction(7, 2)


def test_replay_respects_arrival_trace():
    d = designs.generate("tp3p5_w32")
    eager = d.report(14)
    slow = d.replay(tuple(2 * k for k in range(14)))   # 1 op / 2 cycles
    assert slow.scheduler == "streaming"
    assert slow.cycles > eager.cycles
    assert slow.cycles >= 2 * 13                       # last arrival


def test_plan_describe_distinguishes_adder_and_signed():
    """Satellite fix: two genuinely different plans (3CA vs 1CA final
    adder, signed vs unsigned) must no longer print identically."""
    from repro.core.mcim import MCIMConfig
    from repro.core.planner import Plan
    base = MCIMConfig(arch="karatsuba", ct=3, levels=1)
    p1 = Plan(configs=((1, base),), throughput=Fraction(1, 3), area=1.0)
    p3ca = Plan(configs=((1, dataclasses.replace(base, adder="3ca")),),
                throughput=Fraction(1, 3), area=1.0)
    psgn = Plan(configs=((1, dataclasses.replace(base, signed=True)),),
                throughput=Fraction(1, 3), area=1.0)
    assert len({p1.describe(), p3ca.describe(), psgn.describe()}) == 3
    assert "3ca" in p3ca.describe()
    assert "signed" in psgn.describe()


def test_replicas_validate_against_available_devices():
    import jax
    too_many = len(jax.devices()) + 1
    with pytest.raises(designs.DesignError):
        designs.generate(
            designs.DesignSpec(32, 32, 1, replicas=too_many))
