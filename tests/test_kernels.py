"""Per-kernel validation: Pallas (interpret=True) vs pure-jnp oracles.

Shape/dtype sweeps per the kernel-testing contract: every kernel is
checked bit-exactly (integer outputs) or to bf16 tolerance (dequantized
outputs) against its ref.py oracle.
"""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core import limbs as L
from repro.kernels.mcim_fold import (mcim_fold_mul, mcim_fold_mul_ref,
                                     big_mul, vmem_bytes_per_step)
from repro.kernels.int8_matmul import (int8_matmul, int8_matmul_ref,
                                       quantized_matmul, quantize_rows)

RNG = np.random.default_rng(7)


# ------------------------------------------------------------ mcim_fold

@pytest.mark.parametrize("bits", [32, 64, 128, 256])
@pytest.mark.parametrize("ct", [2, 3, 4])
def test_mcim_fold_matches_ref(bits, ct):
    a = jnp.asarray(L.random_limbs(RNG, (64,), bits))
    b = jnp.asarray(L.random_limbs(RNG, (64,), bits))
    got = mcim_fold_mul(a, b, ct=ct, tile_b=32, interpret=True)
    want = mcim_fold_mul_ref(a, b, ct=ct)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("tile_b", [1, 8, 64])
def test_mcim_fold_tile_sweep(tile_b):
    a = jnp.asarray(L.random_limbs(RNG, (64,), 64))
    b = jnp.asarray(L.random_limbs(RNG, (64,), 64))
    got = mcim_fold_mul(a, b, ct=2, tile_b=tile_b, interpret=True)
    want = mcim_fold_mul_ref(a, b, ct=2)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_mcim_fold_rectangular():
    a = jnp.asarray(L.random_limbs(RNG, (32,), 128))
    b = jnp.asarray(L.random_limbs(RNG, (32,), 64))
    got = mcim_fold_mul(a, b, ct=2, tile_b=32, interpret=True)
    want = mcim_fold_mul_ref(a, b, ct=2)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_mcim_fold_oracle_is_exact():
    """The kernel chain all the way to Python ints."""
    vals_a = [0, 1, 2**64 - 1, 0xDEADBEEFCAFEBABE]
    vals_b = [2**64 - 1, 7, 2**63, 0x0123456789ABCDEF]
    a = jnp.asarray(L.batch_to_limbs(vals_a, 4))
    b = jnp.asarray(L.batch_to_limbs(vals_b, 4))
    got = mcim_fold_mul(a, b, ct=2, tile_b=4, interpret=True)
    for va, vb, row in zip(vals_a, vals_b, np.asarray(got)):
        assert L.from_limbs(row) == va * vb


def test_big_mul_wrapper_and_unbatched():
    a = jnp.asarray(L.random_limbs(RNG, (48,), 96))
    b = jnp.asarray(L.random_limbs(RNG, (48,), 96))
    got = big_mul(a, b, ct=3)
    want = mcim_fold_mul_ref(a, b, ct=3)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    one = big_mul(a[0], b[0], ct=3)
    np.testing.assert_array_equal(np.asarray(one), np.asarray(want[0]))


def test_vmem_footprint_folds_with_ct():
    """The TPU 'area' claim: per-step working set shrinks ~1/CT."""
    base = vmem_bytes_per_step(8, 64, 1, 256)
    prev = base
    for ct in (2, 4, 8):
        folded = vmem_bytes_per_step(8, 64, ct, 256)
        assert folded < prev
        prev = folded
    # B chunk and accumulator fold by 1/CT; only the A tile is fixed.
    assert vmem_bytes_per_step(8, 64, 8, 256) < 0.30 * base


# ---------------------------------------------------------- int8_matmul

@pytest.mark.parametrize("m,k,n", [(64, 64, 64), (128, 256, 128),
                                   (32, 512, 64), (256, 128, 256)])
def test_int8_matmul_matches_ref(m, k, n):
    x = jnp.asarray(RNG.integers(-127, 128, (m, k), dtype=np.int8))
    w = jnp.asarray(RNG.integers(-127, 128, (k, n), dtype=np.int8))
    sx = jnp.asarray(RNG.random(m, dtype=np.float32) + 0.01)
    sw = jnp.asarray(RNG.random(n, dtype=np.float32) + 0.01)
    got = int8_matmul(x, w, sx, sw, block_m=32, block_n=32, block_k=32,
                      interpret=True)
    want = int8_matmul_ref(x, w, sx, sw)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=1e-2, atol=1e-2)


@pytest.mark.parametrize("bk", [32, 64, 128])
def test_int8_matmul_fold_depth_invariance(bk):
    """CT = K/block_k must not change the result (exact int32 accum)."""
    m = k = n = 128
    x = jnp.asarray(RNG.integers(-127, 128, (m, k), dtype=np.int8))
    w = jnp.asarray(RNG.integers(-127, 128, (k, n), dtype=np.int8))
    ones_m, ones_n = jnp.ones(m), jnp.ones(n)
    got = int8_matmul(x, w, ones_m, ones_n, block_m=64, block_n=64,
                      block_k=bk, interpret=True, out_dtype=jnp.float32)
    want = int8_matmul_ref(x, w, ones_m, ones_n, out_dtype=jnp.float32)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_quantize_roundtrip_error_bound():
    x = jnp.asarray(RNG.standard_normal((64, 128)), jnp.float32)
    q, s = quantize_rows(x, axis=1)
    back = q.astype(jnp.float32) * s[:, None]
    err = np.abs(np.asarray(back - x))
    step = np.asarray(s)[:, None]
    assert (err <= 0.5 * step + 1e-6).all()


def test_quantized_matmul_accuracy():
    x = jnp.asarray(RNG.standard_normal((64, 256)), jnp.float32)
    w = jnp.asarray(RNG.standard_normal((256, 64)), jnp.float32)
    got = np.asarray(quantized_matmul(x, w, block=64), np.float32)
    want = np.asarray(x @ w, np.float32)
    # int8 with per-row/col scales: ~1% relative error on gaussian data
    rel = np.linalg.norm(got - want) / np.linalg.norm(want)
    assert rel < 0.02, rel
