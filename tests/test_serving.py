"""Integration tests for the online serving subsystem.

The worker-loop contracts the ISSUE's bench gates rely on, checked on
real compiled designs: zero SLO violations by construction, refusals
only with infeasibility evidence, bit-exact responses vs the bigint
oracle across registry design points (including the fractional-TP
tp3p5_w32 bank), work stealing under a skewed router, autoscaling, the
shared latency-histogram accounting path, and the verifier/lint
coverage of the new tree.
"""
import dataclasses
import pathlib
from fractions import Fraction

import numpy as np
import pytest

from repro import designs
from repro.core import limbs as L
from repro.core.bank import Bank
from repro.core.bank import schedule as S
from repro.serving import (Autoscaler, SLOScheduler, Worker, admissible,
                           bursty_arrivals, diurnal_arrivals,
                           earliest_completion, edf_schedule,
                           poisson_arrivals, synthesize)

#: (name, below-TP load, overload) -- covers a pure folded point, the
#: paper's fractional-TP mixed bank, and the wide CT combination
POINTS = ("tbl8_w32_relaxed", "tp3p5_w32", "tp5over6_w128")


def _requests(design, load, n, seed, budget_mult=32):
    tp = float(design.plan.throughput)
    budget = max(8, int(budget_mult / tp))
    arr = poisson_arrivals(n, load * tp, seed=seed)
    return synthesize(arr, design.spec.bits_a, design.spec.bits_b,
                      budget=budget, seed=seed + 1)


# ------------------------------------------------------------ registration

def test_slo_edf_registered_and_contract_clean():
    import repro.serving  # noqa: F401  (registers at import)
    from repro.verify import contracts
    assert "slo_edf" in S.SCHEDULERS
    for cts, n_ops in contracts.SCHEDULER_CASES:
        assert not list(contracts.check_scheduler(
            S.SCHEDULERS["slo_edf"], cts, n_ops))


def test_slo_default_reduces_to_greedy():
    for cts in [(1,), (2, 3), (1, 1, 2), (1, 2, 3, 4)]:
        for n in (0, 1, 7, 23):
            assert SLOScheduler().schedule(cts, n) == \
                S.greedy_schedule(cts, n)


def test_edf_orders_by_deadline():
    # two ops, one instance: the tighter deadline issues first even
    # though it has the later index
    assign, makespan = edf_schedule((2,), 2, (0, 0), (100, 4))
    assert assign == ((1, 0),)
    assert makespan == 4
    # deadline traces must match n_ops
    with pytest.raises(ValueError):
        edf_schedule((2,), 3, (0, 0, 0), (1, 2))


def test_admission_predicates():
    cts, free = (1, 2), [5, 0]
    # best: instance 1 issues at max(0, 3)=3, retires 5
    assert earliest_completion(cts, free, 3) == 5
    assert admissible(cts, free, 3, 5)
    assert not admissible(cts, free, 3, 4)


# ------------------------------------------------------- histogram helpers

def test_completion_cycles_matches_schedule_makespan():
    cts = (1, 2, 3)
    for n in (0, 1, 5, 17):
        assign, makespan = S.greedy_schedule(cts, n)
        finish = S.completion_cycles(cts, assign)
        assert len(finish) == n
        assert (max(finish) if n else 0) == makespan


def test_histogram_percentiles():
    hist = S.latency_histogram([3, 1, 1, 7])
    assert hist == ((1, 2), (3, 1), (7, 1))
    assert S.histogram_percentile(hist, 0.5) == 1
    assert S.histogram_percentile(hist, 0.75) == 3
    assert S.histogram_percentile(hist, 0.99) == 7
    assert S.histogram_percentile((), 0.5) is None
    with pytest.raises(ValueError):
        S.histogram_percentile(hist, 1.5)


def test_bank_report_latency_hist():
    design = designs.generate("tbl8_w32_relaxed")
    rep = design.report(8)
    total = sum(c for _, c in rep.latency_hist)
    assert total == 8
    assert rep.latency_p50 is not None
    assert rep.latency_p99 >= rep.latency_p50
    # streaming replay: latencies measured from the real arrival trace
    trace = (0, 0, 4, 4, 9)
    rep2 = design.replay(trace)
    assert sum(c for _, c in rep2.latency_hist) == len(trace)


# ------------------------------------------------------------- worker loop

@pytest.mark.parametrize("name", POINTS)
def test_serve_below_tp_zero_violations_bit_exact(name):
    design = designs.generate(name)
    reqs = _requests(design, 0.7, 40, seed=11)
    rep, resp = design.serve(reqs, check=True)
    assert rep.n_requests == 40
    assert len(resp) == 40
    assert rep.slo_violations == 0
    assert rep.n_refused == 0
    assert rep.bit_exact is True
    assert all(r.met_deadline for r in resp.values())
    # admission proof on every response
    assert all(r.earliest_possible <= r.deadline for r in resp.values())
    assert all(r.arrival <= r.issue < r.finish for r in resp.values())


def test_serve_overload_refuses_with_evidence():
    design = designs.generate("tp3p5_w32")
    reqs = _requests(design, 2.5, 120, seed=13, budget_mult=24)
    rep, resp = design.serve(reqs, check=True)
    assert rep.slo_violations == 0          # admitted always meet SLO
    assert rep.n_refused > 0                # the excess is refused
    assert rep.bit_exact is True
    refused = [r for r in resp.values() if not r.admitted]
    assert all(r.earliest_possible > r.deadline for r in refused)
    # graceful degradation, not collapse
    assert rep.goodput >= 0.6 * float(Fraction(rep.provisioned_tp))


def test_serve_is_deterministic():
    design = designs.generate("tbl8_w32_relaxed")
    reqs = _requests(design, 0.9, 40, seed=17)
    rep1, resp1 = design.serve(reqs, replicas=2)
    rep2, resp2 = design.serve(reqs, replicas=2)
    assert resp1 == resp2
    assert rep1.latency_hist == rep2.latency_hist
    assert rep1.steals == rep2.steals


def test_work_stealing_under_skewed_router():
    design = designs.generate("tp3p5_w32")
    tp = float(design.plan.throughput)
    arr = bursty_arrivals(80, 1.2 * tp, seed=19, burst=8)
    reqs = synthesize(arr, 32, 32, budget=24, seed=20)
    # even rids pin every request's home to replica 0: only the work
    # stealer can use replica 1
    skewed = tuple(dataclasses.replace(r, rid=2 * r.rid) for r in reqs)
    rep, resp = design.serve(skewed, replicas=2, check=True)
    assert rep.steals > 0
    assert any(r.stolen and r.replica == 1 for r in resp.values())
    assert rep.slo_violations == 0
    assert rep.bit_exact is True
    # stealing must strictly help: a no-steal run of the same stream
    # cannot beat it on completions
    rep_ns, _ = design.serve(skewed, replicas=2, steal=False)
    assert rep.n_completed >= rep_ns.n_completed


def test_round_batches_bucketed_power_of_two():
    from repro.serving.worker import _bucket
    assert [_bucket(n) for n in (1, 2, 3, 5, 8, 9)] == [1, 2, 4, 8, 8, 16]
    design = designs.generate("tbl8_w32_relaxed")
    reqs = _requests(design, 0.8, 50, seed=23)
    w = Worker(design)
    w.run(reqs)
    # ragged rounds share a bounded set of compiled batch sizes
    for rep in w.replicas:
        sizes = set(rep.bank._compiled)
        assert all(s & (s - 1) == 0 for s in sizes)


def test_fused_round_is_one_launch():
    design = designs.generate("tp3p5_w32")
    bank = Bank(design.plan, 32, 32, backend="fused")
    assert bank.launch_count(16) == 1


# -------------------------------------------------------------- autoscaler

def test_autoscaler_up_immediate_down_patient():
    a = Autoscaler(Fraction(1, 2), max_replicas=4, ema=1.0, patience=2)
    # burst: rate 1.2 ops/cy vs 0.5*0.85 per replica -> needs 3
    assert a.observe(16, 19, 16, live=1) == 3
    # one quiet window is not enough to scale down...
    assert a.observe(32, 1, 16, live=3) == 3
    # ...two consecutive are
    assert a.observe(48, 1, 16, live=3) == 1


def test_autoscaler_worker_scales_on_diurnal_trace():
    design = designs.generate("tbl8_w32_relaxed")
    tp = float(design.plan.throughput)
    scaler = Autoscaler(design.plan.throughput, max_replicas=4,
                        ema=0.6, patience=2)
    arr = diurnal_arrivals(120, 1.2 * tp, seed=29, period=128)
    reqs = synthesize(arr, 32, 32, budget=256, seed=30)
    rep, _ = design.serve(reqs, autoscaler=scaler, check=True)
    peaks = [n for _, n in rep.replica_timeline]
    assert max(peaks) > 1                   # scaled up under the peak
    assert rep.slo_violations == 0
    assert rep.bit_exact is True


def test_autoscaler_recommends_from_pareto_front():
    from repro.autotune.pareto import Candidate, ParetoFront
    from repro.core.mcim import MCIMConfig

    def cand(tp, area):
        return Candidate(
            spec=designs.DesignSpec(32, 32, Fraction(tp)),
            configs=((1, MCIMConfig(arch="fb", ct=2)),),
            area_um2=area, latency_cycles=2, fmax_ghz=1.0,
            energy_per_op_pj=1.0, peak_power_mw=1.0, slack_ns=(0.0,))

    front = ParetoFront([cand("1/2", 100.0), cand("7/2", 900.0)])
    a = Autoscaler(Fraction(7, 2), ema=1.0)
    a.observe(16, 4, 16, live=1)            # sustained rate 0.25/cy
    rec = a.recommend(front)
    assert rec is not None
    assert rec.spec.throughput == Fraction(1, 2)   # the cheaper point
    # nothing on the front covers 10 ops/cy
    assert front.best_meeting(10.0) is None
    with pytest.raises(ValueError):
        front.best_meeting(0.1, objective="nope")
    # when load fills the provisioned design, keep it
    a.rate = 3.6
    assert a.recommend(front) is None


# ------------------------------------------------- launch-layer satellites

def test_serve_engine_completion_trace():
    from repro.launch.serve import ServeEngine
    eng = ServeEngine.__new__(ServeEngine)   # no model needed for the
    eng._arrivals = [(0, 0), (1, 0), (2, 4)]  # accounting-path surface
    eng._completions = {}
    eng.live = np.array([True, True, False])
    eng.request_of_slot = [1, 0, -1]
    eng.cycle = 9
    eng.finish(0)                            # rid 1 finishes at cycle 9
    assert eng.completion_trace() == (-1, 9, -1)
    eng.cycle = 12
    eng.finish(1)                            # rid 0 finishes at cycle 12
    assert eng.completion_trace() == (12, 9, -1)
    assert eng.latency_trace() == (12, 9)    # rid 2 still in flight
    eng.finish(2)                            # empty slot: no-op record
    assert eng.completion_trace() == (12, 9, -1)


# ---------------------------------------------------------------- hygiene

def test_serving_tree_is_lint_clean():
    import repro.serving
    from repro.verify import lint
    root = pathlib.Path(repro.serving.__file__).parent
    assert not lint.lint_tree(root)


def test_synthesize_validates():
    with pytest.raises(ValueError):
        synthesize((3, 1), 32, 32, budget=8)          # decreasing trace
    with pytest.raises(ValueError):
        synthesize((0, 1), 32, 32, budget=0)          # no budget
    with pytest.raises(ValueError):
        synthesize((0,), 32, 32, budget=8,
                   width_classes=((64, 32),))         # wider than design
    reqs = synthesize((0, 0, 5), 32, 32, budget=8,
                      width_classes=((32, 32), (16, 8)))
    assert [r.tenant for r in reqs] == [0, 1, 0]
    assert all(r.deadline == r.arrival + 8 for r in reqs)
    # narrow tenants zero-extend into the design's limbs
    narrow = reqs[1]
    assert L.from_limbs(np.asarray(narrow.a, np.uint32)) < 1 << 16
    assert len(narrow.a) == L.n_limbs_for_bits(32)
