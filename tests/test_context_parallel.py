"""Context-parallel attention == single-device flash attention (bitwise
semantics checked numerically on a 4-device placeholder mesh)."""
import json
import os
import subprocess
import sys

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import numpy as np
import jax, jax.numpy as jnp
from repro.models.attention import flash_attention, \
    flash_attention_context_parallel

mesh = jax.make_mesh((1, 4), ("data", "model"))
rng = np.random.default_rng(0)
B, S, H, KV, D = 2, 512, 4, 1, 64
q = jnp.asarray(rng.standard_normal((B, S, H, D)), jnp.bfloat16)
k = jnp.asarray(rng.standard_normal((B, S, KV, D)), jnp.bfloat16)
v = jnp.asarray(rng.standard_normal((B, S, KV, D)), jnp.bfloat16)

for kind, window in [("causal", None), ("local", 128), ("prefix", None),
                     ("none", None)]:
    pl = 64 if kind == "prefix" else None
    ref = flash_attention(q, k, v, mask_kind=kind, window=window,
                          prefix_len=pl, q_chunk=128, k_chunk=128)
    with mesh:
        got = jax.jit(lambda a, b, c: flash_attention_context_parallel(
            a, b, c, mesh, mask_kind=kind, window=window, prefix_len=pl,
            q_chunk=128, k_chunk=128))(q, k, v)
    err = float(jnp.max(jnp.abs(got.astype(jnp.float32)
                                - ref.astype(jnp.float32))))
    assert err < 0.05, (kind, err)
    print("OK", kind, err)
print("ALLOK")
"""


def test_context_parallel_matches_flash():
    env = dict(os.environ,
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "ALLOK" in out.stdout
