"""Hypothesis property tests for SLO scheduling and admission control.

Pure-scheduler properties (no jax) run on wide random grids; the
worker-loop properties -- no admitted request ever misses its deadline,
refusals only when provably infeasible, bursty-trace bit-exactness vs
the bigint oracle -- execute a real compiled design, so they run fewer
examples on small request sets.
"""
import pytest

hyp = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.bank import schedule as S
from repro.serving import slo
from repro.serving.requests import (bursty_arrivals, poisson_arrivals,
                                    synthesize)

CTS = st.lists(st.integers(min_value=1, max_value=8),
               min_size=1, max_size=6).map(tuple)
N_OPS = st.integers(min_value=0, max_value=60)
SEEDS = st.integers(min_value=0, max_value=2**16)


@st.composite
def edf_cases(draw):
    cts = draw(CTS)
    n = draw(N_OPS)
    arrivals = tuple(sorted(
        draw(st.lists(st.integers(min_value=0, max_value=40),
                      min_size=n, max_size=n))))
    deadlines = tuple(a + draw(st.integers(min_value=1, max_value=60))
                      for a in arrivals)
    return cts, n, arrivals, deadlines


@settings(max_examples=200, deadline=None)
@given(case=edf_cases())
def test_edf_complete_and_duplicate_free(case):
    cts, n, arrivals, deadlines = case
    assign, makespan = slo.edf_schedule(cts, n, arrivals, deadlines)
    flat = sorted(op for ops in assign for op in ops)
    assert flat == list(range(n)), "incomplete or duplicated"
    assert len(assign) == len(cts)
    assert makespan >= 0


@settings(max_examples=200, deadline=None)
@given(case=edf_cases())
def test_edf_deterministic_and_chain_reconstructible(case):
    cts, n, arrivals, deadlines = case
    first = slo.edf_schedule(cts, n, arrivals, deadlines)
    assert slo.edf_schedule(cts, n, arrivals, deadlines) == first
    # per-instance issue chains reconstruct the makespan exactly: the
    # one-accounting-path property Bank.report and the worker share
    assign, makespan = first
    finish = S.completion_cycles(cts, assign, arrivals)
    assert (max(finish) if n else 0) == makespan
    # no op finishes before its arrival + its instance's cycle time
    for ops, ct in zip(assign, cts):
        for k in ops:
            assert finish[k] >= arrivals[k] + ct


@settings(max_examples=200, deadline=None)
@given(cts=CTS, n=N_OPS)
def test_slo_without_deadlines_is_greedy(cts, n):
    assert slo.SLOScheduler().schedule(cts, n) == S.greedy_schedule(cts, n)


@settings(max_examples=200, deadline=None)
@given(cts=CTS,
       free=st.lists(st.integers(min_value=0, max_value=50),
                     min_size=1, max_size=6),
       arrival=st.integers(min_value=0, max_value=50))
def test_earliest_completion_is_a_lower_bound(cts, free, arrival):
    free = (free * len(cts))[:len(cts)]
    best = slo.earliest_completion(cts, free, arrival)
    # achievable by some instance...
    assert any(max(f, arrival) + ct == best
               for f, ct in zip(free, cts))
    # ...and no instance beats it
    assert all(max(f, arrival) + ct >= best
               for f, ct in zip(free, cts))
    assert best >= arrival + min(cts)


@settings(max_examples=100, deadline=None)
@given(lat=st.lists(st.integers(min_value=0, max_value=30), max_size=50),
       q1=st.floats(min_value=0.0, max_value=1.0),
       q2=st.floats(min_value=0.0, max_value=1.0))
def test_histogram_percentile_monotone(lat, q1, q2):
    hist = S.latency_histogram(lat)
    assert sum(c for _, c in hist) == len(lat)
    if not lat:
        assert S.histogram_percentile(hist, q1) is None
        return
    lo, hi = sorted((q1, q2))
    assert S.histogram_percentile(hist, lo) <= \
        S.histogram_percentile(hist, hi)
    assert S.histogram_percentile(hist, 1.0) == max(lat)


# --------------------------------------------------- worker-loop properties

@pytest.fixture(scope="module")
def design():
    from repro import designs
    return designs.generate("tbl8_w32_relaxed")


@settings(max_examples=10, deadline=None)
@given(seed=SEEDS,
       load=st.floats(min_value=0.3, max_value=2.5),
       budget=st.integers(min_value=4, max_value=80))
def test_admissions_meet_deadline_refusals_infeasible(design, seed, load,
                                                      budget):
    tp = float(design.plan.throughput)
    arr = poisson_arrivals(16, load * tp, seed=seed)
    reqs = synthesize(arr, 32, 32, budget=budget, seed=seed + 1)
    rep, resp = design.serve(reqs)
    assert rep.slo_violations == 0
    for r in resp.values():
        if r.admitted:
            # the committed slot honours the admission proof
            assert r.arrival <= r.issue < r.finish <= r.deadline
            assert r.earliest_possible <= r.deadline
        else:
            # refusal evidence: even the best instance was too late
            assert r.earliest_possible > r.deadline


@settings(max_examples=6, deadline=None)
@given(seed=SEEDS)
def test_bursty_trace_bit_exact_vs_oracle(design, seed):
    tp = float(design.plan.throughput)
    arr = bursty_arrivals(20, 1.1 * tp, seed=seed, burst=5)
    reqs = synthesize(arr, 32, 32, budget=100, seed=seed + 1,
                      width_classes=((32, 32), (16, 24), (8, 8)))
    rep, resp = design.serve(reqs, replicas=2, check=True)
    assert rep.n_checked == rep.n_admitted
    assert rep.bit_exact is True
    # independent re-check through the Request's own oracle
    for req in reqs:
        r = resp[req.rid]
        if r.admitted:
            import numpy as np
            from repro.core import limbs as L
            assert L.from_limbs(np.asarray(r.product, np.uint32)) == \
                req.oracle()
