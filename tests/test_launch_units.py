"""Unit tests: sharding rules, roofline parsing, serve engine, configs,
adafactor."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.launch import sharding as shd
from repro.launch import roofline
from repro.configs import get_config, ARCH_NAMES, SHAPES, SKIPS, \
    cell_runnable
from repro.models import build_model


def _mesh(data=2, model=2):
    devs = np.array(jax.devices()[:1] * (data * model)).reshape(data, model)
    return Mesh(devs, ("data", "model"))


# ------------------------------------------------------------- sharding

def test_attn_cache_spec_kv_divisible():
    m = _mesh(2, 2)
    spec = shd.attn_cache_spec(m, (8, 128, 4, 64))
    assert spec == P("data", None, "model", None)


def test_attn_cache_spec_hd_fallback():
    m = _mesh(2, 16)
    # kv=8 % 16 != 0 -> head_dim takes the model axis
    spec = shd.attn_cache_spec(m, (32, 1024, 8, 128))
    assert spec == P("data", None, None, "model")


def test_attn_cache_spec_seq_fallback_batch1():
    m = _mesh(4, 2)
    spec = shd.attn_cache_spec(m, (1, 1024, 2, 64))
    assert spec == P(None, "data", "model", None)


def test_cache_specs_tree_dispatch():
    m = _mesh(2, 2)
    cfg = get_config("zamba2-1.2b", smoke=True)
    model = build_model(cfg)
    tree = model.cache_spec(4, 64)
    specs = shd.cache_specs(tree, m)
    assert (jax.tree_util.tree_structure(specs)
            == jax.tree_util.tree_structure(tree))


def test_batch_spec_divisibility_fallback():
    m = _mesh(4, 2)
    assert shd.batch_spec(m, 2, 8)[0] in ("data", ("data",))
    assert shd.batch_spec(m, 2, 3)[0] is None      # 3 % 4 != 0


# ------------------------------------------------------------- roofline

def test_roofline_terms_and_dominance():
    t = roofline.roofline_terms(197e12, 819e9, 50e9)
    assert abs(t["compute_s"] - 1.0) < 1e-9
    assert abs(t["memory_s"] - 1.0) < 1e-9
    assert abs(t["collective_s"] - 1.0) < 1e-9
    t2 = roofline.roofline_terms(1e12, 819e9 * 5, 0)
    assert t2["dominant"] == "memory"


def test_parse_collectives_ring_costs():
    hlo = """
ENTRY %main (p: f32[16]) -> f32[16] {
  %ar = f32[1024,512]{1,0} all-reduce(%x), replica_groups={{0,1,2,3}}, to_apply=%sum
  %ag = bf16[64,256]{1,0} all-gather(%y), replica_groups=[8,4]<=[32], dimensions={0}
}
"""
    out = roofline.parse_collectives(hlo)
    ar_bytes = 1024 * 512 * 4
    assert out["all-reduce"]["result_bytes"] == ar_bytes
    assert out["all-reduce"]["link_bytes"] == 2 * ar_bytes * 3 / 4
    ag_bytes = 64 * 256 * 2
    assert out["all-gather"]["link_bytes"] == ag_bytes * 3 / 4


# --------------------------------------------------------------- configs

def test_registry_complete_and_cells():
    assert len(ARCH_NAMES) == 10
    runnable = sum(cell_runnable(a, s) for a in ARCH_NAMES for s in SHAPES)
    assert runnable == 40 - len(SKIPS) == 32


def test_padded_vocab_shardable():
    for a in ARCH_NAMES:
        cfg = get_config(a)
        assert cfg.padded_vocab % 256 == 0
        assert cfg.padded_vocab >= cfg.vocab_size


def test_reduced_configs_exercise_structure():
    g3 = get_config("gemma3-1b", smoke=True)
    assert g3.n_layers % (g3.local_per_global + 1) != 0   # has a tail
    z2 = get_config("zamba2-1.2b", smoke=True)
    assert z2.n_layers % z2.shared_attn_every != 0        # has a tail


# -------------------------------------------------------------- adafactor

def test_adafactor_reduces_loss_and_state_size():
    from repro.optim.adafactor import (AdafactorConfig, init_state,
                                       apply_updates, state_bytes)
    rng = np.random.default_rng(0)
    params = {"w": jnp.asarray(rng.standard_normal((64, 32)), jnp.float32),
              "b": jnp.zeros((32,), jnp.float32)}
    target = jax.tree_util.tree_map(jnp.ones_like, params)
    cfg = AdafactorConfig(lr=0.05)
    state = init_state(params, cfg)

    def loss(p):
        return sum(jnp.sum((a - b) ** 2) for a, b in zip(
            jax.tree_util.tree_leaves(p),
            jax.tree_util.tree_leaves(target)))

    l0 = float(loss(params))
    for _ in range(200):
        grads = jax.grad(loss)(params)
        params, state, _ = apply_updates(params, grads, state, cfg)
    assert float(loss(params)) < 0.1 * l0
    adam_b, af_b = state_bytes(params)
    assert af_b < 0.1 * adam_b           # factored state is tiny

    # factored state shapes
    assert state["v"]["w"]["vr"].shape == (64,)
    assert state["v"]["w"]["vc"].shape == (32,)
    assert state["v"]["b"]["v"].shape == (32,)


# ----------------------------------------------------------------- serve

def test_serve_engine_continuous_batching():
    from repro.launch.serve import main as serve_main
    eng = serve_main(["--arch", "mamba2-370m", "--smoke",
                      "--requests", "3", "--slots", "2",
                      "--prompt-len", "8", "--max-new", "4"])
    assert len(eng.outputs) == 3
    assert all(len(v) >= 4 for v in eng.outputs.values())
    # admissions were recorded as a streaming-consumable arrival trace:
    # one entry per request, nondecreasing, starting at cycle 0, and the
    # third request only entered after a slot freed (2 slots, 3 requests)
    trace = eng.arrival_trace()
    assert len(trace) == 3
    assert list(trace) == sorted(trace)
    assert trace[0] == 0 and trace[-1] > 0
    from repro.core.bank import StreamingScheduler
    assign, makespan = StreamingScheduler(arrivals=trace).schedule(
        (1, 1), len(trace))
    assert sorted(op for ops in assign for op in ops) == [0, 1, 2]
    assert makespan >= trace[-1] + 1
