"""core.power_model: calibration, paper headline directions, invariants.

The power model has ONE fitted scale (FJ_PER_CELL, Star 16x16 = 1 pJ);
everything else is bit-level activity counting.  These tests pin the
calibration anchor exactly and check the paper's Sec. V energy story:
TP=1/2 folded designs save double-digit energy (paper: up to 33%) and
cut peak power hard (paper: 65% average), and deeper folding never
costs energy.
"""
import pytest

from repro.core import power_model as pm
from repro.core import area_model as am
from repro.core import timing_model as tm
from repro.core.mcim import MCIMConfig

STAR = MCIMConfig(arch="star", ct=1)
FB2 = MCIMConfig(arch="fb", ct=2)
WIDTHS = (8, 16, 32, 64, 128)


# ------------------------------------------------------------ calibration

def test_calibration_anchor_exact():
    # the single fitted scale: Star 16x16 == 1.0 pJ/op by construction
    assert pm.energy_per_op_pj(16, 16, STAR) == pytest.approx(1.0)


def test_breakdown_components_positive_and_sum():
    for cfg in (STAR, FB2, MCIMConfig(arch="ff", ct=4),
                MCIMConfig(arch="karatsuba", ct=3, levels=2, adder="3ca")):
        e = pm.mcim_energy(32, 32, cfg)
        assert e.ppm > 0 and e.compressor > 0 and e.final_adder > 0
        assert e.registers >= 0 and e.leakage > 0
        assert e.dynamic == pytest.approx(
            e.ppm + e.compressor + e.final_adder + e.registers)
        assert e.total == pytest.approx(e.dynamic + e.leakage)


def test_leakage_tracks_area():
    # leakage is proportional to modeled area (per-op, NOT x cycles)
    for cfg in (STAR, FB2, MCIMConfig(arch="fb", ct=6)):
        e = pm.mcim_energy(32, 32, cfg)
        area_cells = am.mcim_area(32, 32, cfg).total
        assert e.leakage == pytest.approx(pm.LEAK_RATIO * area_cells)


# ------------------------------------------------- paper headline: energy

@pytest.mark.parametrize("bits", WIDTHS)
def test_tp_half_double_digit_savings(bits):
    sav = pm.energy_savings_vs_star(bits, bits, FB2)
    assert sav > 0.10, f"{bits}b FB2 saving {sav:.1%} not double-digit"
    assert sav < 0.40, f"{bits}b FB2 saving {sav:.1%} above paper ceiling"


def test_savings_grow_with_width_toward_paper_max():
    # paper: 'up to 33%' -- the max over Table-VIII widths must approach
    # it from below, and widen monotonically (glitch depth grows with nb)
    savs = [pm.energy_savings_vs_star(b, b, FB2) for b in WIDTHS]
    assert all(a < b for a, b in zip(savs, savs[1:]))
    assert 0.25 < max(savs) < 0.40


# --------------------------------------------- paper headline: peak power

@pytest.mark.parametrize("bits", WIDTHS)
def test_tp_half_peak_reduction(bits):
    red = pm.peak_power_reduction_vs_star(bits, bits, FB2)
    assert red > 0.40, f"{bits}b FB2 peak reduction {red:.1%} too small"


def test_average_peak_reduction_near_paper():
    reds = [pm.peak_power_reduction_vs_star(b, b, FB2) for b in WIDTHS]
    avg = sum(reds) / len(reds)
    assert 0.50 < avg < 0.75, f"avg peak reduction {avg:.1%} vs paper 65%"


@pytest.mark.parametrize("arch,ct", [("fb", 2), ("fb", 4), ("fb", 8),
                                     ("ff", 2), ("ff", 6)])
def test_peak_switched_below_star(arch, ct):
    for bits in WIDTHS:
        cfg = MCIMConfig(arch=arch, ct=ct)
        assert pm.peak_switched(bits, bits, cfg) < \
            pm.peak_switched(bits, bits, STAR)


def test_karatsuba_peak_below_star_at_planner_widths():
    # the planner only picks karatsuba at >=128b; peak <= Star must hold
    # from 16b up (below that the recursion overhead dominates)
    for bits in (16, 32, 64, 128, 256):
        for levels in (1, 2, 3):
            cfg = MCIMConfig(arch="karatsuba", ct=3, levels=levels,
                             adder="3ca")
            assert pm.peak_switched(bits, bits, cfg) < \
                pm.peak_switched(bits, bits, STAR), (bits, levels)


# ------------------------------------------------------- CT monotonicity

@pytest.mark.parametrize("bits", (4, 8, 16, 32, 64, 128))
def test_energy_strictly_decreases_with_ct(bits):
    cts = range(2, min(12, bits) + 1)
    es = [pm.mcim_energy(bits, bits, MCIMConfig(arch="fb", ct=ct)).total
          for ct in cts]
    assert all(a > b for a, b in zip(es, es[1:])), \
        f"fb energy not strictly decreasing over ct at {bits}b: {es}"


def test_folded_always_cheaper_than_star():
    for bits in WIDTHS:
        star = pm.mcim_energy(bits, bits, STAR).total
        for arch in ("fb", "ff"):
            for ct in (2, 3, 4, 6):
                e = pm.mcim_energy(bits, bits,
                                   MCIMConfig(arch=arch, ct=ct)).total
                assert e < star, (bits, arch, ct)


# ------------------------------------------------------------- structure

def test_signed_overhead():
    u = pm.mcim_energy(32, 32, FB2)
    s = pm.mcim_energy(32, 32, MCIMConfig(arch="fb", ct=2, signed=True))
    assert s.total > u.total
    assert s.compressor == pytest.approx(u.compressor * pm.SIGNED_OVERHEAD)
    assert s.ppm == u.ppm          # PP generation itself is unchanged


def test_karatsuba_energy_sane():
    # folded karatsuba at 128b must be cheaper than star, and 3CA
    # (narrower final adders, one per cycle) cheaper than 1CA
    star = pm.mcim_energy(128, 128, STAR).total
    for levels in (1, 2):
        k3 = pm.mcim_energy(128, 128, MCIMConfig(
            arch="karatsuba", ct=3, levels=levels, adder="3ca")).total
        k1 = pm.mcim_energy(128, 128, MCIMConfig(
            arch="karatsuba", ct=3, levels=levels, adder="1ca")).total
        assert k3 < star and k1 < star
        assert k3 < k1


def test_peak_power_mw_units():
    # peak power at an explicit clock must scale inversely with period
    p1 = pm.peak_power_mw(32, 32, FB2, clock_ns=1.0)
    p2 = pm.peak_power_mw(32, 32, FB2, clock_ns=2.0)
    assert p1 == pytest.approx(2 * p2)
    # default clock = the design's own combinational period
    dflt = pm.peak_power_mw(32, 32, FB2)
    assert dflt == pytest.approx(
        pm.peak_power_mw(32, 32, FB2, clock_ns=tm.t_comb("fb", 32)))


# ------------------------------------------------------- plan aggregation

def test_plan_energy_is_throughput_weighted():
    # a mixed bank's energy/op is weighted by each instance's op share
    cfgs = ((3, STAR), (1, FB2))       # the TP=3.5 use-case bank
    e = pm.plan_energy_per_op_pj(32, 32, cfgs)
    e_star = pm.energy_per_op_pj(32, 32, STAR)
    e_fb = pm.energy_per_op_pj(32, 32, FB2)
    w_star, w_fb = 3.0, 0.5            # ops/cycle contributed
    expect = (w_star * e_star + w_fb * e_fb) / (w_star + w_fb)
    assert e == pytest.approx(expect)
    assert min(e_star, e_fb) < e < max(e_star, e_fb)


def test_plan_peak_sums_instances():
    cfgs = ((2, FB2),)
    one = pm.plan_peak_power_mw(32, 32, ((1, FB2),), clock_ns=1.0)
    two = pm.plan_peak_power_mw(32, 32, cfgs, clock_ns=1.0)
    assert two == pytest.approx(2 * one)


def test_stress_scales_dynamic_energy():
    base = pm.plan_energy_per_op_pj(32, 32, ((1, FB2),))
    stressed = pm.plan_energy_per_op_pj(32, 32, ((1, FB2),), stress=1.5)
    assert stressed == pytest.approx(1.5 * base)
