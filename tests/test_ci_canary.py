"""CI canary: the jax version pinned in the workflow is the one tested.

``launch/hlo_cost.py`` parses *optimized HLO text*, a surface with no
stability guarantee -- dialect drift across jax releases silently breaks
FLOP accounting.  The CI workflow therefore pins ``jax[cpu]`` to one
tested version; this canary fails loudly when either side moves without
the other:

  * the workflow pin must equal the jax that is actually running the
    suite (bump ci.yml and re-validate, don't let them diverge), and
  * the running jax's optimized HLO must still parse into nonzero FLOPs
    (the drift the pin exists to prevent).
"""
import os
import re

import pytest
import jax
import jax.numpy as jnp

CI_YML = os.path.join(os.path.dirname(__file__), "..", ".github",
                      "workflows", "ci.yml")


def _pinned_version() -> str:
    with open(CI_YML) as f:
        text = f.read()
    m = re.search(r'JAX_PINNED_VERSION:\s*"([0-9][0-9a-z.]*)"', text)
    assert m, "ci.yml no longer declares JAX_PINNED_VERSION"
    return m.group(1)


def test_workflow_pin_matches_running_jax():
    pin = _pinned_version()
    if jax.__version__ != pin:
        pytest.fail(
            f"ci.yml pins jax=={pin} but the suite is running "
            f"jax=={jax.__version__}; bump the pin and re-validate "
            f"hlo_cost against the new release")


def test_pinned_jax_hlo_dialect_parses():
    """The fragile surface itself: optimized HLO from the pinned jax must
    yield a sane FLOP count through hlo_cost.analyze."""
    from repro.launch import hlo_cost
    m, k, n = 32, 64, 16
    a = jax.ShapeDtypeStruct((m, k), jnp.float32)
    b = jax.ShapeDtypeStruct((k, n), jnp.float32)
    txt = jax.jit(lambda x, y: x @ y).lower(a, b).compile().as_text()
    res = hlo_cost.analyze(txt)
    assert res["flops"] == 2 * m * k * n, (
        "hlo_cost no longer parses this jax's optimized HLO dialect")


def test_pinned_jax_hlo_dialect_parses_chained_dots():
    """Second dialect probe (re-validated at the 0.4.37 pin): chained
    contractions must each be found -- a parser that silently drops
    every dot but the first would still pass the single-dot probe."""
    from repro.launch import hlo_cost
    m = 32
    a = jax.ShapeDtypeStruct((m, m), jnp.float32)
    txt = jax.jit(lambda x: (x @ x) @ x).lower(a).compile().as_text()
    res = hlo_cost.analyze(txt)
    assert res["flops"] == 2 * (2 * m * m * m), (
        "hlo_cost missed a contraction in this jax's optimized HLO")
