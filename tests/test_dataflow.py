"""Tests of the static Pallas dataflow analyzer (repro.verify.dataflow).

Three angles: (1) every launch the repo can plan proves clean -- the
registry, the autotuner vocabulary on both substrates, the standalone
kernels and ragged batch shapes; (2) seeded corruptions (window tables,
synthetic hazard kernels, understated VMEM models) are REJECTED with
structured violations naming the offending grid step / scratch ref;
(3) the plan-time gate raises DataflowError through the public facade.
"""
from __future__ import annotations

import dataclasses

import numpy as np
import pytest
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import limbs as L
from repro.core.mcim import MCIMConfig
from repro.kernels import bank_fold, mcim_fold
from repro.kernels.introspect import LaunchContract
from repro.verify import (DataflowError, VerificationError,
                          assert_plan_dataflow, dataflow, vmem)


def _violated(violations, rule):
    return [v for v in violations if v.rule == rule]


# ---------------------------------------------------------------- clean

def test_registry_plans_prove_clean_on_both_substrates():
    """All 13 registry design points: every implied launch verifies
    with zero violations and a positive static arithmetic intensity."""
    from repro.designs import registry
    from repro.designs.compile import _plan_with_timing
    names = sorted(registry.names())
    assert len(names) >= 13
    for name in names:
        spec = registry.get(name)
        plan, _ = _plan_with_timing(spec)   # already dataflow-gated
        for substrate in ("kernel", "fused"):
            for rep in dataflow.analyze_plan(spec.bits_a, spec.bits_b,
                                             plan.configs,
                                             substrate=substrate):
                assert rep.ok, (name, substrate,
                                [v.describe() for v in rep.violations])
                assert rep.arith_intensity > 0
                assert rep.flops > 0 and rep.hbm_bytes > 0
                assert rep.vmem["total_bytes"] > 0


def test_vocabulary_clean_at_one_width():
    """Every planner-emittable instance arch at 32b, both substrates."""
    vocab = [MCIMConfig(arch="star", ct=1),
             MCIMConfig(arch="karatsuba", ct=3)]
    vocab += [MCIMConfig(arch=a, ct=ct) for a in ("fb", "ff")
              for ct in (2, 3, 12)]
    for cfg in vocab:
        vs = dataflow.verify_plan_dataflow(32, 32, ((1, cfg),))
        assert not vs, (cfg, [v.describe() for v in vs])


def test_signed_configs_analyze_like_unsigned():
    """Signedness is handled outside the kernel; the launches (and the
    cached reports) are identical."""
    cfg = MCIMConfig(arch="fb", ct=2)
    signed = dataclasses.replace(cfg, signed=True)
    a = dataflow.analyze_plan(32, 32, ((1, cfg),), substrate="fused")
    b = dataflow.analyze_plan(32, 32, ((1, signed),), substrate="fused")
    assert a == b


def test_standalone_kernels_and_ragged_batches():
    for rep in dataflow.analyze_standalone():
        assert rep.ok, (rep.name, [v.describe() for v in rep.violations])
        assert rep.arith_intensity > 0
    for rep in dataflow.analyze_tiling(32, batches=(8, 100, 513)):
        assert rep.ok, (rep.name, [v.describe() for v in rep.violations])


def test_report_serializes():
    rep = dataflow.analyze_plan(32, 32,
                                ((1, MCIMConfig(arch="star", ct=1)),),
                                substrate="fused")[0]
    d = rep.as_dict()
    assert d["ok"] and d["violations"] == []
    import json
    json.dumps(d)


# ------------------------------------------------- window-table rejection

def _geo(configs=(MCIMConfig(arch="fb", ct=1), MCIMConfig(arch="fb", ct=2)),
         la=2, lb=2):
    return bank_fold.super_geometry(configs, la, lb)


def test_window_off_by_one_hi_rejected():
    sg = _geo()
    tbl = sg.table()
    tbl[1, 1, 1] += 1                       # hi beyond LB
    vs = dataflow.check_window_table(sg, tbl)
    assert _violated(vs, "window-bounds")
    assert "instance 1 step 1" in _violated(vs, "window-bounds")[0].where


def test_window_overlap_rejected():
    sg = _geo()
    tbl = sg.table()
    tbl[1, 1, 0] -= 1                       # second window re-covers limb 0
    vs = dataflow.check_window_table(sg, tbl)
    assert _violated(vs, "window-overlap")


def test_window_coverage_gap_rejected():
    sg = _geo()
    tbl = sg.table()
    tbl[1, 1] = (0, 0)                      # real step masked out
    vs = dataflow.check_window_table(sg, tbl)
    assert _violated(vs, "window-empty")
    assert _violated(vs, "window-coverage")


def test_unmasked_idle_rejected_by_table_and_interpreter():
    """An idle step carrying a real window is caught twice: by the
    table rule AND independently by the abstract interpreter, which
    proves the step writes effective (maybe-nonzero) data to scratch."""
    configs = (MCIMConfig(arch="fb", ct=1), MCIMConfig(arch="fb", ct=2))
    sg = _geo(configs)
    tbl = sg.table()
    tbl[0, 1] = (0, 2)                      # instance 0 step 1 is idle
    vs = dataflow.check_window_table(sg, tbl)
    assert _violated(vs, "idle-unmasked")
    contract = bank_fold.launch_contract(configs, 2, 2, table=tbl)
    rep = dataflow.analyze_contract(contract)
    hits = _violated(rep.violations, "idle-step-effect")
    assert hits, [v.describe() for v in rep.violations]
    # the violation names the offending grid step and the scratch ref
    assert "(0, 0, 1)" in hits[0].where
    assert "scratch" in hits[0].detail


def test_window_shape_mismatch_rejected():
    sg = _geo()
    vs = dataflow.check_window_table(sg, np.zeros((1, 1, 2), np.int32))
    assert _violated(vs, "window-shape")


def test_hypothesis_random_corruptions_rejected():
    """Property: any single-cell corruption that changes a window table
    is rejected; the pristine table always passes."""
    hyp = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")

    configs = (MCIMConfig(arch="fb", ct=1), MCIMConfig(arch="fb", ct=2),
               MCIMConfig(arch="karatsuba", ct=3))
    sg = _geo(configs, la=4, lb=4)
    good = sg.table()
    assert not dataflow.check_window_table(sg, good)

    @hyp.given(st.integers(0, sg.n_instances - 1),
               st.integers(0, sg.max_steps - 1),
               st.integers(0, 1),
               st.integers(-2, sg.lb + 2))
    @hyp.settings(max_examples=120, deadline=None)
    def prop(i, j, k, val):
        tbl = good.copy()
        tbl[i, j, k] = val
        if np.array_equal(tbl, good):
            assert not dataflow.check_window_table(sg, tbl)
        else:
            assert dataflow.check_window_table(sg, tbl), \
                (i, j, k, val)

    prop()


def test_exhaustive_single_cell_corruptions_rejected():
    """Deterministic edition of the corruption property (runs even when
    the container lacks hypothesis): EVERY single-cell table change is
    rejected; every no-op rewrite passes."""
    configs = (MCIMConfig(arch="fb", ct=1), MCIMConfig(arch="fb", ct=2),
               MCIMConfig(arch="karatsuba", ct=3))
    sg = _geo(configs, la=4, lb=4)
    good = sg.table()
    assert not dataflow.check_window_table(sg, good)
    for i in range(sg.n_instances):
        for j in range(sg.max_steps):
            for k in range(2):
                for val in range(-2, sg.lb + 3):
                    tbl = good.copy()
                    tbl[i, j, k] = val
                    vs = dataflow.check_window_table(sg, tbl)
                    if np.array_equal(tbl, good):
                        assert not vs
                    else:
                        assert vs, (i, j, k, val)


# -------------------------------------------------- synthetic hazards

def _contract(name, fn, args, grid, scratch=(), model=1 << 20):
    return LaunchContract(name=name, fn=fn, args=args, grid=grid,
                          scratch_shapes=scratch,
                          vmem_model_bytes=model)


def test_read_before_write_rejected():
    """A kernel reading VMEM scratch before any write this run."""
    def kernel(x_ref, o_ref, acc_ref):
        o_ref[...] = acc_ref[...] + x_ref[...]

    def fn(x):
        return pl.pallas_call(
            kernel, grid=(1,),
            in_specs=[pl.BlockSpec((8, 8), lambda i: (0, 0))],
            out_specs=pl.BlockSpec((8, 8), lambda i: (0, 0)),
            out_shape=jax.ShapeDtypeStruct((8, 8), jnp.uint32),
            scratch_shapes=[pltpu.VMEM((8, 8), jnp.uint32)],
            interpret=True)(x)

    c = _contract("synthetic/rbw", fn,
                  (jax.ShapeDtypeStruct((8, 8), jnp.uint32),),
                  grid=(1,), scratch=(((8, 8), "uint32"),))
    rep = dataflow.analyze_contract(c)
    hits = _violated(rep.violations, "read-before-write")
    assert hits and "step (0,)" in hits[0].where


def test_waw_between_instances_rejected():
    """Two non-adjacent grid steps writing the same output block."""
    def kernel(x_ref, o_ref):
        o_ref[...] = x_ref[...]

    def fn(x):
        return pl.pallas_call(
            kernel, grid=(3,),
            in_specs=[pl.BlockSpec((8, 8), lambda i: (0, 0))],
            out_specs=pl.BlockSpec((8, 8), lambda i: (i % 2, 0)),
            out_shape=jax.ShapeDtypeStruct((16, 8), jnp.uint32),
            interpret=True)(x)

    c = _contract("synthetic/waw", fn,
                  (jax.ShapeDtypeStruct((8, 8), jnp.uint32),),
                  grid=(3,))
    rep = dataflow.analyze_contract(c)
    assert _violated(rep.violations, "waw-out")


def test_out_of_bounds_index_map_rejected():
    """An index map emitting a block index past the padded extent."""
    def kernel(x_ref, o_ref):
        o_ref[...] = x_ref[...]

    def fn(x):
        return pl.pallas_call(
            kernel, grid=(2,),
            in_specs=[pl.BlockSpec((8, 8), lambda i: (i + 1, 0))],
            out_specs=pl.BlockSpec((8, 8), lambda i: (i, 0)),
            out_shape=jax.ShapeDtypeStruct((16, 8), jnp.uint32),
            interpret=True)(x)

    c = _contract("synthetic/oob", fn,
                  (jax.ShapeDtypeStruct((16, 8), jnp.uint32),),
                  grid=(2,))
    rep = dataflow.analyze_contract(c)
    hits = _violated(rep.violations, "block-bounds")
    assert hits and "step (1,)" in hits[0].where


def test_grid_mismatch_rejected():
    """A contract whose declared grid disagrees with the traced one."""
    good = mcim_fold.launch_contract(2, 2, 2, "fb")
    bad = dataclasses.replace(good, grid=(1, 7))
    rep = dataflow.analyze_contract(bad)
    assert _violated(rep.violations, "grid-mismatch")


# --------------------------------------------------------------- vmem

def test_understated_vmem_model_rejected():
    good = mcim_fold.launch_contract(2, 2, 2, "fb")
    bad = dataclasses.replace(good, vmem_model_bytes=16)
    rep = dataflow.analyze_contract(bad)
    assert _violated(rep.violations, "vmem-model")


def test_vmem_budget_overflow_rejected():
    c = mcim_fold.launch_contract(2, 2, 2, "fb")
    rep = dataflow.analyze_contract(c, budget=64)
    assert _violated(rep.violations, "vmem-budget")


def test_vmem_breakdown_measures_kernel_refs():
    c = mcim_fold.launch_contract(2, 2, 2, "fb")
    eqn = dataflow.jaxpr_walk.find_pallas_calls(c.trace().jaxpr)[0]
    bd = vmem.measure(eqn)
    assert bd.in_bytes > 0 and bd.out_bytes > 0
    assert bd.scratch_bytes > 0
    assert bd.total_bytes == (bd.in_bytes + bd.out_bytes +
                              bd.scratch_bytes + bd.smem_bytes)
    assert bd.fold_bytes <= c.vmem_model_bytes


# ---------------------------------------------------------------- gate

def test_assert_plan_dataflow_passes_clean_plan():
    assert_plan_dataflow(64, 64, ((3, MCIMConfig(arch="star", ct=1)),
                                  (1, MCIMConfig(arch="fb", ct=2))))


def test_assert_plan_dataflow_raises_structured_error():
    """An impossible VMEM budget fails every launch: the gate raises a
    DataflowError (a VerificationError) with structured violations."""
    with pytest.raises(DataflowError) as ei:
        assert_plan_dataflow(64, 64, ((1, MCIMConfig(arch="fb", ct=2)),),
                             budget=64)
    assert isinstance(ei.value, VerificationError)
    assert any(v.rule == "vmem-budget" for v in ei.value.violations)
    assert all(v.analyzer == "dataflow" for v in ei.value.violations)


def test_generate_gates_dataflow(monkeypatch):
    """The designs facade runs the dataflow gate at plan time."""
    from repro import designs, verify
    calls = []
    real = verify.assert_plan_dataflow

    def spy(*a, **kw):
        calls.append(a)
        return real(*a, **kw)

    monkeypatch.setattr(verify, "assert_plan_dataflow", spy)
    designs.generate(designs.DesignSpec(16, 16, "1/2"))
    assert calls


# ----------------------------------------------------------- roofline

def test_roofline_shares_jaxpr_walker():
    """launch.roofline's Pallas counting runs on verify.jaxpr_walk."""
    from repro.launch import roofline
    c = bank_fold.launch_contract((MCIMConfig(arch="star", ct=1),), 2, 2)
    assert roofline.count_pallas_launches(c.fn, *c.args) == 1
    assert dataflow.jaxpr_walk.count_primitive(c.trace().jaxpr,
                                               "pallas_call") == 1


def test_static_stats_for_bench_columns():
    configs = ((3, MCIMConfig(arch="star", ct=1)),
               (1, MCIMConfig(arch="fb", ct=2)))
    s = dataflow.plan_static_stats(32, 32, configs)
    assert s["vmem_bytes_step"] > 0
    assert s["arith_intensity"] > 0
    assert s["flops_per_launch"] > 0
    assert s["hbm_bytes_per_launch"] > 0


def test_fused_flops_scale_with_instances():
    """More instances -> more grid steps -> more static FLOPs, while
    the per-step VMEM stays flat (the fused datapath is time-shared)."""
    one = dataflow.plan_static_stats(
        32, 32, ((1, MCIMConfig(arch="fb", ct=2)),))
    four = dataflow.plan_static_stats(
        32, 32, ((4, MCIMConfig(arch="fb", ct=2)),))
    assert four["flops_per_launch"] > one["flops_per_launch"]
    # only the SMEM table grows (3 more instances x 2 steps x 2 int32
    # scalars); the block residency is unchanged -- time-sharing
    assert four["vmem_bytes_step"] - one["vmem_bytes_step"] == 3 * 2 * 2 * 4
