"""Attention equivalences: chunked flash vs naive, banded vs masked,
decode vs flash, int8 decode accuracy, hypothesis sweeps."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.models.attention import (flash_attention, decode_attention,
                                    decode_attention_int8, _band_pairs)

RNG = np.random.default_rng(9)


def _qkv(b=2, sq=256, sk=256, h=4, kv=2, d=32, dtype=jnp.float32):
    q = jnp.asarray(RNG.standard_normal((b, sq, h, d)), dtype)
    k = jnp.asarray(RNG.standard_normal((b, sk, kv, d)), dtype)
    v = jnp.asarray(RNG.standard_normal((b, sk, kv, d)), dtype)
    return q, k, v


def _naive(q, k, v, mask_kind, window=None, prefix_len=None, cap=None):
    b, sq, h, d = q.shape
    kv = k.shape[2]
    g = h // kv
    qg = q.reshape(b, sq, kv, g, d).astype(jnp.float32)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qg, k.astype(jnp.float32))
    s = s / np.sqrt(d)
    if cap is not None:
        s = jnp.tanh(s / cap) * cap
    qpos = jnp.arange(sq)[:, None]
    kpos = jnp.arange(k.shape[1])[None, :]
    if mask_kind == "causal":
        m = kpos <= qpos
    elif mask_kind == "local":
        m = (kpos <= qpos) & (kpos > qpos - window)
    elif mask_kind == "prefix":
        m = (kpos <= qpos) | (kpos < prefix_len)
    else:
        m = jnp.ones_like(kpos <= qpos)
    s = jnp.where(m[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bskd->bqkgd", p, v.astype(jnp.float32))
    return o.reshape(b, sq, h, d)


@pytest.mark.parametrize("mask_kind,window,prefix", [
    ("causal", None, None), ("local", 64, None),
    ("prefix", None, 48), ("none", None, None)])
@pytest.mark.parametrize("qc,kc", [(64, 64), (128, 32), (256, 256)])
def test_flash_matches_naive(mask_kind, window, prefix, qc, kc):
    q, k, v = _qkv()
    got = flash_attention(q, k, v, mask_kind=mask_kind, window=window,
                          prefix_len=prefix, q_chunk=qc, k_chunk=kc)
    want = _naive(q, k, v, mask_kind, window, prefix)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("mask_kind,window,prefix", [
    ("causal", None, None), ("local", 64, None), ("prefix", None, 48)])
def test_banded_matches_masked(mask_kind, window, prefix):
    q, k, v = _qkv()
    a = flash_attention(q, k, v, mask_kind=mask_kind, window=window,
                        prefix_len=prefix, q_chunk=64, k_chunk=64,
                        schedule="masked")
    b = flash_attention(q, k, v, mask_kind=mask_kind, window=window,
                        prefix_len=prefix, q_chunk=64, k_chunk=64,
                        schedule="banded")
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=2e-4, atol=2e-4)


def test_banded_skips_half_the_work():
    """The compute-term claim: causal band ~ half the chunk pairs."""
    full = len(_band_pairs(8, 8, "none", None, 64, None))
    causal = len(_band_pairs(8, 8, "causal", None, 64, None))
    local = len(_band_pairs(8, 8, "local", 64, 64, None))
    assert causal == 36 and full == 64      # n(n+1)/2
    assert local <= 2 * 8                   # diagonal band


def test_softcap_applied():
    q, k, v = _qkv(sq=64, sk=64)
    got = flash_attention(q, k, v, mask_kind="causal", logit_cap=5.0,
                          q_chunk=32, k_chunk=32)
    want = _naive(q, k, v, "causal", cap=5.0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=3e-4, atol=3e-4)


def test_decode_matches_flash_last_row():
    """decode over a cache == last row of full flash attention."""
    q, k, v = _qkv(sq=128, sk=128)
    full = flash_attention(q, k, v, mask_kind="causal", q_chunk=32,
                           k_chunk=32)
    valid = jnp.ones((2, 128), bool)
    dec = decode_attention(q[:, -1:], k, v, valid)
    np.testing.assert_allclose(np.asarray(dec[:, 0]),
                               np.asarray(full[:, -1]), rtol=2e-3, atol=2e-3)


def test_int8_decode_close_to_fp():
    q, k, v = _qkv(sq=1, sk=256, dtype=jnp.bfloat16)
    valid = jnp.ones((2, 256), bool)
    ref = decode_attention(q, k, v, valid)
    amax_k = jnp.max(jnp.abs(k.astype(jnp.float32)), -1)
    amax_v = jnp.max(jnp.abs(v.astype(jnp.float32)), -1)
    ks = jnp.where(amax_k == 0, 1, amax_k / 127)
    vs = jnp.where(amax_v == 0, 1, amax_v / 127)
    k8 = jnp.round(k.astype(jnp.float32) / ks[..., None]).astype(jnp.int8)
    v8 = jnp.round(v.astype(jnp.float32) / vs[..., None]).astype(jnp.int8)
    got = decode_attention_int8(q, k8, ks, v8, vs, valid)
    err = np.abs(np.asarray(got - ref, np.float32))
    assert err.max() < 0.08, err.max()


@settings(max_examples=15, deadline=None)
@given(st.integers(1, 3), st.sampled_from([32, 48, 96]),
       st.sampled_from([1, 2, 4]))
def test_property_flash_shapes(b, s, kv):
    """Shape sweep incl. non-chunk-divisible sequence lengths."""
    h, d = 4, 16
    q = jnp.asarray(RNG.standard_normal((b, s, h, d)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((b, s, kv, d)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((b, s, kv, d)), jnp.float32)
    got = flash_attention(q, k, v, mask_kind="causal", q_chunk=32,
                          k_chunk=32)
    want = _naive(q, k, v, "causal")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=3e-4, atol=3e-4)
